"""Benchmark driver — one module per paper table.

  bench_svm       — Tables 4/5 (HSS accuracy presets: compression /
                    factorization / memory / ADMM time / accuracy)
  bench_baselines — Tables 2/3 (dense-ADMM = RACQP role, SMO = LIBSVM role,
                    Nystrom rival, HSS-ADMM ours)
  bench_grid      — Figure 2 + the C-grid amortization headline
  bench_kernels   — kernel micro-benches + HSS O(N r) scaling evidence

Prints ``name,us_per_call,derived`` CSV.  Roofline numbers come from the
dry-run sweep (benchmarks/run_dryrun_sweep.sh -> EXPERIMENTS.md), not from
CPU wall-time.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import bench_baselines, bench_grid, bench_kernels, \
        bench_svm

    rows: list = []
    print("name,us_per_call,derived")
    for mod in (bench_kernels, bench_svm, bench_baselines, bench_grid):
        t0 = time.time()
        try:
            start = len(rows)
            mod.run(rows)
            for r in rows[start:]:
                print(",".join(str(x) for x in r), flush=True)
            print(f"# {mod.__name__} done in {time.time()-t0:.1f}s",
                  file=sys.stderr)
        except Exception:   # noqa: BLE001 — keep the suite going
            traceback.print_exc()
            print(f"{mod.__name__},0,ERROR", flush=True)


if __name__ == "__main__":
    main()
