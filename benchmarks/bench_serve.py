"""Closed-loop serving bench: batched ticks vs the per-request demo loop.

One trained model per task (binary / k-class OVR / k-class OVO / ε-SVR /
ν one-class), then the same closed-loop request stream — R requests of q
query points each — driven through the serving tier two ways:

  * **loop** — the per-request demo loop the launch CLI used to hand-roll:
    every request is its own tick (bucket = request size), so each pays a
    full dispatch + kernel launch + host decode;
  * **ticks** — request-level dynamic batching: ``max_batch`` queued query
    rows trigger a tick, so 64 requests share ONE multi-column
    ``kernel_matvec_streamed`` launch and one host decode.

Both paths run the SAME jitted scorer (``repro.serve.batched_scores``), so
f32 predictions are bit-identical between them and to the trained model's
own ``predict`` — the recorded ``accuracy`` field is the served-vs-trained
prediction agreement of the batched path, which ci/check_bench.py
hard-gates against the committed reference (accuracy drift in the serving
tier fails CI; p50/p99 latency regressions warn).

Per task the JSON record carries: sustained QPS (query points/s) and
p50/p99 request latency for both paths, the batched-over-loop throughput
gain (the acceptance floor is >= 3x at tick batches of >= 64 requests),
and the shared-cache counters of the batched engine.

Usage: python benchmarks/bench_serve.py --json BENCH_serve.json [--smoke]
The committed BENCH_serve.json is generated with --smoke (the scale the
ci/run_tests.sh --bench tier reruns, so the guard compares like to like).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.data import synthetic
from repro.serve import BatchPolicy, ServingEngine

COMP = CompressionParams(rank=32, n_near=48, n_far=64)

# (case, engine task, strategy, h, knob): the four box-QP task families,
# with k-class served both ways (OVR argmax and OVO vote decode).
TASK_CASES = [
    ("binary", "svm", "ovr", 1.2, 1.0),
    ("ovr", "svm", "ovr", 1.5, 1.0),
    ("ovo", "svm", "ovo", 1.5, 1.0),
    ("svr", "svr", "ovr", 1.0, 0.1),
    ("oneclass", "oneclass", "ovr", 2.0, 0.1),
]

N_REQUESTS = 256          # closed-loop request count per path
QUERIES_PER_REQUEST = 2   # small per-request payload — the batching regime
TICK_REQUESTS = 64        # requests per batched tick (>= the acceptance 64)

JSON_RECORDS: list[dict] = []


def _record(case: str, **kw) -> dict:
    rec = dict(case=case, **kw)
    JSON_RECORDS.append(rec)
    return rec


def _train(case, task, strategy, h, knob, n_train, n_test):
    if case == "binary":
        xtr, ytr, xte, _ = synthetic.train_test(
            "blobs", n_train, n_test, seed=0, n_features=6, sep=2.0)
    elif case in ("ovr", "ovo"):
        xtr, ytr, xte, _ = synthetic.train_test(
            "multiclass_blobs", n_train, n_test, seed=0, n_classes=4,
            sep=3.0)
    elif case == "svr":
        xtr, ytr, xte, _ = synthetic.train_test(
            "noisy_sine", n_train, n_test, seed=0, noise=0.1)
    else:
        xtr, _ = synthetic.blobs_with_outliers(
            n_train, n_features=4, outlier_frac=0.1, seed=0)
        xte, _ = synthetic.blobs_with_outliers(
            n_test, n_features=4, outlier_frac=0.1, seed=1)
        ytr = None
    eng = HSSSVMEngine(
        spec=KernelSpec(h=h), comp=COMP, leaf_size=128,
        max_it=30 if task == "oneclass" else 10, task=task,
        strategy=strategy, svr_c=2.0 if task == "svr" else 1.0)
    model = eng.fit(xtr, ytr, c_value=knob)
    return model, np.asarray(xte, np.float32)


def _percentiles_ms(latencies: list[float]) -> tuple[float, float]:
    lat = np.sort(np.asarray(latencies)) * 1e3
    p50 = float(lat[len(lat) // 2])
    p99 = float(lat[min(int(np.ceil(len(lat) * 0.99)) - 1, len(lat) - 1)])
    return p50, p99


def _requests(xte: np.ndarray, n_requests: int, q: int, seed: int = 1):
    r = np.random.default_rng(seed)
    idx = r.integers(0, xte.shape[0], size=(n_requests, q))
    return [xte[i] for i in idx]


def _agreement(preds: list[np.ndarray], ref: np.ndarray) -> float:
    got = np.concatenate([np.asarray(p).reshape(-1) for p in preds])
    if np.issubdtype(ref.dtype, np.floating) and not np.issubdtype(
            got.dtype, np.integer):
        # svr: regression values — agreement is exact f32 match
        return float(np.mean(got == ref))
    return float(np.mean(got == ref))


def bench_task(case, task, strategy, h, knob, scale: float) -> dict:
    n_train = max(int(4096 * scale), 512)
    n_test = 1024
    model, xte = _train(case, task, strategy, h, knob, n_train, n_test)
    q = QUERIES_PER_REQUEST
    reqs = _requests(xte, N_REQUESTS, q)
    all_rows = np.concatenate(reqs, axis=0)
    ref_preds = np.asarray(model.predict(jnp.asarray(all_rows))).reshape(-1)

    # --- per-request demo loop: one tick (and one launch) per request ----
    loop = ServingEngine(policy=BatchPolicy(buckets=(q,)))
    mid = loop.add_model(model)
    loop.score(mid, reqs[0])                    # compile outside timing
    loop.drain_latencies()
    preds_loop = []
    t0 = time.perf_counter()
    for xq in reqs:
        _, p = loop.score(mid, xq)
        preds_loop.append(p)
    loop_s = time.perf_counter() - t0
    loop_p50, loop_p99 = _percentiles_ms(loop.drain_latencies())
    loop_qps = N_REQUESTS * q / loop_s

    # --- batched ticks: max_batch rows of queued requests per launch -----
    tick_rows = TICK_REQUESTS * q
    ticks = ServingEngine(policy=BatchPolicy(
        max_batch=tick_rows, buckets=(tick_rows,)))
    mid = ticks.add_model(model)
    ticks.score(mid, np.concatenate(reqs[:TICK_REQUESTS]))  # compile
    ticks.drain_latencies()
    t0 = time.perf_counter()
    tickets = [ticks.submit(mid, xq) for xq in reqs]  # max_batch auto-ticks
    ticks.flush()                                     # drain the remainder
    ticks_s = time.perf_counter() - t0
    preds_ticks = [t.result(timeout=0)[1] for t in tickets]
    tick_p50, tick_p99 = _percentiles_ms(ticks.drain_latencies())
    tick_qps = N_REQUESTS * q / ticks_s
    stats = ticks.stats()

    agree_ticks = _agreement(preds_ticks, ref_preds)
    agree_loop = _agreement(preds_loop, ref_preds)
    speedup = tick_qps / max(loop_qps, 1e-9)
    rec = _record(
        f"serve/{case}",
        n_train=n_train, task=task, strategy=strategy,
        requests=N_REQUESTS, queries_per_request=q,
        tick_requests=TICK_REQUESTS,
        accuracy=agree_ticks,             # served-vs-trained, hard-gated
        agreement_loop=agree_loop,
        qps=tick_qps, loop_qps=loop_qps, speedup=speedup,
        p50_ms=tick_p50, p99_ms=tick_p99,
        loop_p50_ms=loop_p50, loop_p99_ms=loop_p99,
        launches=stats["launches"], support_uploads=stats["support_uploads"],
    )
    print(f"serve/{case}: loop {loop_qps:.0f} q/s "
          f"(p50 {loop_p50:.2f}ms p99 {loop_p99:.2f}ms) -> ticks "
          f"{tick_qps:.0f} q/s (p50 {tick_p50:.2f}ms p99 {tick_p99:.2f}ms) "
          f"= {speedup:.1f}x, agreement {agree_ticks:.4f}")
    return rec


def bench_shared_cache(scale: float) -> None:
    """The factorization-sharing economy at serve time: k same-(h, β)
    models behind one engine = ONE support upload and one launch per tick,
    vs one per model without sharing."""
    n_train = max(int(4096 * scale), 512)
    xtr, ytr, xte, _ = synthetic.train_test(
        "blobs", n_train, 512, seed=0, n_features=6, sep=2.0)
    eng = HSSSVMEngine(spec=KernelSpec(h=1.2), comp=COMP, leaf_size=128,
                       max_it=10)
    eng.prepare(xtr, ytr)
    models = eng.train_grid([0.25, 0.5, 1.0, 2.0])

    serve = ServingEngine()
    ids = [serve.add_model(m) for m in models]
    xq = np.asarray(xte[:64], np.float32)
    for i in ids:
        serve.submit(i, xq)
    serve.flush()
    st = serve.stats()
    xs_bytes = int(np.asarray(jax.device_get(models[0].x_perm)).nbytes)
    _record(
        "serve/shared_cache",
        n_train=n_train, n_models=len(models),
        cache_entries=st["cache_entries"],
        support_uploads=st["support_uploads"],
        launches=st["launches"],
        resident_support_bytes=st["resident_support_bytes"],
        unshared_support_bytes=xs_bytes * len(models),
    )
    print(f"serve/shared_cache: {len(models)} models -> "
          f"{st['cache_entries']} cache entry, {st['support_uploads']} "
          f"upload, {st['launches']} launch/tick, "
          f"{st['resident_support_bytes']}B resident "
          f"(vs {xs_bytes * len(models)}B unshared)")


def write_json(path: str) -> None:
    payload = dict(
        n_devices=jax.device_count(),
        backend=jax.default_backend(),
        results=JSON_RECORDS,
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {len(JSON_RECORDS)} records to {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable output path")
    ap.add_argument("--smoke", action="store_true",
                    help="toy training sizes — the ci/run_tests.sh --bench "
                         "tier (the committed reference scale)")
    args = ap.parse_args()

    scale = 0.125 if args.smoke else 1.0
    for case, task, strategy, h, knob in TASK_CASES:
        bench_task(case, task, strategy, h, knob, scale)
    bench_shared_cache(scale)
    write_json(args.json)
