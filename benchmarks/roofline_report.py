"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the sweep JSONL.

  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_sweep.jsonl
"""
from __future__ import annotations

import json
import sys
from collections import defaultdict

GB = 1e9
TB = 1e12


def _lever(rec: dict) -> str:
    """One sentence: what would move the dominant term down."""
    ro = rec.get("roofline", {})
    dom = ro.get("dominant", "?")
    arch, shape = rec.get("arch", ""), rec.get("shape", "")
    if arch == "svm-hss-admm":
        return ("memory-bound leaf G·b einsums: fuse leaf solve into one "
                "batched triangular pass (or bf16 leaf factors)")
    if dom == "memory":
        if "decode" in rec.get("kind", ""):
            return ("decode reads the whole KV cache per token: quantize "
                    "cache to int8 / shrink via GQA-sharing")
        return ("attention score traffic in the XLA fallback dominates: the "
                "Pallas flash kernel keeps tiles in VMEM (projected below)")
    if dom == "collective":
        return ("TP all-reduce per layer dominates: overlap with compute "
                "(async collectives) or shift TP->more DP/FSDP")
    return ("compute-bound: raise per-chip utilization via larger "
            "microbatch or reduce remat recompute")


def load(path: str) -> list[dict]:
    return [json.loads(l) for l in open(path)]


def dryrun_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | args GB/dev | temp GB/dev | "
        "compile s | collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            m = r["memory"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{m['argument_bytes']/GB:.2f} | {m['temp_bytes']/GB:.2f} | "
                f"{r['compile_s']} | {r['collectives']['n_collectives']} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"SKIP — {reason} | | | | |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | t_comp s | t_mem s | t_coll s | dominant | "
        "bound s | MODEL/HLO flops | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok" or r["mesh"] != "16x16":
            continue
        ro = r["roofline"]
        ratio = r.get("model_vs_hlo_flops")
        ratio_s = f"{ratio:.3f}" if ratio else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute_s']:.3g} | "
            f"{ro['t_memory_s']:.3g} | {ro['t_collective_s']:.3g} | "
            f"{ro['dominant']} | {ro['step_time_bound_s']:.3g} | {ratio_s} | "
            f"{_lever(r)} |")
    return "\n".join(lines)


def interesting_cells(recs: list[dict]) -> str:
    """Pick the three hill-climb cells per the assignment."""
    ok = [r for r in recs
          if r["status"] == "ok" and r["mesh"] == "16x16"
          and r.get("arch") != "svm-hss-admm"]
    worst_fraction = max(
        ok, key=lambda r: (r["roofline"]["step_time_bound_s"] /
                           max(r["roofline"]["t_compute_s"], 1e-12)))
    most_coll = max(ok, key=lambda r: r["roofline"]["t_collective_s"])
    out = [
        f"* worst roofline fraction (bound/compute): "
        f"{worst_fraction['arch']} x {worst_fraction['shape']} "
        f"(bound {worst_fraction['roofline']['step_time_bound_s']:.3g}s vs "
        f"compute {worst_fraction['roofline']['t_compute_s']:.3g}s)",
        f"* most collective-bound: {most_coll['arch']} x "
        f"{most_coll['shape']} "
        f"(t_coll {most_coll['roofline']['t_collective_s']:.3g}s)",
        "* paper-representative: mamba2-780m x train_4k (SSD = semiseparable"
        " evaluation, DESIGN.md §5) + the svm-hss-admm cell itself",
    ]
    return "\n".join(out)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_sweep.jsonl"
    recs = load(path)
    # dedup: keep the LAST record per cell (later runs supersede)
    seen = {}
    for r in recs:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    recs = list(seen.values())
    print("## Dry-run matrix\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16, per device)\n")
    print(roofline_table(recs))
    print("\n## Hill-climb cell selection\n")
    print(interesting_cells(recs))


if __name__ == "__main__":
    main()
