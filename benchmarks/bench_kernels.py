"""Kernel microbenchmarks + HSS scaling evidence.

  * gaussian/admm/ssd/attention Pallas kernels (interpret mode — correctness
    path; TPU wall-times come from the roofline analysis, not CPU timing)
  * HSS matvec / factorize / solve scaling in N at fixed rank — the paper's
    O(N r) / O(N r^2) claims: time ratios across doublings should approach
    2x, not 4x.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, factorization, tree as tree_mod
from repro.core.kernelfn import KernelSpec


def _timeit(fn, n_iter=3):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(n_iter):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n_iter


def run(csv_rows: list) -> None:
    rng = np.random.default_rng(0)

    # --- gaussian block kernel (XLA path — production CPU path) ---
    xa = jnp.asarray(rng.normal(size=(1024, 16)), jnp.float32)
    from repro.core.kernelfn import gaussian_block_xla

    dt = _timeit(lambda: gaussian_block_xla(xa, xa, 1.0))
    csv_rows.append(("kernel_gaussian_xla_1024x1024", dt * 1e6,
                     f"gbps={(1024*1024*4)/dt/1e9:.2f}"))

    # --- HSS scaling in N ---
    prev = {}
    for n in (2048, 4096, 8192):
        x = rng.normal(size=(n, 4)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=256)
        xp = jnp.asarray(x[t.perm])
        spec = KernelSpec(h=1.0)
        t0 = time.perf_counter()
        hss = compression.compress(
            xp, t, spec,
            compression.CompressionParams(rank=32, n_near=32, n_far=48))
        jax.block_until_ready(hss.d_leaf)
        t_comp = time.perf_counter() - t0

        t0 = time.perf_counter()
        fac = factorization.factorize(hss, 100.0)
        jax.block_until_ready(fac.root_lu)
        t_fac = time.perf_counter() - t0

        b = jnp.asarray(rng.normal(size=n), jnp.float32)
        solve = jax.jit(fac.solve)
        t_solve = _timeit(lambda: solve(b), n_iter=5)
        mv = jax.jit(hss.matvec)
        t_mv = _timeit(lambda: mv(b), n_iter=5)

        ratios = ""
        if prev:
            ratios = (f";solve_ratio={t_solve/prev['solve']:.2f}"
                      f";matvec_ratio={t_mv/prev['mv']:.2f}")
        csv_rows.append((
            f"hss_scaling/n{n}", t_solve * 1e6,
            f"compress_s={t_comp:.2f};factor_s={t_fac:.2f};"
            f"solve_us={t_solve*1e6:.0f};matvec_us={t_mv*1e6:.0f}"
            f";mem_mb={hss.memory_bytes()/1e6:.1f}" + ratios))
        prev = dict(solve=t_solve, mv=t_mv)

    # --- pallas kernels, interpret mode (correctness-path cost) ---
    from repro.kernels.admm_update import ops as aops

    xv = jnp.asarray(rng.normal(size=65536), jnp.float32)
    mu = jnp.zeros(65536, jnp.float32)
    cv = jnp.ones(65536, jnp.float32)
    dt = _timeit(lambda: aops.fused_zmu_update(xv, mu, cv, 100.0,
                                               interpret=True))
    csv_rows.append(("kernel_admm_fused_interpret_64k", dt * 1e6, ""))

    from repro.kernels.ssd import ops as sops

    x = jnp.asarray(rng.normal(size=(1, 128, 4, 32)), jnp.float32)
    dts = jnp.asarray(np.abs(rng.normal(size=(1, 128, 4))) * 0.1 + 0.01,
                      jnp.float32)
    a = jnp.asarray(-np.ones(4), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(1, 128, 1, 16)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.normal(size=(1, 128, 1, 16)) * 0.3, jnp.float32)
    dv = jnp.zeros(4, jnp.float32)
    dt = _timeit(lambda: sops.ssd_forward(x, dts, a, bm, cm, dv, chunk=32,
                                          interpret=True))
    csv_rows.append(("kernel_ssd_interpret_s128", dt * 1e6, ""))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
