"""Paper Tables 2/3 analogue: baselines with the TRUE kernel matrix.

  dense-ADMM   — exact kernel + dense Cholesky (the RACQP role, Table 3)
  SMO          — max-violating-pair working-set solver (the LIBSVM role,
                 Table 2)
  nystrom-ADMM — low-rank approximation rival (paper §1.1's alternative)
  hss-ADMM     — ours

The paper's claim to reproduce: comparable accuracy, with HSS-ADMM's
*training* time flat in n while exact-kernel baselines blow up — the
crossover is visible already at CPU-feasible sizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines
from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic


def run(csv_rows: list) -> None:
    h, c_val = 1.0, 1.0
    for n_train in (1024, 4096):
        xtr, ytr, xte, yte = synthetic.train_test(
            "circles", n_train, 1024, seed=1, n_features=4, gap=0.8)
        xj, yj = jnp.asarray(xtr), jnp.asarray(ytr)
        xtj = jnp.asarray(xte)
        spec = KernelSpec(h=h)

        # ---- dense ADMM (RACQP analogue) ----
        t0 = time.perf_counter()
        z, b = baselines.dense_admm_fit(xj, yj, spec, c_val, beta=100.0)
        jax.block_until_ready(z)
        t_dense = time.perf_counter() - t0
        acc = float(jnp.mean(
            baselines.dense_predict(xj, yj, z, b, spec, xtj) == yte))
        csv_rows.append((f"svm_table23/dense_admm/n{n_train}", t_dense * 1e6,
                         f"acc={acc:.4f};runtime_s={t_dense:.3f}"))

        # ---- SMO (LIBSVM analogue) ----
        t0 = time.perf_counter()
        alpha, b_smo, iters = baselines.smo_fit(xtr, ytr, spec, c_val,
                                                max_iter=4000)
        t_smo = time.perf_counter() - t0
        scores = np.asarray(
            baselines.dense_predict(xj, yj, jnp.asarray(alpha, jnp.float32),
                                    b_smo, spec, xtj))
        acc = float((scores == yte).mean())
        csv_rows.append((f"svm_table23/smo/n{n_train}", t_smo * 1e6,
                         f"acc={acc:.4f};runtime_s={t_smo:.3f};iters={iters}"))

        # ---- Nystrom ADMM ----
        t0 = time.perf_counter()
        z, b = baselines.nystrom_admm_fit(xj, yj, spec, c_val, beta=100.0,
                                          n_landmarks=min(256, n_train))
        jax.block_until_ready(z)
        t_nys = time.perf_counter() - t0
        acc = float(jnp.mean(
            baselines.dense_predict(xj, yj, z, b, spec, xtj) == yte))
        csv_rows.append((f"svm_table23/nystrom_admm/n{n_train}", t_nys * 1e6,
                         f"acc={acc:.4f};runtime_s={t_nys:.3f}"))

        # ---- HSS ADMM (ours) ----
        trainer = HSSSVMTrainer(
            spec=spec, comp=CompressionParams(rank=32, n_near=48, n_far=64),
            leaf_size=128, max_it=10)
        t0 = time.perf_counter()
        model = trainer.fit(xtr, ytr, c_value=c_val)
        t_hss = time.perf_counter() - t0
        acc = float(jnp.mean(model.predict(xtj) == yte))
        csv_rows.append((
            f"svm_table23/hss_admm/n{n_train}", t_hss * 1e6,
            f"acc={acc:.4f};runtime_s={t_hss:.3f};"
            f"admm_only_s={trainer.report.admm_s:.3f}"))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
