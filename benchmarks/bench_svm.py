"""Paper Tables 4/5 analogue: HSS-ADMM at two approximation accuracies.

Columns mirror the paper: Compression [s] | Factorization [s] | Memory [MB] |
ADMM Time [s] (per C, MaxIt=10) | Accuracy [%].  Two presets mirror the
paper's STRUMPACK settings: "crude" (Table 4: hss_max_rank=200, 64
neighbours — here rank 32) and "accurate" (Table 5: rank 2000, 512
neighbours — here rank 64).  The paper's headline observations to check:
  (1) crude ≈ accurate in accuracy (approximation tolerance of SVMs),
  (2) ADMM time << compression time (the C-grid amortization),
  (3) memory scales O(N r), not O(N^2).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

PRESETS = {
    "crude": CompressionParams(rank=32, n_near=32, n_far=32),
    "accurate": CompressionParams(rank=64, n_near=64, n_far=128),
}

DATASETS = [
    ("blobs", dict(n_features=8, sep=1.6), 8192, 2048, 1.0),
    ("circles", dict(n_features=4, gap=0.8), 8192, 2048, 0.5),
    ("susy_like", dict(), 16384, 4096, 3.0),
]


def run(csv_rows: list) -> None:
    for name, kw, n_train, n_test, h in DATASETS:
        xtr, ytr, xte, yte = synthetic.train_test(name, n_train, n_test,
                                                  seed=0, **kw)
        for preset_name, comp in PRESETS.items():
            trainer = HSSSVMTrainer(
                spec=KernelSpec(h=h), comp=comp, leaf_size=256, max_it=10)
            rep = trainer.prepare(xtr, ytr)
            model, _ = trainer.train(1.0)
            acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
            csv_rows.append((
                f"svm_table45/{name}/{preset_name}",
                rep.admm_s * 1e6,
                f"acc={acc:.4f};compress_s={rep.compression_s:.2f};"
                f"factor_s={rep.factorization_s:.2f};"
                f"mem_mb={rep.memory_mb:.1f};admm_s={rep.admm_s:.3f}",
            ))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
