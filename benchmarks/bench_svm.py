"""Paper Tables 4/5 analogue: HSS-ADMM at two approximation accuracies.

Columns mirror the paper: Compression [s] | Factorization [s] | Memory [MB] |
ADMM Time [s] (per C, MaxIt=10) | Accuracy [%].  Two presets mirror the
paper's STRUMPACK settings: "crude" (Table 4: rel_tol=1e-2, hss_max_rank=200,
64 neighbours — here rtol 1e-2, cap 32) and "accurate" (Table 5: rel_tol=
1e-4, rank 2000, 512 neighbours — here rtol 1e-4, cap 64).  The paper's
headline observations to check:
  (1) crude ≈ accurate in accuracy (approximation tolerance of SVMs),
  (2) ADMM time << compression time (the C-grid amortization),
  (3) memory scales O(N r), not O(N^2).

Every record includes the per-level HSS rank caps BEFORE and AFTER the
shrink-to-fit pass (pre == post when the tolerance saturates the cap — the
honest outcome on the high-dimensional table45 cases), the Σ n_k·r_k stored
rank sums, and the exact kernel-evaluation count of the build, so rank
adaptivity is observable in the perf trajectory.  The ``svm_adaptive/*``
cases isolate the tolerance-driven win on smooth (2-feature) kernels: same
holdout accuracy, several-fold smaller stored rank sum, faster
factorization.

The ``svm_tasks/*`` cases run the non-classification members of the box-QP
family (ε-SVR on noisy-sine, ν one-class on blobs-with-outliers) through
the SAME engine and factorization machinery; their "accuracy" fields hold
R² / balanced detection accuracy so the drift guard covers them too.

All cases drive repro.core.engine.HSSSVMEngine — the same orchestration the
launch/ and examples/ layers use — and every case additionally records a
machine-readable dict.  ``python benchmarks/bench_svm.py --json
BENCH_svm.json`` (or the ci/run_tests.sh --bench smoke tier) writes them:
build/factor/ADMM wall times, holdout accuracy, HSS memory, and the peak
per-device bytes of the resident HSS + factorization arrays (the number the
mesh-parallel build exists to keep flat as devices are added).
ci/check_bench.py compares a fresh run's accuracies against the committed
BENCH_svm.json and fails on silent drift.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.core.multiclass import MulticlassHSSSVMTrainer
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

PRESETS = {
    "crude": CompressionParams.crude(),        # rtol 1e-2, cap 32
    "accurate": CompressionParams.accurate(),  # rtol 1e-4, cap 64
}

DATASETS = [
    ("blobs", dict(n_features=8, sep=1.6), 8192, 2048, 1.0),
    ("circles", dict(n_features=4, gap=0.8), 8192, 2048, 0.5),
    ("susy_like", dict(), 16384, 4096, 3.0),
]

# Machine-readable records accumulated by every run_* function; written by
# write_json() / the --json CLI flag.
JSON_RECORDS: list[dict] = []


def peak_device_bytes(*pytrees) -> int:
    """Max over devices of resident bytes across the given array pytrees."""
    per_dev: dict = {}
    for tree in pytrees:
        for a in jax.tree.leaves(tree):
            shards = getattr(a, "addressable_shards", None)
            if shards is None:
                continue
            for s in shards:
                per_dev[s.device] = per_dev.get(s.device, 0) + s.data.nbytes
    return max(per_dev.values()) if per_dev else 0


def _record(case: str, **kw) -> dict:
    rec = dict(case=case, **kw)
    JSON_RECORDS.append(rec)
    return rec


def _rank_fields(rep) -> dict:
    """FitReport rank-adaptivity fields for a JSON record."""
    return dict(
        ranks_pre=list(rep.ranks_pre or ()),
        ranks_post=list(rep.ranks_post or ()),
        rank_sum_pre=rep.rank_sum_pre,
        rank_sum_post=rep.rank_sum_post,
        kernel_evals=rep.kernel_evals,
    )


def _steady_fit(make_engine, xtr, ytr, knob):
    """Two-pass timing: steady-state stage times + the cold (compile-
    inclusive) first-pass times, reported separately.

    The committed per-stage timings used to fold one-off XLA trace/compile
    time into whichever case ran a shape first (e.g. factorization_s 5.1-5.6s
    for svm_tasks at n=1024 vs 0.14-0.24s for identically-shaped
    classification cases).  Protocol:

      * pass 1 (fresh engine): prepare + train — pays every compile; its
        times are returned as the ``*_cold_s`` fields;
      * pass 2 (fresh engine): prepare hits the module-level jit caches, so
        ``compression_s`` / ``factorization_s`` are steady-state;
      * the ADMM run's jit cache is per-ENGINE (reset by ``prepare``), so
        pass 2 trains twice — both trains start cold from z0=0 (identical
        work) and the second one's increment is the steady-state ``admm_s``.

    Returns (engine, model, rep, cold) with rep's stage timings steady-state
    and ``cold`` a dict of the pass-1 times.
    """
    eng_cold = make_engine()
    rep_cold = eng_cold.prepare(xtr, ytr)
    eng_cold.train(knob)
    cold = dict(
        compression_cold_s=rep_cold.compression_s,
        factorization_cold_s=rep_cold.factorization_s,
        admm_cold_s=rep_cold.admm_s,
    )
    eng = make_engine()
    rep = eng.prepare(xtr, ytr)
    eng.train(knob)
    admm_first = rep.admm_s
    model, _ = eng.train(knob)
    rep.admm_s -= admm_first
    return eng, model, rep, cold


def run(csv_rows: list, scale: float = 1.0) -> None:
    for name, kw, n_train, n_test, h in DATASETS:
        n_train, n_test = int(n_train * scale), max(int(n_test * scale), 256)
        xtr, ytr, xte, yte = synthetic.train_test(name, n_train, n_test,
                                                  seed=0, **kw)
        for preset_name, comp in PRESETS.items():
            engine, model, rep, cold = _steady_fit(
                lambda: HSSSVMEngine(spec=KernelSpec(h=h), comp=comp,
                                     leaf_size=256, max_it=10),
                xtr, ytr, 1.0)
            acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
            _record(
                f"svm_table45/{name}/{preset_name}",
                n_train=n_train, accuracy=acc,
                compression_s=rep.compression_s,
                factorization_s=rep.factorization_s,
                admm_s=rep.admm_s, memory_mb=rep.memory_mb,
                peak_device_bytes=peak_device_bytes(engine.hss, engine.fac),
                **cold, **_rank_fields(rep),
            )
            csv_rows.append((
                f"svm_table45/{name}/{preset_name}",
                rep.admm_s * 1e6,
                f"acc={acc:.4f};compress_s={rep.compression_s:.2f};"
                f"factor_s={rep.factorization_s:.2f};"
                f"mem_mb={rep.memory_mb:.1f};admm_s={rep.admm_s:.3f}",
            ))


def run_sharded(csv_rows: list, scale: float = 1.0) -> None:
    """Mesh-parallel build over all local devices vs the local build.

    The quantity of interest is peak PER-DEVICE bytes of the resident HSS +
    factorization: the sharded build divides it by ~n_devices (leaf arrays
    dominate) while matching the local build's accuracy — the ISSUE's
    "training never hits a single device's memory ceiling" claim in
    measurable form.
    """
    n_train, n_test = int(16384 * scale), max(int(2048 * scale), 256)
    xtr, ytr, xte, yte = synthetic.train_test(
        "blobs", n_train, n_test, seed=0, n_features=8, sep=1.6)
    comp = PRESETS["crude"]
    cases = [("local", None)]
    if jax.device_count() > 1:
        cases.append(
            ("mesh", jax.make_mesh((jax.device_count(),), ("data",))))
    accs = {}
    for label, mesh in cases:
        engine, model, rep, cold = _steady_fit(
            lambda: HSSSVMEngine(spec=KernelSpec(h=1.0), comp=comp,
                                 leaf_size=256, max_it=10, mesh=mesh),
            xtr, ytr, 1.0)
        acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
        accs[label] = acc
        peak = peak_device_bytes(engine.hss, engine.fac)
        ndev = 1 if mesh is None else jax.device_count()
        _record(
            f"svm_sharded_build/{label}",
            n_train=n_train, n_devices=ndev, accuracy=acc,
            compression_s=rep.compression_s,
            factorization_s=rep.factorization_s,
            admm_s=rep.admm_s, memory_mb=rep.memory_mb,
            peak_device_bytes=peak,
            **cold, **_rank_fields(rep),
        )
        csv_rows.append((
            f"svm_sharded_build/{label}",
            rep.compression_s * 1e6,
            f"acc={acc:.4f};n_devices={ndev};"
            f"compress_s={rep.compression_s:.2f};"
            f"factor_s={rep.factorization_s:.2f};"
            f"peak_device_mb={peak / 1e6:.1f}",
        ))
    if len(accs) == 2:
        csv_rows.append((
            "svm_sharded_build/parity",
            0.0,
            f"acc_local={accs['local']:.4f};acc_mesh={accs['mesh']:.4f};"
            f"delta={abs(accs['local'] - accs['mesh']):.4f}",
        ))


ADAPTIVE_CASES = [
    # (dataset, kwargs, n_train, n_test, h): smooth 2-feature kernels where
    # the numerical rank sits far below the cap — the regime the paper's
    # rel_tol knob exists for.
    ("circles", dict(n_features=2, gap=0.8), 16384, 2048, 1.5),
    ("blobs", dict(n_features=2, sep=2.5), 16384, 2048, 2.0),
]


def run_adaptive(csv_rows: list, scale: float = 1.0) -> None:
    """Tolerance-driven adaptive rank vs the fixed-rank baseline.

    Same cap, same proxies, same data: the adaptive build must match the
    fixed build's holdout accuracy while the stored rank sum (Σ n_k·r_k) and
    the factorization time drop — rank is measured per node, not paid at the
    worst case.  Runs each path twice and reports steady-state times so the
    comparison is not a compile-time artifact.
    """
    for name, kw, n_train, n_test, h in ADAPTIVE_CASES:
        n_train_s = int(n_train * scale)
        n_test_s = max(int(n_test * scale), 256)
        xtr, ytr, xte, yte = synthetic.train_test(
            name, n_train_s, n_test_s, seed=0, **kw)
        results = {}
        for label, comp in [
            ("fixed", CompressionParams(rank=64, n_near=64, n_far=128)),
            ("adaptive", CompressionParams(rank=64, n_near=64, n_far=128,
                                           rtol=1e-4)),
        ]:
            engine, model, rep, cold = _steady_fit(
                lambda: HSSSVMEngine(spec=KernelSpec(h=h), comp=comp,
                                     leaf_size=256, max_it=10),
                xtr, ytr, 1.0)
            acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
            results[label] = (rep, acc)
            _record(
                f"svm_adaptive/{name}/{label}",
                n_train=n_train_s, accuracy=acc,
                compression_s=rep.compression_s,
                factorization_s=rep.factorization_s,
                admm_s=rep.admm_s, memory_mb=rep.memory_mb,
                peak_device_bytes=peak_device_bytes(engine.hss, engine.fac),
                **cold, **_rank_fields(rep),
            )
            csv_rows.append((
                f"svm_adaptive/{name}/{label}",
                rep.factorization_s * 1e6,
                f"acc={acc:.4f};rank_sum={rep.rank_sum_post};"
                f"ranks_post={list(rep.ranks_post or ())};"
                f"compress_s={rep.compression_s:.2f};"
                f"factor_s={rep.factorization_s:.2f};"
                f"mem_mb={rep.memory_mb:.2f}",
            ))
        (rep_f, acc_f), (rep_a, acc_a) = results["fixed"], results["adaptive"]
        csv_rows.append((
            f"svm_adaptive/{name}/summary",
            0.0,
            f"acc_delta={abs(acc_f - acc_a):.4f};"
            f"rank_sum={rep_f.rank_sum_post}->{rep_a.rank_sum_post};"
            f"factor_s={rep_f.factorization_s:.2f}->"
            f"{rep_a.factorization_s:.2f};"
            f"mem_mb={rep_f.memory_mb:.2f}->{rep_a.memory_mb:.2f}",
        ))


TASK_CASES = [
    # (task, dataset, kwargs, n_train, n_test, h, knob): the non-
    # classification members of the box-QP family on the same engine —
    # the "accuracy" field holds R² for SVR and balanced inlier/outlier
    # accuracy for one-class, so ci/check_bench.py guards their quality
    # drift exactly like the classification cases.
    ("svr", "noisy_sine", dict(noise=0.1), 8192, 2048, 1.0, 0.1),
    ("oneclass", "blobs_with_outliers", dict(outlier_frac=0.1),
     8192, 2048, 2.0, 0.1),
]


def run_tasks(csv_rows: list, scale: float = 1.0) -> None:
    """ε-SVR and one-class SVM through the SAME engine + crude preset.

    Records one case per task: quality (R² / balanced accuracy — both
    higher-is-better and scale-free, so the accuracy-drift guard applies),
    the task-specific raw metric, and the usual stage timings.
    """
    comp = PRESETS["crude"]
    for task, name, kw, n_train, n_test, h, knob in TASK_CASES:
        n_train_s = int(n_train * scale)
        n_test_s = max(int(n_test * scale), 256)
        xtr, ytr, xte, yte = synthetic.train_test(
            name, n_train_s, n_test_s, seed=0, **kw)
        engine, model, rep, cold = _steady_fit(
            lambda: HSSSVMEngine(
                spec=KernelSpec(h=h), comp=comp, leaf_size=256,
                max_it=30 if task == "oneclass" else 10, task=task,
                svr_c=2.0),
            xtr, None if task == "oneclass" else ytr, knob)
        if task == "svr":
            pred = np.asarray(model.predict(jnp.asarray(xte)))
            rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
            var = float(np.var(yte))
            quality = 1.0 - rmse ** 2 / max(var, 1e-12)       # R²
            extra = dict(rmse=rmse)
            detail = f"r2={quality:.4f};rmse={rmse:.4f}"
        else:
            from repro.core.tasks import oneclass_metrics

            m = oneclass_metrics(model.predict(jnp.asarray(xte)), yte)
            quality = m["balanced_accuracy"]
            extra = dict(precision=m["precision"], recall=m["recall"])
            detail = (f"balanced_acc={quality:.4f};prec={m['precision']:.4f};"
                      f"recall={m['recall']:.4f}")
        _record(
            f"svm_tasks/{task}/{name}",
            n_train=n_train_s, accuracy=float(quality), knob=knob,
            compression_s=rep.compression_s,
            factorization_s=rep.factorization_s,
            admm_s=rep.admm_s, memory_mb=rep.memory_mb,
            peak_device_bytes=peak_device_bytes(engine.hss, engine.fac),
            **cold, **extra, **_rank_fields(rep),
        )
        csv_rows.append((
            f"svm_tasks/{task}/{name}",
            rep.admm_s * 1e6,
            f"{detail};compress_s={rep.compression_s:.2f};"
            f"factor_s={rep.factorization_s:.2f};admm_s={rep.admm_s:.3f}",
        ))


def run_krr(csv_rows: list, scale: float = 1.0) -> None:
    """Kernel ridge regression: one multi-RHS solve, zero ADMM iterations.

    The ADMM-free member of the task family on the same engine + crude
    preset: ``admm_s`` here is pure solve time and ``iters_run`` is pinned
    at 0 in the record.  Accuracy holds holdout R² so the drift guard
    applies unchanged.
    """
    comp = PRESETS["crude"]
    n_train = int(8192 * scale)
    n_test = max(int(2048 * scale), 256)
    xtr, ytr, xte, yte = synthetic.train_test(
        "noisy_sine", n_train, n_test, seed=0, noise=0.1)
    engine, model, rep, cold = _steady_fit(
        lambda: HSSSVMEngine(spec=KernelSpec(h=1.0), comp=comp,
                             leaf_size=256, task="krr"),
        xtr, ytr, 0.5)
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
    quality = 1.0 - rmse ** 2 / max(float(np.var(yte)), 1e-12)       # R²
    iters = int(np.max(np.asarray(engine.report.iters_run)))
    _record(
        "svm_krr/noisy_sine",
        n_train=n_train, accuracy=float(quality), knob=0.5, rmse=rmse,
        admm_iters=iters,
        compression_s=rep.compression_s,
        factorization_s=rep.factorization_s,
        admm_s=rep.admm_s, memory_mb=rep.memory_mb,
        peak_device_bytes=peak_device_bytes(engine.hss, engine.fac),
        **cold, **_rank_fields(rep),
    )
    csv_rows.append((
        "svm_krr/noisy_sine",
        rep.admm_s * 1e6,
        f"r2={quality:.4f};rmse={rmse:.4f};admm_iters={iters};"
        f"compress_s={rep.compression_s:.2f};"
        f"factor_s={rep.factorization_s:.2f};solve_s={rep.admm_s:.3f}",
    ))


def _kmeans_purity(emb, labels, k, seed=0, iters=30):
    """Seeded Lloyd k-means on the embedding -> majority-class purity."""
    r = np.random.default_rng(seed)
    centers = emb[r.choice(emb.shape[0], size=k, replace=False)]
    for _ in range(iters):
        d = ((emb[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d.argmin(1)
        for c in range(k):
            if np.any(assign == c):
                centers[c] = emb[assign == c].mean(0)
    hit = 0
    for c in np.unique(assign):
        _, counts = np.unique(labels[assign == c], return_counts=True)
        hit += counts.max()
    return hit / len(labels)


def run_spectral(csv_rows: list, scale: float = 1.0) -> None:
    """Lanczos top-k spectral embedding of the HSS kernel operator.

    Concentric rings with a bandwidth below the ring gap: k-means on raw
    coordinates is chance (~0.52 purity), on the kernel-PCA embedding the
    rings separate (~0.8).  Accuracy holds the embedding purity so the
    drift guard covers eigen-solver quality, not just wall time.
    """
    comp = PRESETS["crude"]
    n_train = int(8192 * scale)
    k = 3
    x, y = synthetic.circles(n_train, n_features=2, gap=0.8, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=0.25), comp=comp,
                          leaf_size=256, task="krr")
    rep = engine.prepare(x, np.zeros(n_train, np.float32))
    engine.spectral_embed(k)                    # compile pass
    t0 = time.perf_counter()
    emb = engine.spectral_embed(k)
    lanczos_s = time.perf_counter() - t0
    p_raw = _kmeans_purity(x, y, 2)
    p_emb = _kmeans_purity(emb, y, 2)
    _record(
        "svm_spectral/circles",
        n_train=n_train, accuracy=float(p_emb), purity_raw=float(p_raw),
        k=k, lanczos_s=lanczos_s,
        compression_s=rep.compression_s, memory_mb=rep.memory_mb,
        peak_device_bytes=peak_device_bytes(engine.hss),
        **_rank_fields(rep),
    )
    csv_rows.append((
        "svm_spectral/circles",
        lanczos_s * 1e6,
        f"purity_emb={p_emb:.4f};purity_raw={p_raw:.4f};k={k};"
        f"compress_s={rep.compression_s:.2f};lanczos_s={lanczos_s:.3f}",
    ))


MULTICLASS_CASES = [
    # (n_classes, n_train, n_test, h, C)
    (4, 8192, 2048, 1.5, 1.0),
    (6, 8192, 2048, 1.5, 1.0),
]


def run_multiclass(csv_rows: list) -> None:
    """k-class batched solve (1 compression + 1 factorization + ONE batched
    ADMM) vs k sequential binary one-vs-rest trainings (k of each) — the
    shared-factorization economy the multiclass subsystem exists for.

    Each path runs twice and reports its second (steady-state) time: the
    first run at each shape pays XLA compilation for BOTH paths (whichever
    goes first eats all the shared compiles), which is not the quantity the
    factor-once claim is about.
    """
    comp = PRESETS["crude"]
    for k, n_train, n_test, h, c_value in MULTICLASS_CASES:
        xtr, ytr, xte, yte = synthetic.train_test(
            "multiclass_blobs", n_train, n_test, seed=0, n_classes=k, sep=3.0)
        classes = np.unique(ytr)

        def batched():
            t0 = time.perf_counter()
            trainer = MulticlassHSSSVMTrainer(
                spec=KernelSpec(h=h), comp=comp, leaf_size=256, max_it=10)
            model = trainer.fit(xtr, ytr, c_value=c_value)
            pred = np.asarray(model.predict(jnp.asarray(xte)))
            return time.perf_counter() - t0, float(np.mean(pred == yte))

        def sequential():
            t0 = time.perf_counter()
            scores = []
            for cls in classes:
                yb = np.where(ytr == cls, 1.0, -1.0).astype(np.float32)
                bt = HSSSVMTrainer(spec=KernelSpec(h=h), comp=comp,
                                   leaf_size=256, max_it=10)
                bm = bt.fit(xtr, yb, c_value=c_value)
                scores.append(
                    np.asarray(bm.decision_function(jnp.asarray(xte))))
            acc = float(np.mean(
                classes[np.argmax(np.stack(scores, 1), 1)] == yte))
            return time.perf_counter() - t0, acc

        t_cold, _ = batched()
        t_seq_cold, _ = sequential()
        t_batched, acc = batched()
        t_seq, acc_seq = sequential()

        speedup = t_seq / max(t_batched, 1e-9)
        _record(
            f"svm_multiclass/{k}way",
            n_train=n_train, batched_s=t_batched, sequential_s=t_seq,
            speedup=speedup, accuracy=acc, accuracy_sequential=acc_seq,
        )
        csv_rows.append((
            f"svm_multiclass/{k}way/batched_vs_sequential",
            t_batched * 1e6,
            f"batched_s={t_batched:.2f};sequential_s={t_seq:.2f};"
            f"speedup={speedup:.2f}x;acc_batched={acc:.4f};"
            f"acc_sequential={acc_seq:.4f};"
            f"batched_beats_sequential={t_batched < t_seq};"
            f"cold_batched_s={t_cold:.2f};cold_sequential_s={t_seq_cold:.2f}",
        ))


# N for the streamed out-of-core scaling curve: full tier covers the local
# paper-scale range 2^13..2^17; the smoke tier keeps the two smallest so the
# CI reference stays comparable (check_bench matches on n_train).  The
# resident build rides along while it is cheap enough to hold in one piece,
# giving the accuracy-parity and peak-bytes columns a baseline.
SCALING_NS_FULL = [2 ** k for k in range(13, 18)]
SCALING_NS_SMOKE = [2 ** 13, 2 ** 14]
SCALING_RESIDENT_MAX = 2 ** 14


def run_scaling(csv_rows: list, smoke: bool = False, slow: bool = False
                ) -> None:
    """Wall-clock + peak-bytes vs N for the streamed build (ISSUE 8 curve).

    The quantity of interest is ``peak_stream_bytes`` — the largest device
    footprint any single compression batch touched: it must stay FLAT as N
    grows (it depends on batch_leaves·m·d and the skeleton sizes, not on N),
    while the resident build's peak grows linearly.  Streamed cases are
    single-pass (the out-of-core walk is eager host-side orchestration, so
    there is no compile cache to warm), which is also how a one-shot
    paper-scale build would pay for it.

    ``slow`` adds the 10^6-point emulated tier: streamed compression with
    mesh assembly over all local (emulated) devices — the paper-scale
    configuration on CI hardware.
    """
    from repro.core.compression import StreamParams

    comp = PRESETS["crude"]
    ns = list(SCALING_NS_SMOKE if smoke else SCALING_NS_FULL)
    if slow:
        ns.append(10 ** 6)
    for n_train in ns:
        n_test = 2048
        xtr, ytr, xte, yte = synthetic.train_test(
            "blobs", n_train, n_test, seed=0, n_features=8, sep=1.6)
        mesh = None
        if n_train >= 10 ** 6 and jax.device_count() > 1:
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
        variants = [("streamed", StreamParams(batch_leaves=16))]
        if n_train <= SCALING_RESIDENT_MAX:
            variants.append(("resident", None))
        accs = {}
        for label, sp in variants:
            engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=comp,
                                  leaf_size=256, max_it=10, stream=sp,
                                  mesh=mesh)
            t0 = time.perf_counter()
            rep = engine.prepare(xtr, ytr)
            model, _ = engine.train(1.0)
            total_s = time.perf_counter() - t0
            acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
            accs[label] = acc
            peak_dev = peak_device_bytes(engine.hss, engine.fac)
            rec = dict(
                n_train=n_train, accuracy=acc, total_s=total_s,
                compression_s=rep.compression_s,
                factorization_s=rep.factorization_s,
                admm_s=rep.admm_s, memory_mb=rep.memory_mb,
                peak_device_bytes=peak_dev, **_rank_fields(rep),
            )
            if sp is not None:
                rec.update(peak_stream_bytes=rep.peak_stream_bytes,
                           stream_batches=rep.stream_batches)
            _record(f"svm_scaling/n{n_train}/{label}", **rec)
            detail = (f"acc={acc:.4f};total_s={total_s:.2f};"
                      f"compress_s={rep.compression_s:.2f};"
                      f"factor_s={rep.factorization_s:.2f};"
                      f"peak_device_mb={peak_dev / 1e6:.1f}")
            if sp is not None:
                detail += (f";peak_stream_mb={rep.peak_stream_bytes / 1e6:.1f}"
                           f";batches={rep.stream_batches}")
            csv_rows.append((f"svm_scaling/n{n_train}/{label}",
                             rep.compression_s * 1e6, detail))
        if len(accs) == 2:
            csv_rows.append((
                f"svm_scaling/n{n_train}/parity", 0.0,
                f"acc_streamed={accs['streamed']:.4f};"
                f"acc_resident={accs['resident']:.4f};"
                f"delta={abs(accs['streamed'] - accs['resident']):.4f}"))


def run_multilevel_warm(csv_rows: list) -> None:
    """AML-SVM-style multilevel warm start vs a cold solve (fixed size).

    Train on a stratified coarse subsample, prolong the duals to the full
    set by nearest-skeleton interpolation (scaled by n_c/n_f), and finish
    with early-stopping ADMM: ``iters_warm`` must come in below
    ``iters_cold`` at matched holdout accuracy.  The case runs at a FIXED
    size in both tiers (it measures iteration counts, not wall time), so
    the smoke-generated CI reference guards the full run too.
    """
    comp = PRESETS["crude"]
    n_train, n_test = 2048, 512
    xtr, ytr, xte, yte = synthetic.train_test(
        "blobs", n_train, n_test, seed=0, n_features=5, sep=3.0)

    def make():
        return HSSSVMEngine(spec=KernelSpec(h=2.0), comp=comp, leaf_size=128,
                            beta=100.0, tol=3e-2, max_it=400)

    eng = make()
    eng.prepare(xtr, ytr)
    m_cold, _ = eng.train(1.0)
    iters_cold = int(np.max(np.asarray(eng.report.iters_run)))
    acc_cold = float(jnp.mean(m_cold.predict(jnp.asarray(xte)) == yte))

    eng = make()
    eng.prepare(xtr, ytr)
    m_warm, info = eng.train_multilevel(1.0, coarse_frac=0.25,
                                        coarse_leaf_size=64, seed=0)
    iters_warm = int(np.max(np.asarray(info["iters_run"])))
    iters_coarse = int(np.max(np.asarray(info["coarse_iters_run"])))
    acc_warm = float(jnp.mean(m_warm.predict(jnp.asarray(xte)) == yte))

    _record(
        "svm_multilevel/blobs",
        n_train=n_train, accuracy=acc_warm, accuracy_cold=acc_cold,
        iters_cold=iters_cold, iters_warm=iters_warm,
        iters_coarse=iters_coarse, coarse_n=info["coarse_n"],
    )
    csv_rows.append((
        "svm_multilevel/blobs", float(iters_warm),
        f"iters_cold={iters_cold};iters_warm={iters_warm};"
        f"iters_coarse={iters_coarse};coarse_n={info['coarse_n']};"
        f"acc_cold={acc_cold:.4f};acc_warm={acc_warm:.4f};"
        f"warm_beats_cold={iters_warm < iters_cold}",
    ))


def run_adaptive_rho(csv_rows: list) -> None:
    """Residual-balancing adaptive ρ vs the fixed-β baseline (fixed size).

    Both start from a badly scaled β = 10⁴ (the grid-search failure mode
    the knob exists for).  The fixed run hits the iteration cap without
    converging; the adaptive run rebalances β downward between scan chunks
    and converges in a fraction of the budget at the same accuracy.  Like
    the multilevel case this is an iteration-count case at a fixed size.
    """
    from repro.core.admm import ADMMParams

    comp = PRESETS["crude"]
    n_train, n_test = 2048, 512
    xtr, ytr, xte, yte = synthetic.train_test(
        "blobs", n_train, n_test, seed=0, n_features=5, sep=3.0)
    results = {}
    for label, ap in (
        ("fixed", None),
        ("adaptive", ADMMParams(max_it=400, tol=3e-2, adapt_rho=True,
                                rho_every=5, rho_max_updates=8)),
    ):
        engine = HSSSVMEngine(spec=KernelSpec(h=2.0), comp=comp,
                              leaf_size=128, beta=1e4, tol=3e-2,
                              max_it=400, admm=ap)
        engine.prepare(xtr, ytr)
        model, _ = engine.train(1.0)
        iters = int(np.max(np.asarray(engine.report.iters_run)))
        acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
        results[label] = (iters, acc)
        _record(
            f"svm_adaptive_rho/{label}",
            n_train=n_train, accuracy=acc, iters_run=iters,
            rho_final=engine.report.rho_final,
            rho_rescales=engine.report.rho_rescales,
        )
        csv_rows.append((
            f"svm_adaptive_rho/{label}", float(iters),
            f"iters={iters};acc={acc:.4f};"
            f"rho_final={engine.report.rho_final};"
            f"rescales={engine.report.rho_rescales}",
        ))
    (i_f, a_f), (i_a, a_a) = results["fixed"], results["adaptive"]
    csv_rows.append((
        "svm_adaptive_rho/summary", 0.0,
        f"iters={i_f}->{i_a};acc_delta={abs(a_f - a_a):.4f};"
        f"adaptive_beats_fixed={i_a < i_f}",
    ))


def write_json(path: str) -> None:
    payload = dict(
        n_devices=jax.device_count(),
        backend=jax.default_backend(),
        results=JSON_RECORDS,
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# wrote {len(JSON_RECORDS)} records to {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_svm.json",
                    help="machine-readable output path")
    ap.add_argument("--smoke", action="store_true",
                    help="toy sizes — the ci/run_tests.sh --bench tier")
    ap.add_argument("--skip-multiclass", action="store_true")
    ap.add_argument("--slow", action="store_true",
                    help="add the 10^6-point streamed scaling case "
                         "(mesh-assembled over the local devices)")
    ap.add_argument("--full-scaling", action="store_true",
                    help="run the full 2^13..2^17 scaling curve even under "
                         "--smoke (how the committed reference is generated: "
                         "--smoke --full-scaling --slow)")
    args = ap.parse_args()

    scale = 0.125 if args.smoke else 1.0
    rows: list = []
    run(rows, scale=scale)
    run_adaptive(rows, scale=scale)
    run_tasks(rows, scale=scale)
    run_krr(rows, scale=scale)
    run_spectral(rows, scale=scale)
    run_sharded(rows, scale=scale)
    run_scaling(rows, smoke=args.smoke and not args.full_scaling,
                slow=args.slow)
    run_multilevel_warm(rows)
    run_adaptive_rho(rows)
    if not (args.smoke or args.skip_multiclass):
        run_multiclass(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
    write_json(args.json)
