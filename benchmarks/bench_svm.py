"""Paper Tables 4/5 analogue: HSS-ADMM at two approximation accuracies.

Columns mirror the paper: Compression [s] | Factorization [s] | Memory [MB] |
ADMM Time [s] (per C, MaxIt=10) | Accuracy [%].  Two presets mirror the
paper's STRUMPACK settings: "crude" (Table 4: hss_max_rank=200, 64
neighbours — here rank 32) and "accurate" (Table 5: rank 2000, 512
neighbours — here rank 64).  The paper's headline observations to check:
  (1) crude ≈ accurate in accuracy (approximation tolerance of SVMs),
  (2) ADMM time << compression time (the C-grid amortization),
  (3) memory scales O(N r), not O(N^2).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.multiclass import MulticlassHSSSVMTrainer
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

PRESETS = {
    "crude": CompressionParams(rank=32, n_near=32, n_far=32),
    "accurate": CompressionParams(rank=64, n_near=64, n_far=128),
}

DATASETS = [
    ("blobs", dict(n_features=8, sep=1.6), 8192, 2048, 1.0),
    ("circles", dict(n_features=4, gap=0.8), 8192, 2048, 0.5),
    ("susy_like", dict(), 16384, 4096, 3.0),
]


def run(csv_rows: list) -> None:
    for name, kw, n_train, n_test, h in DATASETS:
        xtr, ytr, xte, yte = synthetic.train_test(name, n_train, n_test,
                                                  seed=0, **kw)
        for preset_name, comp in PRESETS.items():
            trainer = HSSSVMTrainer(
                spec=KernelSpec(h=h), comp=comp, leaf_size=256, max_it=10)
            rep = trainer.prepare(xtr, ytr)
            model, _ = trainer.train(1.0)
            acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
            csv_rows.append((
                f"svm_table45/{name}/{preset_name}",
                rep.admm_s * 1e6,
                f"acc={acc:.4f};compress_s={rep.compression_s:.2f};"
                f"factor_s={rep.factorization_s:.2f};"
                f"mem_mb={rep.memory_mb:.1f};admm_s={rep.admm_s:.3f}",
            ))


MULTICLASS_CASES = [
    # (n_classes, n_train, n_test, h, C)
    (4, 8192, 2048, 1.5, 1.0),
    (6, 8192, 2048, 1.5, 1.0),
]


def run_multiclass(csv_rows: list) -> None:
    """k-class batched solve (1 compression + 1 factorization + ONE batched
    ADMM) vs k sequential binary one-vs-rest trainings (k of each) — the
    shared-factorization economy the multiclass subsystem exists for.

    Each path runs twice and reports its second (steady-state) time: the
    first run at each shape pays XLA compilation for BOTH paths (whichever
    goes first eats all the shared compiles), which is not the quantity the
    factor-once claim is about.
    """
    comp = PRESETS["crude"]
    for k, n_train, n_test, h, c_value in MULTICLASS_CASES:
        xtr, ytr, xte, yte = synthetic.train_test(
            "multiclass_blobs", n_train, n_test, seed=0, n_classes=k, sep=3.0)
        classes = np.unique(ytr)

        def batched():
            t0 = time.perf_counter()
            trainer = MulticlassHSSSVMTrainer(
                spec=KernelSpec(h=h), comp=comp, leaf_size=256, max_it=10)
            model = trainer.fit(xtr, ytr, c_value=c_value)
            pred = np.asarray(model.predict(jnp.asarray(xte)))
            return time.perf_counter() - t0, float(np.mean(pred == yte))

        def sequential():
            t0 = time.perf_counter()
            scores = []
            for cls in classes:
                yb = np.where(ytr == cls, 1.0, -1.0).astype(np.float32)
                bt = HSSSVMTrainer(spec=KernelSpec(h=h), comp=comp,
                                   leaf_size=256, max_it=10)
                bm = bt.fit(xtr, yb, c_value=c_value)
                scores.append(
                    np.asarray(bm.decision_function(jnp.asarray(xte))))
            acc = float(np.mean(
                classes[np.argmax(np.stack(scores, 1), 1)] == yte))
            return time.perf_counter() - t0, acc

        t_cold, _ = batched()
        t_seq_cold, _ = sequential()
        t_batched, acc = batched()
        t_seq, acc_seq = sequential()

        speedup = t_seq / max(t_batched, 1e-9)
        csv_rows.append((
            f"svm_multiclass/{k}way/batched_vs_sequential",
            t_batched * 1e6,
            f"batched_s={t_batched:.2f};sequential_s={t_seq:.2f};"
            f"speedup={speedup:.2f}x;acc_batched={acc:.4f};"
            f"acc_sequential={acc_seq:.4f};"
            f"batched_beats_sequential={t_batched < t_seq};"
            f"cold_batched_s={t_cold:.2f};cold_sequential_s={t_seq_cold:.2f}",
        ))


if __name__ == "__main__":
    rows = []
    run(rows)
    run_multiclass(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
