"""Paper Figure 2 + §3.3 amortization: (h, C) grid search.

Produces the accuracy heat-map data over h x C and measures the paper's
headline speed-up: total grid time with compress-once/factor-once reuse vs
the naive retrain-from-scratch-per-C estimate.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.svm import HSSSVMTrainer, grid_search
from repro.data import synthetic

HS = (0.3, 1.0, 3.0)
CS = (0.1, 1.0, 10.0)


def run(csv_rows: list) -> None:
    xtr, ytr, xte, yte = synthetic.train_test(
        "circles", 8192, 2048, seed=2, n_features=4, gap=0.6, noise=0.25)
    t0 = time.perf_counter()
    model, info = grid_search(
        xtr, ytr, xte, yte, hs=HS, cs=CS,
        trainer_kwargs=dict(
            comp=CompressionParams(rank=32, n_near=48, n_far=64),
            leaf_size=256, max_it=10))
    t_grid = time.perf_counter() - t0

    total_admm = 0.0
    total_setup = 0.0
    for (h, c), rec in info["results"].items():
        csv_rows.append((
            f"svm_fig2/h{h}/C{c}", rec["admm_s"] * 1e6,
            f"acc={rec['accuracy']:.4f}"))
    # setup cost appears once per h; admm cost once per (h, C)
    per_h = {}
    for (h, c), rec in info["results"].items():
        per_h[h] = rec["compression_s"] + rec["factorization_s"]
        total_admm += rec["admm_s"]
    total_setup = sum(per_h.values())
    naive = total_setup * len(CS) + total_admm   # recompress for every C
    csv_rows.append((
        "svm_grid_amortization", t_grid * 1e6,
        f"grid_s={t_grid:.2f};setup_s={total_setup:.2f};"
        f"admm_total_s={total_admm:.2f};naive_estimate_s={naive:.2f};"
        f"speedup={naive / max(t_grid, 1e-9):.2f};"
        f"best_h={info['best_h']};best_C={info['best_c']};"
        f"best_acc={info['best_accuracy']:.4f}"))


if __name__ == "__main__":
    rows = []
    run(rows)
    for r in rows:
        print(",".join(str(x) for x in r))
