"""Lanczos spectral embedding on the O(N r) HSS kernel operator.

``engine.top_eigenpairs(k)`` runs full-reorthogonalized Lanczos where every
operator application is the HSS telescoping matvec — top-k eigenpairs of
the N×N Gaussian kernel matrix without ever forming it.  The embedding
rows (eigenvectors scaled by √eigenvalue, kernel-PCA style) unfold the
concentric-rings dataset that k-means on raw coordinates cannot split:
with a bandwidth below the ring gap the leading eigenvectors are localized
per ring, so cluster purity jumps from chance to ~0.8.

  PYTHONPATH=src python examples/spectral_embedding.py
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def kmeans(x: np.ndarray, k: int, iters: int = 30, seed: int = 0):
    """Seeded Lloyd iterations — enough for a purity readout."""
    r = np.random.default_rng(seed)
    centers = x[r.choice(x.shape[0], size=k, replace=False)]
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None, :, :]) ** 2).sum(-1)
        assign = d.argmin(1)
        for c in range(k):
            if np.any(assign == c):
                centers[c] = x[assign == c].mean(0)
    return assign


def purity(assign: np.ndarray, labels: np.ndarray) -> float:
    """Fraction of points in their cluster's majority class."""
    hit = 0
    for c in np.unique(assign):
        _, counts = np.unique(labels[assign == c], return_counts=True)
        hit += counts.max()
    return hit / len(labels)


def rings_embedding(n: int = 4096, k: int = 3):
    x, y = synthetic.circles(n, n_features=2, gap=0.8, seed=0)
    # Only the compressed operator matters here: prepare under the krr task
    # (dummy targets) so no classification labels are needed.
    engine = HSSSVMEngine(spec=KernelSpec(h=0.25), comp=COMP, leaf_size=256,
                          task="krr")
    t0 = time.time()
    engine.prepare(x, np.zeros(n, np.float32))
    evals, _ = engine.top_eigenpairs(k)
    emb = engine.spectral_embed(k)
    t_build = time.time() - t0
    print(f"concentric rings, n={n}: top-{k} Lanczos eigenpairs of the "
          f"{n}x{n} kernel in {t_build:.1f}s (never formed densely)")
    print("  eigenvalues:", np.round(np.asarray(evals), 1).tolist())
    p_raw = purity(kmeans(x, 2), y)
    p_emb = purity(kmeans(emb, 2), y)
    print(f"  k-means purity: raw coords {p_raw:.3f} -> "
          f"spectral embedding {p_emb:.3f}")


if __name__ == "__main__":
    rings_embedding()
