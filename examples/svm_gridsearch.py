"""Hyper-parameter grid search with compression/factorization amortization.

The paper's headline operational win (§3.3): for fixed kernel width h the
HSS approximation + factorization are computed ONCE and reused for every C —
so the grid column costs one ADMM run (~ms-s) instead of a full retrain.

  PYTHONPATH=src python examples/svm_gridsearch.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core.compression import CompressionParams
from repro.core.svm import grid_search
from repro.data import synthetic


def main():
    xtr, ytr, xte, yte = synthetic.train_test(
        "susy_like", n_train=16384, n_test=4096, seed=0)

    t0 = time.time()
    model, info = grid_search(
        xtr, ytr, xte, yte,
        hs=[1.0, 3.0], cs=[0.1, 1.0, 10.0],
        trainer_kwargs=dict(
            comp=CompressionParams(rank=32, n_near=48, n_far=64),
            leaf_size=256, max_it=10),
    )
    dt = time.time() - t0

    print(f"{'h':>6} {'C':>6} {'accuracy':>9} {'admm_s':>8}")
    for (h, c), rec in sorted(info["results"].items()):
        print(f"{h:>6} {c:>6} {rec['accuracy']:>9.4f} {rec['admm_s']:>8.3f}")
    print(f"\nbest: h={info['best_h']} C={info['best_c']} "
          f"acc={info['best_accuracy']:.4f}")
    print(f"total grid time: {dt:.1f}s for {len(info['results'])} cells "
          f"({len(set(h for h, _ in info['results']))} compressions)")


if __name__ == "__main__":
    main()
