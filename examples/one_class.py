"""One-class (ν-)SVM novelty detection on the shared HSS factorization.

The one-class dual is the simplest member of the box-QP family — no labels,
no linear term, box [0, 1/(νn)] with eᵀα = 1 — and it reuses the exact
compression + factorization machinery of the classifier.  ν directly bounds
the fraction of training points flagged as outliers; this demo sweeps ν on
one factorization and reports holdout precision/recall against the
generator's ground truth.

  PYTHONPATH=src python examples/one_class.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.core.tasks import grid_search_oneclass, oneclass_metrics
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def nu_sweep():
    xtr, _ytr = synthetic.blobs_with_outliers(8192, n_features=4,
                                              outlier_frac=0.1, seed=0)
    xte, yte = synthetic.blobs_with_outliers(2048, n_features=4,
                                             outlier_frac=0.1, seed=1)
    engine = HSSSVMEngine(spec=KernelSpec(h=2.0), comp=COMP, leaf_size=256,
                          max_it=30, task="oneclass")
    t0 = time.time()
    rep = engine.prepare(xtr)            # unsupervised: no labels
    print(f"blobs+outliers, n=8192 (10% planted outliers): compressed "
          f"{rep.compression_s:.1f}s + factorized {rep.factorization_s:.2f}s "
          f"ONCE for the whole ν sweep")
    warm = None
    print(f"{'nu':>6} {'train outlier frac':>19} {'precision':>10} "
          f"{'recall':>7}")
    for nu in (0.02, 0.05, 0.1, 0.2):
        model, warm = engine.train(nu, warm=warm)
        pred_tr = np.asarray(model.predict(jnp.asarray(xtr)))
        m = oneclass_metrics(model.predict(jnp.asarray(xte)), yte)
        print(f"{nu:>6} {float(np.mean(pred_tr < 0)):>19.3f} "
              f"{m['precision']:>10.3f} {m['recall']:>7.3f}")
    print(f"[{time.time() - t0:.1f}s total; ν upper-bounds the training "
          f"outlier fraction — the Schölkopf ν-property]\n")


def h_nu_grid():
    xtr, _ = synthetic.blobs_with_outliers(4096, n_features=4,
                                           outlier_frac=0.1, seed=0)
    xval, yval = synthetic.blobs_with_outliers(1024, n_features=4,
                                               outlier_frac=0.1, seed=2)
    t0 = time.time()
    model, info = grid_search_oneclass(
        xtr, xval, yval, hs=[1.0, 2.0], nus=[0.05, 0.1, 0.2],
        trainer_kwargs=dict(comp=COMP, leaf_size=128, max_it=30))
    print("(h, ν) grid (scores are balanced inlier/outlier accuracy):")
    print(f"{'h':>6} {'nu':>6} {'balanced acc':>13}")
    for (h, nu), rec in sorted(info["results"].items()):
        print(f"{h:>6} {nu:>6} {rec['accuracy']:>13.4f}")
    print(f"best: h={info['best_h']} nu={info['best_c']} "
          f"balanced_acc={info['best_accuracy']:.4f}  "
          f"[{time.time() - t0:.1f}s, 2 compressions for "
          f"{len(info['results'])} cells]")


if __name__ == "__main__":
    nu_sweep()
    h_nu_grid()
