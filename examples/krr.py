"""Kernel ridge regression and GP posterior mean: ADMM-free solves.

KRR and the GP posterior mean are ONE multi-RHS triangular solve on the
same K̃ + λI factorization the SVM tasks use — the ridge λ rides the β
shift slot, so a λ sweep is a cached refactorization + solve per value and
zero ADMM iterations ever run.  This demo sweeps λ on one compression,
then scores a (h, λ) grid two ways: holdout RMSE (KRR) and the Hutchinson
log marginal likelihood (GP — no validation split needed).

  PYTHONPATH=src python examples/krr.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.core.krr import grid_search_gp, grid_search_krr
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def lambda_sweep():
    xtr, ytr, xte, yte = synthetic.train_test(
        "noisy_sine", n_train=8192, n_test=2048, seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=256,
                          task="krr")
    t0 = time.time()
    rep = engine.prepare(xtr, ytr)
    print(f"noisy sine, n=8192 (noise std 0.1): compressed "
          f"{rep.compression_s:.1f}s ONCE for the whole λ sweep")
    print(f"{'lam':>6} {'rmse':>8} {'admm iters':>11}")
    for lam in (0.1, 0.5, 2.0, 8.0, 32.0):
        model, _ = engine.train(lam)
        pred = np.asarray(model.predict(jnp.asarray(xte)))
        rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
        iters = int(max(engine.report.iters_run))
        print(f"{lam:>6} {rmse:>8.4f} {iters:>11}")
    print(f"[{time.time() - t0:.1f}s total; the noise floor is 0.1 — small "
          f"λ already sits on it, large λ over-smooths]\n")


def h_lambda_grid():
    xtr, ytr, xte, yte = synthetic.train_test(
        "noisy_sine", n_train=4096, n_test=1024, seed=0, noise=0.1)
    t0 = time.time()
    model, info = grid_search_krr(
        xtr, ytr, xte, yte, hs=[0.5, 1.0], lams=[0.3, 1.0, 4.0],
        trainer_kwargs=dict(comp=COMP, leaf_size=128))
    print("KRR (h, λ) grid (scores are negated validation RMSE):")
    print(f"{'h':>6} {'lam':>6} {'rmse':>8}")
    for (h, lam), rec in sorted(info["results"].items()):
        print(f"{h:>6} {lam:>6} {-rec['accuracy']:>8.4f}")
    print(f"best: h={info['best_h']} λ={info['best_c']} "
          f"rmse={-info['best_accuracy']:.4f}  "
          f"[{time.time() - t0:.1f}s, 2 compressions for "
          f"{len(info['results'])} cells]\n")


def gp_evidence_grid():
    xtr, ytr, _, _ = synthetic.train_test(
        "noisy_sine", n_train=2048, n_test=256, seed=0, noise=0.1)
    t0 = time.time()
    model, info = grid_search_gp(
        xtr, ytr, hs=[0.5, 1.0], lams=[0.01, 0.1, 1.0],
        trainer_kwargs=dict(comp=COMP, leaf_size=128))
    print("GP (h, λ) grid scored by log marginal likelihood — no holdout:")
    print(f"{'h':>6} {'lam':>6} {'log p(y)':>12}")
    for (h, lam), rec in sorted(info["results"].items()):
        print(f"{h:>6} {lam:>6} {rec['log_marginal']:>12.1f}")
    print(f"best: h={info['best_h']} λ={info['best_lam']} "
          f"log p(y)={info['best_log_marginal']:.1f}  "
          f"[{time.time() - t0:.1f}s; the evidence picks λ near the true "
          f"noise variance 0.01 without ever seeing a validation split]")


if __name__ == "__main__":
    lambda_sweep()
    h_lambda_grid()
    gp_evidence_grid()
