"""ε-SVR on the shared HSS factorization (factor once, sweep ε-many).

The ε-SVR difference-form dual rides the SAME K̃ + βI factorization the
classifier uses — only the O(d) linear term and the z-step's soft-threshold
change with (y, ε).  This demo trains on the noisy-sine generator, sweeps
the ε tube on one compression + factorization, and runs the (h, ε) grid.

  PYTHONPATH=src python examples/svr.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.core.tasks import grid_search_svr
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def epsilon_sweep():
    xtr, ytr, xte, yte = synthetic.train_test(
        "noisy_sine", n_train=8192, n_test=2048, seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=256,
                          max_it=10, task="svr", svr_c=2.0)
    t0 = time.time()
    rep = engine.prepare(xtr, ytr)
    print(f"noisy sine, n=8192 (noise std 0.1): compressed "
          f"{rep.compression_s:.1f}s + factorized {rep.factorization_s:.2f}s "
          f"ONCE for the whole ε sweep")
    warm = None
    print(f"{'eps':>6} {'rmse':>8} {'SV frac':>8}")
    for eps in (0.02, 0.05, 0.1, 0.2, 0.4):
        model, warm = engine.train(eps, warm=warm)
        pred = np.asarray(model.predict(jnp.asarray(xte)))
        rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
        sv_frac = float(np.mean(np.abs(np.asarray(model.z_y)) > 1e-5))
        print(f"{eps:>6} {rmse:>8.4f} {sv_frac:>8.3f}")
    print(f"[{time.time() - t0:.1f}s total; a wider ε tube means fewer "
          f"support vectors until the fit degrades]\n")


def h_eps_grid():
    xtr, ytr, xte, yte = synthetic.train_test(
        "noisy_step", n_train=4096, n_test=1024, seed=0, noise=0.05)
    t0 = time.time()
    model, info = grid_search_svr(
        xtr, ytr, xte, yte, hs=[0.2, 0.5], epsilons=[0.02, 0.1, 0.3],
        c_value=2.0, trainer_kwargs=dict(comp=COMP, leaf_size=128, max_it=10))
    print("noisy step (h, ε) grid (scores are negated validation RMSE):")
    print(f"{'h':>6} {'eps':>6} {'rmse':>8}")
    for (h, e), rec in sorted(info["results"].items()):
        print(f"{h:>6} {e:>6} {-rec['accuracy']:>8.4f}")
    print(f"best: h={info['best_h']} eps={info['best_c']} "
          f"rmse={-info['best_accuracy']:.4f}  "
          f"[{time.time() - t0:.1f}s, 2 compressions for "
          f"{len(info['results'])} cells]")


if __name__ == "__main__":
    epsilon_sweep()
    h_eps_grid()
