"""End-to-end LM training driver over the assigned-architecture substrate.

Wraps repro.launch.train: pick any --arch from the pool; "tiny"/"small"
presets run on CPU, "full" is the production-mesh configuration (the one
the dry-run lowers).  Checkpoints + resume + failure drill included:

  PYTHONPATH=src python examples/lm_train.py --arch gemma2-9b --steps 300
  PYTHONPATH=src python examples/lm_train.py --arch mamba2-780m --steps 100 \
      --fail-at 50           # exercises checkpoint-restart mid-run
"""
import sys

sys.path.insert(0, "src")

if __name__ == "__main__":
    from repro.launch import train

    if "--preset" not in " ".join(sys.argv):
        sys.argv += ["--preset", "small"]
    if "--ckpt-dir" not in " ".join(sys.argv):
        sys.argv += ["--ckpt-dir", "/tmp/repro_lm_train"]
    train.main()
