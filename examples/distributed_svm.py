"""Distributed HSS-ADMM: the paper's solver sharded across devices.

Runs on 8 emulated host devices (the same code lowers on the 256/512-chip
production meshes — see launch/dryrun.py --arch svm-hss-admm).  Leaf-level
factorization blocks are device-local; upper levels auto-replicate; ADMM
vector work is data-parallel with psum reductions.

  PYTHONPATH=src python examples/distributed_svm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core.distributed import fac_shardings, vec_sharding
from repro.core.kernelfn import KernelSpec
from repro.data import synthetic


def main():
    print(f"devices: {jax.device_count()}")
    n = 16384
    x, y = synthetic.blobs(n, n_features=8, sep=1.8, seed=0)
    t = tree_mod.build_tree(x, leaf_size=256)
    xp = jnp.asarray(x[t.perm])
    yp = jnp.asarray(y[t.perm])

    hss = compression.compress(
        xp, t, KernelSpec(h=1.0),
        compression.CompressionParams(rank=32, n_near=48, n_far=64))
    fac = factorization.factorize(hss, beta=100.0)

    mesh = jax.make_mesh((8,), ("data",))
    fac_d = jax.device_put(fac, fac_shardings(jax.eval_shape(lambda: fac),
                                              mesh))
    y_d = jax.device_put(yp, vec_sharding(n, mesh))

    @jax.jit
    def train(fac_, y_, c):
        state, trace = admm_mod.admm_svm(fac_.solve, y_, c, 100.0, max_it=10)
        return state.z, trace.primal_res

    with mesh:
        z, res = train(fac_d, y_d, 1.0)
    z = jax.block_until_ready(z)
    print(f"z sharding: {z.sharding}")
    print(f"final primal residual: {float(res[-1]):.2e}")
    print(f"support vectors: {int(jnp.sum(z > 1e-6))} / {n}")


if __name__ == "__main__":
    main()
