"""Mesh-parallel HSS-ADMM: build + factor + train sharded end-to-end.

Runs on 8 emulated host devices (the same code lowers on the 256/512-chip
production meshes — see launch/dryrun.py --arch svm-hss-admm).  Unlike the
pre-engine flow (single-device compress/factorize, then device_put), EVERY
stage here is mesh-parallel from the start: leaf kernel blocks, ID-QR bases,
E/G factors, ADMM iterates, bias extraction and prediction scoring all live
sharded over the node/sample axis — no device ever holds an unsharded
O(N·m) array.

  PYTHONPATH=src python examples/distributed_svm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.data import synthetic


def main():
    print(f"devices: {jax.device_count()}")
    n = 16384
    xtr, ytr, xte, yte = synthetic.train_test(
        "blobs", n, 2048, seed=0, n_features=8, sep=1.8)

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    engine = HSSSVMEngine(
        spec=KernelSpec(h=1.0),
        comp=CompressionParams(rank=32, n_near=48, n_far=64),
        leaf_size=256, beta=100.0, max_it=10, mesh=mesh)

    rep = engine.prepare(xtr, ytr)     # sharded compress + factorize, ONCE
    print(f"compress {rep.compression_s:.1f}s / factorize "
          f"{rep.factorization_s:.2f}s / HSS memory {rep.memory_mb:.1f} MB "
          f"across {jax.device_count()} devices")
    shard = engine.fac.e_leaf.addressable_shards[0].data.shape
    print(f"e_leaf: global {tuple(engine.fac.e_leaf.shape)}, "
          f"per-device {tuple(shard)}")

    # compress once, factor once, sweep C warm-started — the paper's
    # amortization claim, with every stage mesh-parallel via the engine
    c_grid = [0.1, 1.0, 10.0]
    for c, model in zip(c_grid, engine.train_grid(c_grid)):
        acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
        sv = int(jnp.sum(jnp.abs(model.z_y) > 1e-6))
        print(f"C={c:>5}: holdout acc {acc:.4f}, "
              f"support vectors {sv} / {n}")
    print(f"z_y sharding: {model.z_y.sharding}")


if __name__ == "__main__":
    main()
