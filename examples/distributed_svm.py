"""Distributed HSS-ADMM: the paper's solver sharded across devices.

Runs on 8 emulated host devices (the same code lowers on the 256/512-chip
production meshes — see launch/dryrun.py --arch svm-hss-admm).  Leaf-level
factorization blocks are device-local; upper levels auto-replicate; ADMM
vector work is data-parallel with psum reductions.

  PYTHONPATH=src python examples/distributed_svm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression, factorization, tree as tree_mod
from repro.core.distributed import admm_train_distributed
from repro.core.kernelfn import KernelSpec
from repro.data import synthetic


def main():
    print(f"devices: {jax.device_count()}")
    n = 16384
    x, y = synthetic.blobs(n, n_features=8, sep=1.8, seed=0)
    t = tree_mod.build_tree(x, leaf_size=256)
    xp = jnp.asarray(x[t.perm])
    yp = jnp.asarray(y[t.perm])

    hss = compression.compress(
        xp, t, KernelSpec(h=1.0),
        compression.CompressionParams(rank=32, n_near=48, n_far=64))
    fac = factorization.factorize(hss, beta=100.0)

    # compress once, factor once, sweep C data-parallel with warm starts —
    # the paper's amortization claim, across devices via repro.dist
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    c_grid = [0.1, 1.0, 10.0]
    results = admm_train_distributed(fac, yp, c_grid, mesh, max_it=10)

    for c, (z, res) in zip(c_grid, results):
        z = jax.block_until_ready(z)
        print(f"C={c:>5}: final primal residual {float(res[-1]):.2e}, "
              f"support vectors {int(jnp.sum(z > 1e-6))} / {n}")
    print(f"z sharding: {results[-1][0].sharding}")


if __name__ == "__main__":
    main()
