"""Multiclass SVM on ONE shared HSS factorization (factor once, solve k-many).

K̃ + βI never sees the labels, so a k-class one-vs-rest reduction reuses a
single compression + factorization for every class subproblem, and every
ADMM iteration solves all k class systems as ONE multi-RHS telescoping
sweep.  This demo trains 5-class blobs and 3-class spirals, compares against
k sequential binary trainings, and sweeps the (C × class) product grid.

  PYTHONPATH=src python examples/multiclass_svm.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.multiclass import MulticlassHSSSVMTrainer, grid_search_multiclass
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def batched_vs_sequential():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", n_train=8192, n_test=2048, seed=0,
        n_classes=5, sep=3.0)
    classes = np.unique(ytr)
    k = len(classes)

    def run_batched():
        t0 = time.time()
        trainer = MulticlassHSSSVMTrainer(
            spec=KernelSpec(h=1.5), comp=COMP, leaf_size=256, max_it=10)
        model = trainer.fit(xtr, ytr, c_value=1.0)
        acc = float(jnp.mean(model.predict(jnp.asarray(xte))
                             == jnp.asarray(yte)))
        return time.time() - t0, acc, trainer.report

    def run_sequential():
        t0 = time.time()
        preds = []
        for c in classes:
            yb = np.where(ytr == c, 1.0, -1.0).astype(np.float32)
            bt = HSSSVMTrainer(spec=KernelSpec(h=1.5), comp=COMP,
                               leaf_size=256, max_it=10)
            bm = bt.fit(xtr, yb, c_value=1.0)
            preds.append(np.asarray(bm.decision_function(jnp.asarray(xte))))
        acc = float(np.mean(classes[np.argmax(np.stack(preds, 1), 1)] == yte))
        return time.time() - t0, acc

    # First runs pay one-off XLA compilation (shared between the two paths);
    # the factor-once economy is about the steady-state second runs.
    run_batched()
    run_sequential()
    t_batched, acc, rep = run_batched()
    t_seq, acc_seq = run_sequential()

    print(f"{k}-class blobs, n=8192 (steady state, post-compile):")
    print(f"  batched   : {t_batched:6.1f}s  acc={acc:.4f}  "
          f"(1 compression {rep.compression_s:.1f}s + 1 factorization "
          f"{rep.factorization_s:.2f}s + batched ADMM {rep.admm_s:.2f}s)")
    print(f"  sequential: {t_seq:6.1f}s  acc={acc_seq:.4f}  "
          f"({k} compressions + {k} factorizations + {k} ADMM runs)")
    print(f"  speedup   : {t_seq / max(t_batched, 1e-9):.2f}x\n")


def spirals_grid():
    xtr, ytr, xte, yte = synthetic.train_test(
        "spirals", n_train=4096, n_test=1024, seed=0, n_classes=3)
    t0 = time.time()
    model, info = grid_search_multiclass(
        xtr, ytr, xte, yte, hs=[0.1, 0.3], cs=[0.5, 2.0, 8.0],
        trainer_kwargs=dict(comp=COMP, leaf_size=128, max_it=10))
    dt = time.time() - t0
    print("3-class spirals (C x class) grid:")
    print(f"{'h':>6} {'C':>6} {'accuracy':>9}")
    for (h, c), rec in sorted(info["results"].items()):
        print(f"{h:>6} {c:>6} {rec['accuracy']:>9.4f}")
    print(f"best: h={info['best_h']} C={info['best_c']} "
          f"acc={info['best_accuracy']:.4f}  "
          f"[{dt:.1f}s total, 2 compressions for "
          f"{len(info['results'])} grid cells x 3 classes]")


if __name__ == "__main__":
    batched_vs_sequential()
    spirals_grid()
