"""Batched serving demo: prefill + decode with KV/SSM caches.

  PYTHONPATH=src python examples/serve_demo.py --arch zamba2-1.2b
"""
import sys

sys.path.insert(0, "src")

if __name__ == "__main__":
    from repro.launch import serve

    if "--arch" not in " ".join(sys.argv):
        sys.argv += ["--arch", "gemma2-9b"]
    serve.main()
