"""Quickstart: train a nonlinear SVM with HSS-ADMM (the paper's pipeline).

  PYTHONPATH=src python examples/quickstart.py

Steps (= paper Algorithm 3): build cluster tree -> HSS-compress the Gaussian
kernel (partially matrix-free) -> ULV-equivalent factorization -> 10
closed-form ADMM iterations -> bias via one HSS matvec -> predict.
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic


def main():
    xtr, ytr, xte, yte = synthetic.train_test(
        "circles", n_train=8192, n_test=2048, seed=0, n_features=4, gap=0.8)

    trainer = HSSSVMTrainer(
        spec=KernelSpec(name="gaussian", h=1.0),
        comp=CompressionParams(rank=32, n_near=48, n_far=64),
        leaf_size=256,
        max_it=10,                      # the paper fixes MaxIt = 10
    )
    report = trainer.prepare(xtr, ytr)   # compress once + factorize once
    print(f"compression:   {report.compression_s:.2f}s")
    print(f"factorization: {report.factorization_s:.2f}s")
    print(f"HSS memory:    {report.memory_mb:.1f} MB "
          f"(dense would be {8192 * 8192 * 4 / 1e6:.0f} MB)")

    model, _ = trainer.train(c_value=1.0)   # ADMM only — reusable per C
    print(f"ADMM (10 iters, one C): {trainer.report.admm_s:.3f}s")

    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    print(f"test accuracy: {acc:.4f}")


if __name__ == "__main__":
    main()
