#!/usr/bin/env bash
# CI test runner.
#
# Default: the FAST tier — everything except tests marked `slow` (the
# 8-emulated-device subprocess tests, see pytest.ini).  Pass --all for the
# full suite (what the tier-1 verify `python -m pytest -x -q` runs).
# Pass --bench for the benchmark smoke tier instead of pytest: runs the
# JSON-emitting SVM benchmark (benchmarks/bench_svm.py --smoke) at toy
# size, including the sharded-build case on the 8 emulated devices, and
# leaves BENCH_svm.json in the repo root for the perf trajectory.
# Always prints the 10 slowest tests so tier creep stays visible.
#
# The distribution-layer tests (tests/test_dist.py, tests/test_fault.py,
# tests/test_pipeline.py, ...) spawn subprocesses that set
# --xla_force_host_platform_device_count=8 themselves; exporting it here
# also covers any in-process multi-device path and keeps the dist tests
# green on single-accelerator CI runners.
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier_args=(-m "not slow")
pass_args=()
bench=0
for arg in "$@"; do
  if [[ "$arg" == "--all" ]]; then
    tier_args=()
  elif [[ "$arg" == "--bench" ]]; then
    bench=1
  else
    pass_args+=("$arg")
  fi
done

if [[ "$bench" == 1 ]]; then
  exec python benchmarks/bench_svm.py --smoke --json BENCH_svm.json \
    ${pass_args[@]+"${pass_args[@]}"}
fi

# ${arr[@]+...} idiom: empty-array expansion is an unbound-variable error
# under `set -u` on bash < 4.4 (stock macOS bash 3.2)
python -m pytest -x -q --durations=10 \
  ${tier_args[@]+"${tier_args[@]}"} ${pass_args[@]+"${pass_args[@]}"}
