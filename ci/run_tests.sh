#!/usr/bin/env bash
# CI test runner.
#
# Default: the FAST tier — everything except tests marked `slow` (the
# 8-emulated-device subprocess tests, see pytest.ini).  Pass --all for the
# full suite (what the tier-1 verify `python -m pytest -x -q` runs).
# Pass --lint for the static-analysis tier instead of pytest: runs
#   python -m repro.analysis --check
# (repro.analysis) — the AST lint rules over src/repro plus the
# trace-level jaxpr checks (f32-accumulation, host callbacks, the
# one-compile-per-C-sweep guard, and the mesh-placement check, which
# uses the 8 emulated devices exported below).
# Pass --bench for the benchmark smoke tier instead of pytest: runs the
# JSON-emitting SVM benchmark (benchmarks/bench_svm.py --smoke) at toy
# size, including the sharded-build case on the 8 emulated devices, and
# leaves BENCH_svm.json in the repo root for the perf trajectory.  The
# fresh run is then compared against the committed BENCH_svm.json
# (ci/check_bench.py): a per-case accuracy drop beyond the tolerance
# fails the tier, so silent accuracy drift cannot ship.  The serving
# bench (benchmarks/bench_serve.py --smoke -> BENCH_serve.json) then runs
# under the same guard at --tol 0.005: its accuracy field is the
# served-vs-trained prediction agreement (1.0 on the bit-identical f32
# path), so serving-tier drift hard-fails while p50/p99 latency
# regressions warn.
# Always prints the 10 slowest tests so tier creep stays visible.
#
# The distribution-layer tests (tests/test_dist.py, tests/test_fault.py,
# tests/test_pipeline.py, ...) spawn subprocesses that set
# --xla_force_host_platform_device_count=8 themselves; exporting it here
# also covers any in-process multi-device path and keeps the dist tests
# green on single-accelerator CI runners.
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier_args=(-m "not slow")
pass_args=()
bench=0
lint=0
for arg in "$@"; do
  if [[ "$arg" == "--all" ]]; then
    tier_args=()
  elif [[ "$arg" == "--bench" ]]; then
    bench=1
  elif [[ "$arg" == "--lint" ]]; then
    lint=1
  else
    pass_args+=("$arg")
  fi
done

if [[ "$lint" == 1 ]]; then
  exec python -m repro.analysis --check ${pass_args[@]+"${pass_args[@]}"}
fi

if [[ "$bench" == 1 ]]; then
  ref="$(mktemp)"
  trap 'rm -f "$ref"' EXIT   # cleanup even when the guard fails under set -e
  have_ref=0
  # Committed reference from git — the working-tree file is about to be
  # overwritten by the fresh run.
  if git show HEAD:BENCH_svm.json > "$ref" 2>/dev/null; then have_ref=1; fi
  python benchmarks/bench_svm.py --smoke --json BENCH_svm.json \
    ${pass_args[@]+"${pass_args[@]}"}
  if [[ "$have_ref" == 1 ]]; then
    python ci/check_bench.py "$ref" BENCH_svm.json
  else
    echo "check_bench: no committed BENCH_svm.json at HEAD — guard skipped"
  fi
  have_serve_ref=0
  if git show HEAD:BENCH_serve.json > "$ref" 2>/dev/null; then
    have_serve_ref=1
  fi
  python benchmarks/bench_serve.py --smoke --json BENCH_serve.json
  if [[ "$have_serve_ref" == 1 ]]; then
    python ci/check_bench.py "$ref" BENCH_serve.json --tol 0.005
  else
    echo "check_bench: no committed BENCH_serve.json at HEAD — guard skipped"
  fi
  # ADMM-free task smoke: KRR end-to-end through the serving tier (train is
  # ONE multi-RHS solve; the request loop exercises the raw-value decode).
  python -m repro.launch.serve --task krr --svm-train 2048 --batch 64 \
    --requests 5
  exit 0
fi

# ${arr[@]+...} idiom: empty-array expansion is an unbound-variable error
# under `set -u` on bash < 4.4 (stock macOS bash 3.2)
python -m pytest -x -q --durations=10 \
  ${tier_args[@]+"${tier_args[@]}"} ${pass_args[@]+"${pass_args[@]}"}
