#!/usr/bin/env bash
# Tier-1 CI: full test suite with 8 emulated host devices.
#
# The distribution-layer tests (tests/test_dist.py, tests/test_fault.py,
# tests/test_pipeline.py, ...) spawn subprocesses that set
# --xla_force_host_platform_device_count=8 themselves; exporting it here
# also covers any in-process multi-device path and keeps the dist tests
# green on single-accelerator CI runners.
set -euo pipefail
cd "$(dirname "$0")/.."

export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
