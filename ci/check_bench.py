#!/usr/bin/env python
"""Benchmark accuracy regression guard for the ci/run_tests.sh --bench tier.

Compares a fresh BENCH_svm.json against the committed reference and FAILS
when any case's holdout accuracy drops by more than the tolerance — silent
accuracy drift (a looser compression, a broken mask, a bad warm start) then
breaks the bench tier instead of quietly shipping in the perf trajectory.

Only cases present in BOTH files are compared, so adding or retiring bench
cases never trips the guard; accuracy improvements pass.  Rank/memory
fields are machine noise across hosts and are deliberately not guarded.
Per-case stage wall times (compression_s / factorization_s / admm_s) get a
WARN-ONLY check: a stage slower than --time-factor (default 1.5×) vs the
committed reference is printed but never fails the run — cross-host timing
noise makes a hard gate dishonest, but a silent compression regression
should at least be visible in the CI log.  The recorded stage times are
STEADY-STATE (the bench warms up each shape before timing and reports the
one-off compile cost separately as ``*_cold_s``), so the factor/floor can
be much tighter than when compile time was folded in.

The serving-tier records (BENCH_serve.json, ``serve/*`` cases) ride the
same machinery: their ``accuracy`` field holds the served-vs-trained
prediction agreement, so serving drift hard-fails exactly like training
accuracy drift (run with ``--tol 0.005`` — the batched f32 path is
bit-identical, so any disagreement is a real decode/parity bug), while
p50/p99 request latencies get the same warn-only >factor treatment as the
stage wall times (with their own millisecond floor, --latency-floor-ms).

Unlike wall times, ``peak_stream_bytes`` on the streamed out-of-core cases
gets a HARD gate: the whole point of the streamed build is a device
footprint bounded by the batch size, so a fresh run whose peak exceeds
--peak-factor (default 1.5×) of the committed reference FAILS — that is a
real memory regression (a batch that stopped being freed, an accidental
full-array materialization), not host timing noise.

Usage: python ci/check_bench.py REF.json NEW.json [--tol 0.02]
       [--time-factor 1.5] [--time-floor 0.02] [--peak-factor 1.5]
"""
from __future__ import annotations

import argparse
import json
import sys


def load_cases(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["case"]: r for r in payload.get("results", []) if "case" in r}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("ref", help="committed reference BENCH_svm.json")
    ap.add_argument("new", help="freshly generated BENCH_svm.json")
    ap.add_argument("--tol", type=float, default=0.02,
                    help="max tolerated accuracy DROP per case (default 0.02)")
    ap.add_argument("--time-factor", type=float, default=1.5,
                    help="warn when a steady-state stage wall time exceeds "
                         "this factor of the reference (warn-only, "
                         "default 1.5)")
    ap.add_argument("--time-floor", type=float, default=0.02,
                    help="ignore stage times below this many seconds in the "
                         "reference (timing noise, default 0.02)")
    ap.add_argument("--peak-factor", type=float, default=1.5,
                    help="FAIL when peak_stream_bytes on a streamed case "
                         "exceeds this factor of the reference "
                         "(default 1.5)")
    ap.add_argument("--latency-floor-ms", type=float, default=0.5,
                    help="ignore serve latencies below this many ms in the "
                         "reference (default 0.5)")
    args = ap.parse_args()

    ref, new = load_cases(args.ref), load_cases(args.new)
    shared = [c for c in new if c in ref
              and "accuracy" in ref[c] and "accuracy" in new[c]]
    # Case names are scale-independent but accuracies are not: comparing a
    # full-scale reference against a --smoke run (or vice versa) would trip
    # the guard on the scale difference, not on real drift.
    mismatched = [c for c in shared
                  if ref[c].get("n_train") != new[c].get("n_train")]
    for c in mismatched:
        print(f"check_bench: skip {c}: n_train {ref[c].get('n_train')} != "
              f"{new[c].get('n_train')} (different bench scale)")
    shared = [c for c in shared if c not in mismatched]
    if not shared:
        print("check_bench: no comparable cases between ref and new — "
              "nothing to guard")
        return 0

    failures = []
    n_warn = 0
    for case in sorted(shared):
        a_ref, a_new = ref[case]["accuracy"], new[case]["accuracy"]
        drift = a_ref - a_new
        status = "FAIL" if drift > args.tol else "ok"
        print(f"check_bench: {status:4s} {case}: accuracy "
              f"{a_ref:.4f} -> {a_new:.4f} (drift {drift:+.4f})")
        if drift > args.tol:
            failures.append(case)
        # Warn-only wall-time regression check per pipeline stage.  The
        # floor clamps the DENOMINATOR (sub-floor reference times are
        # timing noise) without exempting a sub-floor stage that explodes.
        for field in ("compression_s", "factorization_s", "admm_s"):
            t_ref, t_new = ref[case].get(field), new[case].get(field)
            if t_ref is None or t_new is None:
                continue
            if t_new > args.time_factor * max(t_ref, args.time_floor):
                n_warn += 1
                print(f"check_bench: WARN {case}: {field} "
                      f"{t_ref:.3f}s -> {t_new:.3f}s "
                      f"({t_new / max(t_ref, 1e-9):.1f}x > "
                      f"{args.time_factor:.1f}x, warn-only)")
        # Warn-only serving-latency regression check (ms-unit fields of the
        # serve/* cases), same shape as the stage-time warning above.
        for field in ("p50_ms", "p99_ms", "loop_p50_ms", "loop_p99_ms"):
            t_ref, t_new = ref[case].get(field), new[case].get(field)
            if t_ref is None or t_new is None:
                continue
            if t_new > args.time_factor * max(t_ref, args.latency_floor_ms):
                n_warn += 1
                print(f"check_bench: WARN {case}: {field} "
                      f"{t_ref:.2f}ms -> {t_new:.2f}ms "
                      f"({t_new / max(t_ref, 1e-9):.1f}x > "
                      f"{args.time_factor:.1f}x, warn-only)")
        # HARD gate on the streamed build's device footprint: peak batch
        # bytes are a deterministic function of batch_leaves, the proxy
        # sizes and the (seeded) adaptive ranks — growth beyond the factor
        # means the out-of-core walk started materializing something big.
        p_ref = ref[case].get("peak_stream_bytes")
        p_new = new[case].get("peak_stream_bytes")
        if p_ref and p_new and p_new > args.peak_factor * p_ref:
            failures.append(case)
            print(f"check_bench: FAIL {case}: peak_stream_bytes "
                  f"{p_ref} -> {p_new} "
                  f"({p_new / p_ref:.2f}x > {args.peak_factor:.1f}x)")
    if failures:
        print(f"check_bench: {len(failures)}/{len(shared)} cases failed "
              f"(accuracy drop > {args.tol} or peak-byte regression > "
              f"{args.peak_factor}x): {', '.join(failures)}")
        return 1
    print(f"check_bench: {len(shared)} cases within {args.tol} of reference"
          + (f" ({n_warn} wall-time warnings)" if n_warn else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
