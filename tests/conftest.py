import os

# Tests run on the single real CPU device. (The 512-device override belongs
# EXCLUSIVELY to launch/dryrun.py — never set it here.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def trained_binary():
    """One small trained binary SVM (engine kept for warm C-sweeps) shared
    by the serving-tier and registry suites — training is the slow part."""
    from repro.core.compression import CompressionParams
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec

    x, y = make_blobs(192, seed=11)
    eng = HSSSVMEngine(
        spec=KernelSpec(h=1.2),
        comp=CompressionParams(rank=12, n_near=16, n_far=24),
        leaf_size=32, max_it=20)
    eng.prepare(x, y)
    model, _ = eng.train(1.0)
    xq, yq = make_blobs(64, seed=12)
    return eng, model, xq, yq


def make_blobs(n, n_features=4, seed=0, sep=2.5):
    """Two-class Gaussian blobs — the workhorse synthetic SVM dataset."""
    r = np.random.default_rng(seed)
    half = n // 2
    mu = np.zeros(n_features)
    mu[0] = sep
    xa = r.normal(size=(half, n_features)) + mu
    xb = r.normal(size=(n - half, n_features)) - mu
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]
