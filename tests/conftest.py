import os

# Tests run on the single real CPU device. (The 512-device override belongs
# EXCLUSIVELY to launch/dryrun.py — never set it here.)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def make_blobs(n, n_features=4, seed=0, sep=2.5):
    """Two-class Gaussian blobs — the workhorse synthetic SVM dataset."""
    r = np.random.default_rng(seed)
    half = n // 2
    mu = np.zeros(n_features)
    mu[0] = sep
    xa = r.normal(size=(half, n_features)) + mu
    xb = r.normal(size=(n - half, n_features)) - mu
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]
