import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core.kernelfn import KernelSpec, gaussian_block_xla
from tests.conftest import make_blobs


def _dense_solver(k_mat, beta):
    import jax.scipy.linalg as jsl

    chol = jsl.cholesky(k_mat + beta * jnp.eye(k_mat.shape[0]), lower=True)
    return lambda b: jsl.cho_solve((chol, True), b)


def _dual_objective(k_mat, y, x):
    yx = y * x
    return 0.5 * yx @ (k_mat @ yx) - jnp.sum(x)


def test_admm_converges_to_qp_solution():
    """Long-run ADMM must match a scipy reference on a tiny QP."""
    from scipy.optimize import minimize

    x, y = make_blobs(48, n_features=2, seed=5)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    c_val, beta = 1.0, 1.0
    state, trace = admm_mod.admm_svm(
        _dense_solver(k_mat, beta), yj, c_val, beta, max_it=2000
    )
    # scipy reference on the same dual QP
    kn = np.asarray(k_mat)
    yn = np.asarray(y)

    def obj(a):
        ya = yn * a
        return 0.5 * ya @ kn @ ya - a.sum()

    def grad(a):
        return yn * (kn @ (yn * a)) - 1.0

    cons = [dict(type="eq", fun=lambda a: yn @ a, jac=lambda a: yn)]
    res = minimize(obj, np.zeros(48), jac=grad, bounds=[(0, c_val)] * 48,
                   constraints=cons, method="SLSQP", options=dict(maxiter=500))
    f_admm = float(_dual_objective(k_mat, yj, state.z))
    f_ref = float(res.fun)
    assert f_admm <= f_ref + 1e-2 * abs(f_ref) + 1e-3, (f_admm, f_ref)
    # feasibility of the ADMM point
    assert float(jnp.abs(yj @ state.z)) < 1e-2
    assert float(state.z.min()) >= -1e-5
    assert float(state.z.max()) <= c_val + 1e-5


def test_admm_primal_residual_decreases():
    x, y = make_blobs(128, seed=1)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    _, trace = admm_mod.admm_svm(_dense_solver(k_mat, 10.0), yj, 1.0, 10.0,
                                 max_it=50)
    res = np.asarray(trace.primal_res)
    assert res[-1] <= res.max()
    assert res[-1] < 5e-2 * max(res.max(), 1e-8) or res[-1] < 1e-3


def test_admm_feasibility_invariants():
    """Property-style sweep: z always in box, final |yᵀx| small."""
    for seed in range(4):
        for beta in (1.0, 100.0):
            x, y = make_blobs(96, seed=seed)
            xj, yj = jnp.asarray(x), jnp.asarray(y)
            k_mat = gaussian_block_xla(xj, xj, 1.0)
            state, _ = admm_mod.admm_svm(
                _dense_solver(k_mat, beta), yj, 2.0, beta, max_it=30
            )
            assert float(state.z.min()) >= 0.0
            assert float(state.z.max()) <= 2.0 + 1e-6
            # x-step maintains the equality constraint exactly (closed form)
            assert float(jnp.abs(yj @ state.x)) < 1e-3


def test_admm_vector_c_pins_padded_coords():
    x, y = make_blobs(64, seed=2)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    c_vec = jnp.concatenate([jnp.full(48, 1.0), jnp.zeros(16)])
    state, _ = admm_mod.admm_svm(_dense_solver(k_mat, 10.0), yj, c_vec, 10.0,
                                 max_it=20)
    np.testing.assert_allclose(np.asarray(state.z[48:]), 0.0, atol=1e-7)


def test_warm_start_stays_feasible_and_converges():
    x, y = make_blobs(128, seed=3)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    solver = _dense_solver(k_mat, 10.0)
    s1, _ = admm_mod.admm_svm(solver, yj, 1.0, 10.0, max_it=10)
    s2w, t2w = admm_mod.admm_svm(solver, yj, 1.2, 10.0, max_it=10,
                                 z0=s1.z, mu0=s1.mu)
    s2c, t2c = admm_mod.admm_svm(solver, yj, 1.2, 10.0, max_it=10)
    # warm start must not hurt terminal convergence
    assert float(t2w.primal_res[-1]) <= 2.0 * float(t2c.primal_res[-1]) + 1e-4
    assert float(s2w.z.min()) >= 0.0 and float(s2w.z.max()) <= 1.2 + 1e-6


def test_warm_start_reproduces_cold_fixed_point_across_c_grid():
    """Warm starts (z0/mu0) are an accelerator, not a different algorithm:
    chained across the C-grid they must land on the same ADMM fixed point
    as cold starts (the correctness contract of grid_search's reuse)."""
    x, y = make_blobs(96, seed=7)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    beta = 10.0
    solver = _dense_solver(k_mat, beta)
    warm_z = warm_mu = None
    for c in (0.5, 1.0, 2.0):
        cold, _ = admm_mod.admm_svm(solver, yj, c, beta, max_it=600)
        warm, _ = admm_mod.admm_svm(solver, yj, c, beta, max_it=600,
                                    z0=warm_z, mu0=warm_mu)
        np.testing.assert_allclose(np.asarray(warm.z), np.asarray(cold.z),
                                   atol=1e-3)
        warm_z, warm_mu = warm.z, warm.mu


def test_paper_beta_rule():
    assert admm_mod.paper_beta(50_000) == 1e2
    assert admm_mod.paper_beta(500_000) == 1e3
    assert admm_mod.paper_beta(3_500_000) == 1e4


# --------------------------------------------------------------------- #
# residual-balancing adaptive rho (Boyd 3.4.1, default OFF)             #
# --------------------------------------------------------------------- #
def _adaptive_problem(n=96, seed=7):
    x, y = make_blobs(n, n_features=2, seed=seed)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    task = admm_mod.svm_task(yj[None, :], 1.0)
    return k_mat, task


def test_adaptive_rho_off_matches_plain_boxqp():
    """adapt_rho=False must be the EXACT plain solver (golden-pin safety):
    same iterates, same residual trace, beta untouched."""
    k_mat, task = _adaptive_problem()
    beta = 10.0
    solver = _dense_solver(k_mat, beta)
    state_ref, trace_ref = admm_mod.admm_boxqp(
        solver, task, beta, max_it=12, tol=1e-3)
    params = admm_mod.ADMMParams(max_it=12, tol=1e-3, adapt_rho=False)
    state, trace, info = admm_mod.admm_boxqp_adaptive(
        lambda b: _dense_solver(k_mat, b), task, beta, params)
    np.testing.assert_array_equal(np.asarray(state.z), np.asarray(state_ref.z))
    np.testing.assert_array_equal(np.asarray(trace.iters_run),
                                  np.asarray(trace_ref.iters_run))
    np.testing.assert_array_equal(np.asarray(trace.primal_res),
                                  np.asarray(trace_ref.primal_res))
    assert info["beta"] == beta and info["rescales"] == 0


def test_adaptive_rho_converges_faster_from_bad_beta():
    """From a badly scaled beta the balanced run must converge within the
    budget the fixed run exhausts, end at a rescaled beta, and still solve
    the same QP (matching dual objective to the well-scaled reference)."""
    k_mat, task = _adaptive_problem()
    bad_beta, budget = 1e4, 400
    _, trace_fixed = admm_mod.admm_boxqp(
        _dense_solver(k_mat, bad_beta), task, bad_beta,
        max_it=budget, tol=1e-3)
    params = admm_mod.ADMMParams(max_it=budget, tol=1e-3, adapt_rho=True,
                                 rho_every=5, rho_max_updates=20)
    state, trace, info = admm_mod.admm_boxqp_adaptive(
        lambda b: _dense_solver(k_mat, b), task, bad_beta, params)
    it_fixed = int(np.max(np.asarray(trace_fixed.iters_run)))
    it_adapt = int(np.max(np.asarray(trace.iters_run)))
    assert it_adapt < it_fixed, (it_adapt, it_fixed)
    assert info["rescales"] > 0 and info["beta"] < bad_beta
    # solution quality: same dual objective as a long well-scaled run
    state_ref, _ = admm_mod.admm_boxqp(
        _dense_solver(k_mat, 1.0), task, 1.0, max_it=2000)
    y = task.sign[:, 0]
    f_ref = float(_dual_objective(k_mat, y, state_ref.z[:, 0]))
    f_ad = float(_dual_objective(k_mat, y, state.z[:, 0]))
    assert f_ad <= f_ref + 1e-2 * abs(f_ref) + 1e-2, (f_ad, f_ref)


def test_adaptive_rho_rescale_cap_respected():
    k_mat, task = _adaptive_problem()
    params = admm_mod.ADMMParams(max_it=200, tol=1e-4, adapt_rho=True,
                                 rho_every=2, rho_max_updates=3)
    _, _, info = admm_mod.admm_boxqp_adaptive(
        lambda b: _dense_solver(k_mat, b), task, 1e5, params)
    assert info["rescales"] <= 3
