"""Tests for the fused compression kernels (repro.kernels.compress).

Three layers, mirroring the gaussian parity-test style (interpret mode —
CPU-only CI):

  * laplacian block kernel: Pallas vs ``laplacian_block_xla`` at odd /
    non-tile-aligned shapes, f32 and bf16;
  * fused assemble+ID: pivots EXACTLY equal to the XLA
    assemble-then-``idqr`` reference on non-degenerate random blocks, and
    interpolation matrices equal to f32 rounding, fixed-rank and adaptive,
    both kernels;
  * end-to-end: ``compress`` with impl="pallas_interpret" vs impl="xla" —
    identical skeletons and matvec parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idqr
from repro.core.kernelfn import (
    KernelSpec, gaussian_block_xla, laplacian_block_xla)
from repro.kernels.compress import ops as cops
from repro.kernels.compress.laplacian import laplacian_block


@pytest.mark.parametrize("ma,mb,f", [(1, 3, 2), (255, 129, 5), (300, 7, 11)])
def test_laplacian_pallas_xla_parity_odd_shapes_f32(ma, mb, f):
    rng = np.random.default_rng(1000 * ma + mb)
    xa = jnp.asarray(rng.normal(size=(ma, f)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(mb, f)), jnp.float32)
    for h in (0.7, 3.0):
        out = laplacian_block(xa, xb, h, interpret=True)
        ref = laplacian_block_xla(xa, xb, h)
        assert out.shape == (ma, mb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("ma,mb,f", [(1, 3, 2), (255, 129, 5), (300, 7, 11)])
def test_laplacian_pallas_xla_parity_odd_shapes_bf16(ma, mb, f):
    rng = np.random.default_rng(2000 * ma + mb)
    xa = jnp.asarray(rng.normal(size=(ma, f)), jnp.bfloat16)
    xb = jnp.asarray(rng.normal(size=(mb, f)), jnp.bfloat16)
    out = laplacian_block(xa, xb, 1.0, interpret=True)
    ref = laplacian_block_xla(
        xa.astype(jnp.float32), xb.astype(jnp.float32), 1.0)
    assert out.shape == (ma, mb)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=0.05, atol=0.05)


def _xla_block(name, xc, xp, h):
    if name == "gaussian":
        return gaussian_block_xla(xc, xp, h)
    return laplacian_block_xla(xc, xp, h)


@pytest.mark.parametrize("kernel_name", ["gaussian", "laplacian"])
@pytest.mark.parametrize("adaptive", [False, True])
@pytest.mark.parametrize("b,m,s,f,k", [
    (3, 50, 37, 5, 12),     # odd everything
    (1, 7, 3, 2, 3),        # tiny, k > s
    (4, 129, 65, 11, 16),   # crosses the 128-lane boundary
])
def test_fused_assemble_id_matches_xla_reference(
        kernel_name, adaptive, b, m, s, f, k):
    """Fused Pallas assemble+CPQR == XLA assemble + idqr row ID: exact pivots
    (greedy CPQR is deterministic on non-degenerate random blocks), matching
    interpolation matrices and detected ranks."""
    rng = np.random.default_rng(b * m + s + k)
    xc = jnp.asarray(rng.normal(size=(b, m, f)), jnp.float32)
    xp = jnp.asarray(rng.normal(size=(b, s, f)), jnp.float32)
    h, rtol = 1.3, 1e-5
    piv, pmat, ranks = cops.batched_assemble_id(
        xc, xp, k, kernel_name=kernel_name, h=h, rtol=rtol,
        adaptive=adaptive, interpret=True)
    assert piv.shape == (b, k) and pmat.shape == (b, m, k)
    for i in range(b):
        blk = _xla_block(kernel_name, xc[i], xp[i], h)
        if adaptive:
            piv_ref, p_ref, rk_ref = idqr.row_interp_decomp_ranked(
                blk, k, rtol)
            assert int(ranks[i]) == int(rk_ref)
        else:
            piv_ref, p_ref = idqr.row_interp_decomp(blk, k)
            assert int(ranks[i]) == k
        np.testing.assert_array_equal(np.asarray(piv[i]), np.asarray(piv_ref))
        np.testing.assert_allclose(np.asarray(pmat[i]), np.asarray(p_ref),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("kernel_name", ["gaussian", "laplacian"])
def test_fused_assemble_id_bf16(kernel_name):
    """bf16 inputs: the fused kernel upcasts on load and runs the whole
    assemble+deflation state in f32, so it must agree with the f32 XLA
    reference of the SAME (bf16-rounded) points to bf16 tolerance."""
    rng = np.random.default_rng(7)
    b, m, s, f, k = 2, 40, 24, 6, 8
    xc = jnp.asarray(rng.normal(size=(b, m, f)), jnp.bfloat16)
    xp = jnp.asarray(rng.normal(size=(b, s, f)), jnp.bfloat16)
    piv, pmat, _ = cops.batched_assemble_id(
        xc, xp, k, kernel_name=kernel_name, h=1.0, rtol=1e-5,
        adaptive=False, interpret=True)
    assert pmat.dtype == jnp.bfloat16
    for i in range(b):
        blk = _xla_block(kernel_name, xc[i].astype(jnp.float32),
                         xp[i].astype(jnp.float32), 1.0)
        piv_ref, p_ref = idqr.row_interp_decomp(blk, k)
        np.testing.assert_array_equal(np.asarray(piv[i]), np.asarray(piv_ref))
        np.testing.assert_allclose(
            np.asarray(pmat[i], np.float32), np.asarray(p_ref),
            rtol=0.05, atol=0.05)


def test_fused_assemble_id_respects_cmask():
    """Masked-out candidate rows must never be selected as pivots and must
    get zero interpolation weight — same contract as the XLA adaptive path."""
    rng = np.random.default_rng(11)
    b, m, s, f, k = 2, 30, 20, 4, 6
    xc = jnp.asarray(rng.normal(size=(b, m, f)), jnp.float32)
    xp = jnp.asarray(rng.normal(size=(b, s, f)), jnp.float32)
    dead = jnp.asarray(np.arange(m) >= 20, bool)        # last 10 rows dead
    cmask = jnp.where(dead, 0.0, 1.0)[None, :].repeat(b, axis=0)
    piv, pmat, ranks = cops.batched_assemble_id(
        xc, xp, k, kernel_name="gaussian", h=1.0, rtol=1e-4,
        adaptive=True, cmask=cmask, interpret=True)
    assert int(jnp.max(piv)) < 20
    # Dead rows: zero interpolation weights.
    np.testing.assert_allclose(np.asarray(pmat[:, 20:, :]), 0.0, atol=1e-6)


@pytest.mark.parametrize("kernel_name", ["gaussian", "laplacian"])
@pytest.mark.parametrize("rtol", [None, 1e-2])
def test_compress_pallas_matches_xla_end_to_end(kernel_name, rtol):
    """Whole-build parity: same skeletons, same ranks, matvec-level
    agreement between impl='xla' and impl='pallas_interpret'."""
    from repro.core import compression as comp
    from repro.core.tree import build_tree

    rng = np.random.default_rng(17)
    n, m = 256, 32
    x = rng.normal(size=(n, 5)).astype(np.float32)
    tree = build_tree(x, leaf_size=m)
    xp = jnp.asarray(x[tree.perm])
    params = comp.CompressionParams(rank=16, n_near=16, n_far=16, rtol=rtol)
    hx = comp.compress(xp, tree, KernelSpec(name=kernel_name, h=1.5,
                                            impl="xla"), params)
    hp = comp.compress(xp, tree, KernelSpec(name=kernel_name, h=1.5,
                                            impl="pallas_interpret"), params)
    np.testing.assert_array_equal(np.asarray(hx.skel_leaf),
                                  np.asarray(hp.skel_leaf))
    for sx, sp in zip(hx.skels, hp.skels):
        np.testing.assert_array_equal(np.asarray(sx), np.asarray(sp))
    v = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32))
    ref = hx.matmat(v)
    out = hp.matmat(v)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-3 * scale
