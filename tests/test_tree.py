import numpy as np
import pytest

from repro.core import tree as tree_mod


def test_build_tree_perm_is_permutation():
    r = np.random.default_rng(1)
    x = r.normal(size=(512, 3)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=64)
    assert t.levels == 3
    assert sorted(t.perm.tolist()) == list(range(512))
    inv = t.inverse_perm()
    assert np.all(t.perm[inv] == np.arange(512))


def test_tree_clusters_are_spatially_tight():
    # A tree on two widely separated blobs must not split any leaf across them.
    r = np.random.default_rng(2)
    xa = r.normal(size=(128, 2)) + np.array([100.0, 0.0])
    xb = r.normal(size=(128, 2)) - np.array([100.0, 0.0])
    x = np.concatenate([xa, xb]).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=32)
    xp = x[t.perm]
    for s in tree_mod.leaf_slices(t):
        leaf = xp[s]
        assert leaf[:, 0].max() - leaf[:, 0].min() < 50.0


def test_pad_dataset_inert():
    r = np.random.default_rng(3)
    x = r.normal(size=(100, 3)).astype(np.float32)
    y = np.sign(r.normal(size=100)).astype(np.float32)
    xp, yp, mask, levels = tree_mod.pad_dataset(x, y, leaf_size=32)
    assert xp.shape[0] == 32 * 2 ** levels >= 100
    assert mask.sum() == 100
    # pads are far from data AND from each other
    pads = xp[~mask]
    if len(pads) >= 2:
        d = np.linalg.norm(pads[0] - pads[1])
        assert d > 100.0
    d_data = np.linalg.norm(pads[0] - x, axis=1).min()
    assert d_data > 100.0


def test_padded_size():
    assert tree_mod.padded_size(100, 32) == (128, 2)
    assert tree_mod.padded_size(128, 32) == (128, 2)
    assert tree_mod.padded_size(129, 32) == (256, 3)


def test_build_tree_rejects_bad_n():
    x = np.zeros((100, 2), np.float32)
    with pytest.raises(ValueError):
        tree_mod.build_tree(x, leaf_size=32, levels=2)
