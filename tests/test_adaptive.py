"""Adaptive-rank HSS: tolerance-driven compression, masks, shrink-to-fit.

Fast tier: rank detection flows through compress -> HSSMatrix rank vectors,
masked arrays are structurally consistent (dead slots exactly zero), the
shrink-to-fit pass is EXACT (masked/shrunk-vs-full matmat and solve parity),
the mask-aware factorization solves the same system, and the engine /
trainers plumb rtol end-to-end with rank reporting.

Slow tier (8 emulated devices, subprocess like tests/test_engine.py): the
sharded adaptive build detects the same ranks as the local build, stays
sharded through shrink_to_fit, and keeps shrunk-vs-full parity <=1e-5 under
the mesh.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, factorization, tree as tree_mod
from repro.core.engine import HSSSVMEngine
from repro.core.hss import shrink_to_fit
from repro.core.kernelfn import KernelSpec, gaussian_block_xla
from repro.core.svm import HSSSVMTrainer, grid_search
from repro.data import synthetic


def _run_sub(code: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _build(n=1024, leaf=64, rank=48, h=2.0, rtol=1e-2, n_features=2, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, n_features)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=leaf)
    xp = jnp.asarray(x[t.perm])
    spec = KernelSpec(h=h)
    params = compression.CompressionParams(
        rank=rank, n_near=32, n_far=48, seed=seed, rtol=rtol)
    return compression.compress(xp, t, spec, params), xp, spec, t, params


# --------------------------------------------------------------------- #
# representation: rank vectors, masks, structural zeros                 #
# --------------------------------------------------------------------- #
def test_adaptive_build_detects_subcap_ranks():
    hss, xp, spec, _, _ = _build()
    assert hss.adaptive
    obs = hss.observed_ranks()
    assert all(o < c for o, c in zip(obs, hss.ranks)), (obs, hss.ranks)
    # error still tracks the tolerance
    k_dense = gaussian_block_xla(xp, xp, spec.h)
    err = float(jnp.linalg.norm(hss.todense() - k_dense)
                / jnp.linalg.norm(k_dense))
    assert err < 10 * 1e-2, err


def test_fixed_build_has_no_rank_vectors():
    hss, _, _, _, _ = _build(rtol=None)
    assert not hss.adaptive
    assert hss.leaf_ranks is None and hss.level_ranks == ()
    assert hss.rank_masks() is None
    assert hss.observed_ranks() == hss.ranks
    assert shrink_to_fit(hss) is hss         # passthrough


def test_masked_slots_are_structural_zeros():
    """Everything beyond a node's detected rank must be EXACTLY zero — the
    invariant that makes shrink_to_fit exact rather than approximate."""
    hss, _, _, _, _ = _build()
    leaf_ranks = np.asarray(hss.leaf_ranks)
    u = np.asarray(hss.u_leaf)
    for i, r in enumerate(leaf_ranks):
        assert np.abs(u[i, :, r:]).max() == 0.0, i
    lvl_ranks = [np.asarray(r) for r in hss.level_ranks]
    for k, t in enumerate(hss.transfers):
        t = np.asarray(t)
        rp = t.shape[1] // 2
        child = lvl_ranks[k - 1] if k > 0 else leaf_ranks
        child = child.reshape(-1, 2)
        for i in range(t.shape[0]):
            assert np.abs(t[i, :, lvl_ranks[k][i]:]).max() == 0.0   # parent
            assert np.abs(t[i, child[i, 0]:rp, :]).max() == 0.0     # child 1
            assert np.abs(t[i, rp + child[i, 1]:, :]).max() == 0.0  # child 2
    for k, b in enumerate(hss.b_mats):
        b = np.asarray(b)
        child = (leaf_ranks if k == 0 else lvl_ranks[k - 1]).reshape(-1, 2)
        for i in range(b.shape[0]):
            assert np.abs(b[i, child[i, 0]:, :]).max() == 0.0
            assert np.abs(b[i, :, child[i, 1]:]).max() == 0.0


# --------------------------------------------------------------------- #
# shrink-to-fit: exact parity                                           #
# --------------------------------------------------------------------- #
def test_shrunk_vs_full_matmat_and_solve_parity():
    """Acceptance bar: masked/shrunk-vs-full matmat and hss_solve_mat
    parity <= 1e-5."""
    hss, _, _, _, _ = _build()
    shr = shrink_to_fit(hss)
    assert shr.ranks == hss.observed_ranks()
    assert shr.memory_bytes() < hss.memory_bytes()
    assert shr.stored_rank_sum() < hss.stored_rank_sum()
    v = jnp.asarray(np.random.default_rng(1).normal(size=(hss.n, 4)),
                    jnp.float32)
    mv_full = np.asarray(hss.matmat(v))
    mv_shr = np.asarray(shr.matmat(v))
    rel = np.linalg.norm(mv_shr - mv_full) / np.linalg.norm(mv_full)
    assert rel <= 1e-5, rel

    fac_full = factorization.factorize(hss, 20.0)
    fac_shr = factorization.factorize(shr, 20.0)
    s_full = np.asarray(fac_full.solve_mat(v))
    s_shr = np.asarray(fac_shr.solve_mat(v))
    rel_s = np.linalg.norm(s_shr - s_full) / np.linalg.norm(s_full)
    assert rel_s <= 1e-5, rel_s
    # and the solve actually inverts the shifted operator
    resid = np.asarray(shr.matmat(jnp.asarray(s_shr))) + 20.0 * s_shr \
        - np.asarray(v)
    assert np.linalg.norm(resid) / np.linalg.norm(np.asarray(v)) < 1e-4


def test_shrink_multiple_rounding():
    hss, _, _, _, _ = _build()
    shr8 = shrink_to_fit(hss, multiple=8)
    assert all(r % 8 == 0 or r == c
               for r, c in zip(shr8.ranks, hss.ranks)), shr8.ranks
    assert all(r >= o for r, o in zip(shr8.ranks, hss.observed_ranks()))
    v = jnp.asarray(np.random.default_rng(2).normal(size=(hss.n, 2)),
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(shr8.matmat(v)),
                               np.asarray(hss.matmat(v)),
                               rtol=1e-5, atol=1e-5)


def test_adaptive_accuracy_tracks_tolerance():
    """Tighter rtol => better reconstruction and larger detected ranks."""
    errs, sums = [], []
    for rtol in (1e-1, 1e-2, 1e-4):
        hss, xp, spec, _, _ = _build(rtol=rtol)
        k_dense = gaussian_block_xla(xp, xp, spec.h)
        errs.append(float(jnp.linalg.norm(hss.todense() - k_dense)
                          / jnp.linalg.norm(k_dense)))
        sums.append(shrink_to_fit(hss).stored_rank_sum())
    assert errs[0] > errs[2], errs
    assert sums[0] < sums[2], sums
    assert errs[2] < 5e-3, errs


# --------------------------------------------------------------------- #
# engine / trainers / grid search plumbing                              #
# --------------------------------------------------------------------- #
def test_engine_adaptive_matches_fixed_accuracy_with_smaller_ranks():
    xtr, ytr, xte, yte = synthetic.train_test(
        "circles", 2048, 512, seed=0, n_features=2, gap=0.8)
    kw = dict(spec=KernelSpec(h=1.5), leaf_size=128, max_it=10)
    eng_f = HSSSVMEngine(
        comp=compression.CompressionParams(rank=48, n_near=48, n_far=64),
        **kw)
    acc_f = float(jnp.mean(
        eng_f.fit(xtr, ytr, c_value=1.0).predict(jnp.asarray(xte)) == yte))
    eng_a = HSSSVMEngine(
        comp=compression.CompressionParams(rank=48, n_near=48, n_far=64,
                                           rtol=1e-4), **kw)
    acc_a = float(jnp.mean(
        eng_a.fit(xtr, ytr, c_value=1.0).predict(jnp.asarray(xte)) == yte))
    rep = eng_a.report
    assert rep.rank_sum_post < rep.rank_sum_pre, rep
    assert rep.ranks_post != rep.ranks_pre
    assert rep.kernel_evals and rep.kernel_evals > 0
    assert abs(acc_a - acc_f) <= 0.01, (acc_a, acc_f)
    # the factorization was built on the shrunk representation
    assert eng_a.fac.e_leaf.shape[-1] == rep.ranks_post[0]
    # fixed-rank engine reports pre == post
    rep_f = eng_f.report
    assert rep_f.rank_sum_pre == rep_f.rank_sum_post


def test_trainer_adaptive_prepare_shrinks():
    xtr, ytr, _, _ = synthetic.train_test(
        "blobs", 1024, 256, seed=0, n_features=2, sep=2.5)
    tr = HSSSVMTrainer(
        spec=KernelSpec(h=2.0),
        comp=compression.CompressionParams.accurate(), leaf_size=128,
        max_it=5)
    rep = tr.prepare(xtr, ytr)
    assert rep.rank_sum_post < rep.rank_sum_pre
    model, _ = tr.train(1.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xtr)) == ytr))
    assert acc > 0.9, acc


def test_grid_search_rtol_plumbing():
    """rtol reaches CompressionParams through the grid search kwargs."""
    xtr, ytr, xte, yte = synthetic.train_test(
        "blobs", 512, 128, seed=1, n_features=2, sep=2.5)
    model, info = grid_search(
        xtr, ytr, xte, yte, hs=[2.0], cs=[1.0],
        trainer_kwargs=dict(leaf_size=64, max_it=5,
                            comp=compression.CompressionParams(rank=32)),
        rtol=1e-2)
    assert model.spec.h == 2.0
    assert info["best_accuracy"] > 0.85


def test_multiclass_adaptive_shared_factorization():
    from repro.core.multiclass import MulticlassHSSSVMTrainer

    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 1024, 256, seed=0, n_classes=3, n_features=2,
        sep=4.0)
    tr = MulticlassHSSSVMTrainer(
        spec=KernelSpec(h=2.0),
        comp=compression.CompressionParams(rank=48, n_near=48, n_far=64,
                                           rtol=1e-4),
        leaf_size=128, max_it=10)
    model = tr.fit(xtr, ytr, c_value=1.0)
    assert tr.report.rank_sum_post < tr.report.rank_sum_pre
    acc = float(jnp.mean(model.predict(jnp.asarray(xte))
                         == jnp.asarray(yte)))
    assert acc > 0.9, acc


# --------------------------------------------------------------------- #
# slow tier: 8-device mesh                                              #
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_adaptive_sharded_build_8_devices():
    """Sharded adaptive build: same detected ranks as the local build,
    sharded rank vectors and shrunk arrays, shrunk-vs-full parity <= 1e-5
    under the mesh, sharded-vs-local agreement at O(rtol)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import compression, factorization, tree as tree_mod
        from repro.core.hss import shrink_to_fit
        from repro.core.kernelfn import KernelSpec
        from repro.dist import api as dist_api

        rng = np.random.default_rng(0)
        n, leaf = 4096, 64
        x = rng.normal(size=(n, 2)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=leaf)
        xp = x[t.perm]
        spec = KernelSpec(h=1.5)
        rtol = 1e-4
        params = compression.CompressionParams(
            rank=24, n_near=32, n_far=48, rtol=rtol)
        mesh = jax.make_mesh((8,), ("data",))

        hss_ref = compression.compress(jnp.asarray(xp), t, spec, params)
        hss = compression.compress_sharded(xp, t, spec, params, mesh)
        assert hss.adaptive
        # identical per-node rank detection, rank vectors sharded
        assert (np.asarray(hss.leaf_ranks)
                == np.asarray(hss_ref.leaf_ranks)).all()
        assert hss.observed_ranks() == hss_ref.observed_ranks()
        assert not hss.leaf_ranks.sharding.is_fully_replicated

        shr = shrink_to_fit(hss, mesh=mesh)
        assert shr.ranks == hss.observed_ranks()
        ndev = 8
        for name in ("d_leaf", "u_leaf", "x"):
            a = getattr(shr, name)
            assert not a.sharding.is_fully_replicated, name
            assert a.addressable_shards[0].data.shape[0] == a.shape[0] // ndev

        fac = factorization.factorize_sharded(hss, 10.0, mesh)
        fac_s = factorization.factorize_sharded(shr, 10.0, mesh)
        assert fac_s.e_leaf.shape[-1] == shr.ranks[0]
        v = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
        with dist_api.use_mesh(mesh), mesh:
            mv = np.asarray(jax.jit(lambda h_, b: h_.matmat(b))(hss, v))
            mv_s = np.asarray(jax.jit(lambda h_, b: h_.matmat(b))(shr, v))
            out = np.asarray(jax.jit(lambda f, b: f.solve_mat(b))(fac, v))
            out_s = np.asarray(jax.jit(lambda f, b: f.solve_mat(b))(fac_s, v))
        rel_mv = np.linalg.norm(mv_s - mv) / np.linalg.norm(mv)
        rel_sv = np.linalg.norm(out_s - out) / np.linalg.norm(out)
        assert rel_mv <= 1e-5, rel_mv
        assert rel_sv <= 1e-5, rel_sv
        # sharded-vs-local: both builds truncate at rtol, so near-tie pivot
        # flips bound the difference by O(rtol), not float noise
        mv_ref = np.asarray(hss_ref.matmat(v))
        rel_ml = np.linalg.norm(mv - mv_ref) / np.linalg.norm(mv_ref)
        assert rel_ml <= rtol, rel_ml
        print("ADAPTIVE_SHARDED_OK", rel_mv, rel_sv, rel_ml)
    """)
    r = _run_sub(code)
    assert "ADAPTIVE_SHARDED_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_engine_adaptive_8_devices_matches_local():
    """Adaptive engine under an 8-device mesh: shrunk sharded build, same
    accuracy as the local adaptive engine, rank report populated."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.compression import CompressionParams
        from repro.core.engine import HSSSVMEngine
        from repro.core.kernelfn import KernelSpec
        from repro.data import synthetic

        xtr, ytr, xte, yte = synthetic.train_test(
            "circles", 4096, 512, seed=0, n_features=2, gap=0.8)
        kw = dict(spec=KernelSpec(h=1.5),
                  comp=CompressionParams(rank=48, n_near=48, n_far=64,
                                         rtol=1e-4),
                  leaf_size=64, max_it=10, beta=100.0)

        eng0 = HSSSVMEngine(**kw)
        m0 = eng0.fit(xtr, ytr, c_value=1.0)
        acc0 = float(jnp.mean(m0.predict(jnp.asarray(xte)) == yte))
        mesh = jax.make_mesh((8,), ("data",))
        eng8 = HSSSVMEngine(mesh=mesh, **kw)
        m8 = eng8.fit(xtr, ytr, c_value=1.0)
        acc8 = float(jnp.mean(m8.predict(jnp.asarray(xte)) == yte))

        rep = eng8.report
        assert rep.rank_sum_post < rep.rank_sum_pre, rep
        assert not eng8.hss.d_leaf.sharding.is_fully_replicated
        assert not m8.z_y.sharding.is_fully_replicated
        assert eng8.fac.e_leaf.shape[-1] == rep.ranks_post[0]
        assert abs(acc0 - acc8) <= 0.01, (acc0, acc8)
        print("ADAPTIVE_ENGINE_OK", acc0, acc8, rep.ranks_post)
    """)
    r = _run_sub(code)
    assert "ADAPTIVE_ENGINE_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_shrink_to_fit_sharding_matches_partition_spec_8_devices():
    """EVERY node-stacked field of the shrunk matrix must carry exactly the
    sharding node_partition_spec prescribes for its shape — including the
    2-D skeleton index arrays, whose post-slice device_put pins them
    replicated instead of leaking the gather's inferred output sharding."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.core import compression, tree as tree_mod
        from repro.core.hss import shrink_to_fit
        from repro.core.kernelfn import KernelSpec
        from repro.dist.api import node_partition_spec

        rng = np.random.default_rng(0)
        n, leaf = 4096, 64
        x = rng.normal(size=(n, 2)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=leaf)
        params = compression.CompressionParams(
            rank=24, n_near=32, n_far=48, rtol=1e-4)
        mesh = jax.make_mesh((8,), ("data",))
        hss = compression.compress_sharded(
            x[t.perm], t, KernelSpec(h=1.5), params, mesh)
        shr = shrink_to_fit(hss, mesh=mesh)

        def want(a):
            return NamedSharding(
                mesh, node_partition_spec(mesh, a.ndim, a.shape[0]))

        checked = 0
        fields = dict(d_leaf=shr.d_leaf, u_leaf=shr.u_leaf,
                      skel_leaf=shr.skel_leaf)
        for k, a in enumerate(shr.transfers):
            fields[f"transfers[{k}]"] = a
        for k, a in enumerate(shr.skels):
            fields[f"skels[{k}]"] = a
        for k, a in enumerate(shr.b_mats):
            fields[f"b_mats[{k}]"] = a
        for name, a in fields.items():
            assert a.sharding.is_equivalent_to(want(a), a.ndim), (
                name, a.shape, a.sharding)
            checked += 1
        # the 2-D index arrays must have come out REPLICATED
        assert shr.skel_leaf.sharding.is_fully_replicated
        assert all(s.sharding.is_fully_replicated for s in shr.skels)
        print("SHRINK_SHARDING_OK", checked)
    """)
    r = _run_sub(code)
    assert "SHRINK_SHARDING_OK" in r.stdout, r.stdout + r.stderr
