"""Seeded fixture: Python control flow on traced values (and static probes)."""
from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def bad_branch(x, thresh):
    if x.sum() > thresh:               # VIOLATION python-branch-on-tracer
        return x
    return -x


@jax.jit
def bad_while(x):
    r = jnp.abs(x)
    while r.max() > 1.0:               # VIOLATION python-branch-on-tracer
        r = r * 0.5
    return r


@partial(jax.jit, static_argnames=("blocks",))
def ok_static(x, blocks=4):
    if x.ndim == 1:                    # shape probe: resolves at trace time
        x = x[None, :]
    assert x.shape[0] % blocks == 0    # static arg: branching is the point
    return x


@jax.jit
def ok_none(x, scale=None):
    if scale is None:                  # is-None: trace-time static
        scale = 1.0
    return x * scale
