"""Seeded fixture: Python scalars crossing a jit boundary (PR 5 convention)."""
import jax
import jax.numpy as jnp


def f(x, c):
    return x * c


run = jax.jit(f)


def sweep(x):
    out = []
    for c in [0.5, 1.0, 2.0]:
        out.append(run(x, c))          # VIOLATION retrace-knob
    out.append(run(x, 4.0))            # VIOLATION retrace-knob
    out.append(run(x, float("8")))     # VIOLATION retrace-knob
    knob = jnp.asarray(2.0, jnp.float32)
    out.append(run(x, knob))           # traced scalar: clean
    return out
