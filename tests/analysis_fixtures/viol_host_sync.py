"""Seeded fixture: host syncs inside traced bodies (and static-cast exemptions)."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_float(x):
    return float(x.sum())      # VIOLATION host-sync-in-traced


def body(carry, x):
    carry = carry + x.item()   # VIOLATION host-sync-in-traced
    np.asarray(x)              # VIOLATION host-sync-in-traced
    return carry, carry


def run(xs):
    return jax.lax.scan(body, 0.0, xs)


@jax.jit
def ok_static(x):
    m = int(x.shape[0] * x.shape[1])
    return x.reshape(m)


def never_traced_here(x):
    return float(jnp.sum(x))   # helper not handed to any transform: clean
