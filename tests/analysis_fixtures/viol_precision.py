"""Seeded fixture: unprotected hot-path contractions (and every exemption)."""
import jax.numpy as jnp


def bad_pair(a, b):
    hgate = jnp.einsum("ij,jk->ik", a, b)  # VIOLATION precision-accumulate
    return jnp.matmul(hgate, b)            # VIOLATION precision-accumulate


def ok_exempt(a, b):
    c = jnp.einsum("ij,jk->ik", a, b, preferred_element_type=jnp.float32)
    d = jnp.dot(a.astype(jnp.float32), b)
    e = jnp.matmul(a, b).astype(jnp.float32)
    return c + d + e
