"""Fixture: a deliberate violation silenced by an inline disable comment."""
import jax.numpy as jnp


def deliberate(a, b):
    # operands are f32-by-construction two calls upstream
    return jnp.einsum("ij,jk->ik", a, b)  # lint: disable=precision-accumulate
