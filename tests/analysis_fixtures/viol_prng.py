"""Seeded fixture: PRNG key reuse (and the sanctioned split patterns)."""
import jax


def bad_reuse(n):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n,))
    b = jax.random.uniform(key, (n,))  # VIOLATION prng-key-reuse
    return a + b


def ok_split(n):
    key = jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    a = jax.random.normal(sub, (n,))
    b = jax.random.normal(key, (n,))   # relived by the split reassignment
    return a + b


def ok_batch(n):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    a = jax.random.normal(keys[0], (n,))
    b = jax.random.normal(keys[1], (n,))
    return a + b


def bad_loop(n):
    key = jax.random.PRNGKey(2)
    total = 0.0
    for _ in range(3):
        total = total + jax.random.normal(key, (n,))  # VIOLATION prng-key-reuse
    return total
