"""Clean fixture: the sanctioned idiom for every rule, zero findings."""
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("block",))
def protected(a, b, block=64):
    if a.ndim == 2:
        acc = jnp.einsum("ij,jk->ik", a, b,
                         preferred_element_type=jnp.float32)
    else:
        acc = jnp.matmul(a, b).astype(jnp.float32)
    return acc


run = jax.jit(protected)


def sweep(x, grid):
    out = []
    for c in grid:
        out.append(run(x, jnp.asarray(c, jnp.float32)))
    return out


def sample(n):
    key, sub = jax.random.split(jax.random.PRNGKey(0))
    return jax.random.normal(sub, (n,)), jax.random.normal(key, (n,))
