import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   load_checkpoint, load_checkpoint_arrays,
                                   save_checkpoint)


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(r.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(r.normal(size=(8,)), jnp.bfloat16),
        },
        "opt": {"m": jnp.asarray(r.normal(size=(16, 8)), jnp.float32),
                "step": jnp.asarray(3, jnp.int32)},
    }


def _assert_tree_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la, np.float32),
                                      np.asarray(lb, np.float32))


def test_save_load_roundtrip():
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=7, n_shards=3)
        out, step = load_checkpoint(d, tree)
        assert step == 7
        _assert_tree_equal(tree, out)


def test_latest_step_and_retention():
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save_async(tree, s)
            mgr.wait()
        assert latest_step(d) == 4
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
        assert steps == [3, 4]


def test_load_checkpoint_arrays_template_free():
    """Template-free restore: flat host-numpy dicts (the streamed HSS
    build's level state) round-trip bit-exactly WITH their extra metadata,
    without the caller supplying a pytree template or touching a device."""
    state = {
        "d_leaf": np.arange(24, dtype=np.float32).reshape(4, 6),
        "skel": np.arange(8, dtype=np.int32),
        "ranks": np.asarray([3, 2, 3, 1], np.int32),
    }
    fp = dict(kind="hss_streamed_build", n=128, h=1.5)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, state, step=2, n_shards=3, extra=fp)
        arrays, step, extra = load_checkpoint_arrays(d)
        assert step == 2
        assert extra == fp                      # JSON round-trip preserved
        assert set(arrays) == set(state)
        for k in state:
            assert isinstance(arrays[k], np.ndarray)
            assert arrays[k].dtype == state[k].dtype
            np.testing.assert_array_equal(arrays[k], state[k])


def test_shard_count_independence():
    """A checkpoint written with N shards restores from any reader."""
    tree = _tree(1)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=1, n_shards=7)
        out, _ = load_checkpoint(d, tree)
        _assert_tree_equal(tree, out)


def test_training_resume_bit_exact():
    """Interrupted-and-resumed training == uninterrupted training."""
    from repro.configs import get_config
    from repro.models.transformer import Model
    from repro.train import optim
    from repro.train.step import make_train_step
    from repro.data.tokens import batch_for_config

    cfg = get_config("deepseek-coder-33b").reduced()
    model = Model(cfg)
    step_fn = jax.jit(make_train_step(model))

    def run(n_steps, state):
        for s in range(state.get("_step", 0), n_steps):
            batch = jax.tree.map(
                jnp.asarray, batch_for_config(cfg, 2, 32, s))
            p, o, _ = step_fn(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o, "_step": s + 1}
        return state

    params = model.init(jax.random.PRNGKey(0))
    s0 = {"params": params, "opt": optim.adamw_init(params), "_step": 0}

    # uninterrupted 6 steps
    ref = run(6, dict(s0))

    # interrupted at 3 + checkpoint + restore + continue
    with tempfile.TemporaryDirectory() as d:
        mid = run(3, dict(s0))
        save_checkpoint(d, {"params": mid["params"], "opt": mid["opt"]},
                        step=3)
        restored, step = load_checkpoint(
            d, {"params": mid["params"], "opt": mid["opt"]})
        resumed = run(6, {"params": restored["params"],
                          "opt": restored["opt"], "_step": step})
    _assert_tree_equal(ref["params"], resumed["params"])


@pytest.mark.slow
def test_elastic_reshard_subprocess():
    """Save under an 8-device mesh, restore under a 4-device mesh."""
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys, tempfile
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import save_checkpoint, load_checkpoint

        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        arr = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        sharded = jax.device_put(arr, NamedSharding(mesh8, P("data", "model")))
        tree = {"w": sharded}
        d = tempfile.mkdtemp()
        save_checkpoint(d, tree, step=1)

        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        sh4 = {"w": NamedSharding(mesh4, P("model", "data"))}
        out, step = load_checkpoint(d, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(arr))
        assert out["w"].sharding.mesh.shape["data"] == 2
        print("ELASTIC_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
