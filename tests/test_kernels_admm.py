import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.admm_update import ops as aops
from repro.kernels.admm_update.ref import fused_zmu_update_ref


@pytest.mark.parametrize("n", [128, 1000, 4096, 65537])
@pytest.mark.parametrize("beta", [1.0, 100.0])
def test_fused_update_matches_ref(n, beta):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=n), jnp.float32)
    mu = jnp.asarray(rng.normal(size=n), jnp.float32)
    c = jnp.asarray(np.abs(rng.normal(size=n)) + 0.1, jnp.float32)
    z, mu_new = aops.fused_zmu_update(x, mu, c, beta, interpret=True)
    z_ref, mu_ref = fused_zmu_update_ref(x, mu, c, beta)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(mu_new), np.asarray(mu_ref),
                               rtol=1e-5, atol=1e-4)


def test_projection_idempotent():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=512), jnp.float32)
    mu = jnp.zeros(512, jnp.float32)
    c = jnp.full(512, 1.0, jnp.float32)
    z1, _ = aops.fused_zmu_update(x, mu, c, 10.0, interpret=True)
    z2, _ = aops.fused_zmu_update(z1, jnp.zeros_like(mu), c, 10.0, interpret=True)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-7)


def test_admm_with_fused_kernel_path():
    """End-to-end ADMM using the Pallas fused update (interpret mode)."""
    from repro.core import admm as admm_mod
    from repro.core.kernelfn import gaussian_block_xla
    import jax.scipy.linalg as jsl
    from tests.conftest import make_blobs

    x, y = make_blobs(96, seed=0)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    chol = jsl.cholesky(k_mat + 10.0 * jnp.eye(96), lower=True)
    solver = lambda b: jsl.cho_solve((chol, True), b)
    s_fused, _ = admm_mod.admm_svm(solver, yj, 1.0, 10.0, max_it=10,
                                   use_fused_update=True)
    s_plain, _ = admm_mod.admm_svm(solver, yj, 1.0, 10.0, max_it=10)
    np.testing.assert_allclose(np.asarray(s_fused.z), np.asarray(s_plain.z),
                               rtol=1e-5, atol=1e-6)
