"""Box-QP task layer tests: the generic ADMM refactor + ε-SVR + one-class.

Load-bearing assertions (ISSUE acceptance):
  * EXACT equivalence (≤ 1e-12, in practice bit-identical) of the
    refactored generic path against a verbatim copy of the pre-refactor
    ``admm_svm`` loop — the tentpole refactor cannot silently change
    binary-SVM numerics;
  * SVR and one-class train end-to-end through HSSSVMEngine on ONE shared
    HSS compression + factorization per (h, β), proven by call counting
    across the warm-started knob sweeps;
  * the residual stopping rule freezes iterates EXACTLY at the stopping
    iteration and reports iters_run;
  * slow tier: 8-device mesh parity per new task at ≤ 1e-5.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core import tasks as tasks_mod
from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec, gaussian_block_xla
from repro.data import synthetic
from tests import proptest as pt

COMP = CompressionParams(rank=24, n_near=32, n_far=48)


# --------------------------------------------------------------------- #
# exact-equivalence pin: generic path == pre-refactor admm_svm loop     #
# --------------------------------------------------------------------- #
def _prerefactor_admm_svm_batched(solver_mat, ys, c_upper, beta, max_it=10,
                                  z0=None, mu0=None):
    """Verbatim copy of the pre-refactor (PR 4) admm_svm_batched loop —
    the reference the BoxQPTask generalization is pinned against."""
    k, d = ys.shape
    dtype = ys.dtype
    y_cols = ys.T
    e = jnp.ones((d,), dtype)
    w = solver_mat(e[:, None])[:, 0]
    w1 = e @ w
    w_y = y_cols * w[:, None]
    c_arr = jnp.asarray(c_upper, dtype)
    if c_arr.ndim == 1:
        c_arr = c_arr[:, None]
    elif c_arr.ndim == 2:
        c_arr = c_arr.T
    c_mat = jnp.broadcast_to(c_arr, (d, k))
    z_init = jnp.zeros((d, k), dtype) if z0 is None else z0
    mu_init = jnp.zeros((d, k), dtype) if mu0 is None else mu0

    def step(state, _):
        x, z, mu = state
        q = 1.0 + mu + beta * z
        yq = y_cols * q
        u = solver_mat(yq)
        w2 = w @ yq
        x_new = y_cols * u - (w2 / w1)[None, :] * w_y
        z_new = jnp.clip(x_new - mu / beta, 0.0, c_mat)
        mu_new = mu - beta * (x_new - z_new)
        trace = (jnp.linalg.norm(x_new - z_new, axis=0),
                 beta * jnp.linalg.norm(z_new - z, axis=0))
        return admm_mod.ADMMState(x_new, z_new, mu_new), trace

    init = admm_mod.ADMMState(jnp.zeros((d, k), dtype), z_init, mu_init)
    return jax.lax.scan(step, init, None, length=max_it)


def _equivalence_case(solver_mat, ys, c_upper, beta, max_it, z0=None,
                      mu0=None):
    ref_state, (ref_p, ref_d) = _prerefactor_admm_svm_batched(
        solver_mat, ys, c_upper, beta, max_it, z0=z0, mu0=mu0)
    state, trace = admm_mod.admm_svm_batched(
        solver_mat, ys, c_upper, beta, max_it, z0=z0, mu0=mu0)
    for ref, new, name in [
            (ref_state.x, state.x, "x"), (ref_state.z, state.z, "z"),
            (ref_state.mu, state.mu, "mu"),
            (ref_p, trace.primal_res, "primal_res"),
            (ref_d, trace.dual_res, "dual_res")]:
        diff = float(jnp.max(jnp.abs(ref - new)))
        assert diff <= 1e-12, (name, diff)
    assert np.all(np.asarray(trace.iters_run) == max_it)


def test_generic_path_equals_prerefactor_svm_dense():
    """Dense-solver pin: scalar C, vector C, per-problem C, warm starts."""
    rng = np.random.default_rng(0)
    n, k = 96, 3
    x = rng.normal(size=(n, 3)).astype(np.float32)
    xj = jnp.asarray(x)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    beta = 10.0
    solver = pt.dense_solver_mat(k_mat, beta)
    ys = jnp.asarray(np.sign(rng.normal(size=(k, n))).astype(np.float32))
    _equivalence_case(solver, ys, 1.0, beta, 12)
    c_vec = jnp.asarray(rng.uniform(0.2, 2.0, size=n).astype(np.float32))
    _equivalence_case(solver, ys, c_vec, beta, 12)
    c_kd = jnp.asarray(rng.uniform(0.2, 2.0, size=(k, n)).astype(np.float32))
    _equivalence_case(solver, ys, c_kd, beta, 12)
    warm, _ = _prerefactor_admm_svm_batched(solver, ys, 1.0, beta, 10)
    _equivalence_case(solver, ys, 1.5, beta, 12, z0=warm.z, mu0=warm.mu)


def test_generic_path_equals_prerefactor_svm_hss():
    """HSS-factorization pin: the real solver path, traces to ≤ 1e-12."""
    x, y = synthetic.blobs(512, n_features=4, sep=1.6, seed=3)
    t = tree_mod.build_tree(x, leaf_size=64)
    xp = jnp.asarray(x[t.perm])
    yp = jnp.asarray(y[t.perm])
    hss = compression.compress(xp, t, KernelSpec(h=1.0), COMP)
    fac = factorization.factorize(hss, 100.0)
    ys = jnp.stack([yp, -yp])
    _equivalence_case(fac.solve_mat, ys, 1.0, 100.0, 10)


# --------------------------------------------------------------------- #
# SVR / one-class duals vs a dense QP reference                         #
# --------------------------------------------------------------------- #
def test_svr_task_matches_scipy_reference():
    from scipy.optimize import minimize

    rng = np.random.default_rng(1)
    n = 96
    x = rng.normal(size=(n, 2)).astype(np.float32)
    yt = np.sin(2.0 * x[:, 0]).astype(np.float32)
    xj = jnp.asarray(x)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    beta, c_val, eps = 10.0, 1.0, 0.1
    solver = pt.dense_solver_mat(k_mat, beta)
    task = tasks_mod.svr_task(jnp.asarray(yt)[None, :], c_val, eps)
    state, _ = admm_mod.admm_boxqp(solver, task, beta, max_it=800)
    alpha = np.asarray(state.z[:, 0], np.float64)
    kn = np.asarray(k_mat, np.float64)

    def obj(a):
        return 0.5 * a @ kn @ a - yt @ a + eps * np.abs(a).sum()

    res = minimize(obj, np.zeros(n), bounds=[(-c_val, c_val)] * n,
                   constraints=[dict(type="eq", fun=lambda a: a.sum())],
                   method="SLSQP", options=dict(maxiter=800))
    f_admm, f_ref = obj(alpha), float(res.fun)
    assert f_admm <= f_ref + 1e-3 * abs(f_ref) + 1e-4, (f_admm, f_ref)
    assert abs(alpha.sum()) < 1e-4                  # equality feasibility
    assert np.all(np.abs(alpha) <= c_val + 1e-5)    # box feasibility


def test_one_class_task_matches_scipy_reference():
    from scipy.optimize import minimize

    rng = np.random.default_rng(2)
    n, nu = 96, 0.2
    x = rng.normal(size=(n, 2)).astype(np.float32)
    xj = jnp.asarray(x)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    beta = 10.0
    solver = pt.dense_solver_mat(k_mat, beta)
    task = tasks_mod.one_class_task(jnp.ones((1, n), jnp.float32), nu)
    state, _ = admm_mod.admm_boxqp(solver, task, beta, max_it=800)
    alpha = np.asarray(state.z[:, 0], np.float64)
    kn = np.asarray(k_mat, np.float64)
    hi = 1.0 / (nu * n)

    res = minimize(lambda a: 0.5 * a @ kn @ a, np.full(n, 1.0 / n),
                   bounds=[(0.0, hi)] * n,
                   constraints=[dict(type="eq", fun=lambda a: a.sum() - 1.0)],
                   method="SLSQP", options=dict(maxiter=800))
    f_admm = 0.5 * alpha @ kn @ alpha
    assert f_admm <= float(res.fun) + 1e-3 * abs(res.fun) + 1e-5
    assert abs(alpha.sum() - 1.0) < 1e-4
    assert np.all(alpha >= -1e-6) and np.all(alpha <= hi + 1e-6)


def test_oneclass_nu_bounds_train_outlier_fraction():
    """The Schölkopf ν-property on the real engine: the fraction of training
    points scored as outliers is ≤ ν (+ slack for the f32 margin band)."""
    x, _ = synthetic.blobs_with_outliers(1024, n_features=4,
                                         outlier_frac=0.08, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=2.0), comp=COMP, leaf_size=64,
                          max_it=40, task="oneclass")
    engine.prepare(x)
    for nu in (0.05, 0.15):
        model, _ = engine.train(nu)
        frac = float(jnp.mean(model.predict(jnp.asarray(x)) < 0))
        assert frac <= nu + 0.05, (nu, frac)


# --------------------------------------------------------------------- #
# shared-factorization economy: call-count proofs per new task          #
# --------------------------------------------------------------------- #
def _count_build_calls(monkeypatch):
    calls = {"compress": 0, "factorize": 0}
    orig_c, orig_f = compression.compress, factorization.factorize

    def cc(*a, **kw):
        calls["compress"] += 1
        return orig_c(*a, **kw)

    def cf(*a, **kw):
        calls["factorize"] += 1
        return orig_f(*a, **kw)

    monkeypatch.setattr(compression, "compress", cc)
    monkeypatch.setattr(factorization, "factorize", cf)
    return calls


def test_svr_one_compression_one_factorization_per_h(monkeypatch):
    calls = _count_build_calls(monkeypatch)
    xtr, ytr, xte, yte = synthetic.train_test("noisy_sine", 1000, 256,
                                              seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64,
                          max_it=10, task="svr", svr_c=2.0)
    engine.prepare(xtr, ytr)
    warm = None
    for eps in (0.05, 0.1, 0.2):            # warm-started ε sweep
        model, warm = engine.train(eps, warm=warm)
    assert calls == {"compress": 1, "factorize": 1}, calls
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
    assert rmse < 0.25, rmse


def test_oneclass_one_compression_one_factorization_per_h(monkeypatch):
    calls = _count_build_calls(monkeypatch)
    x, _ = synthetic.blobs_with_outliers(1000, n_features=4,
                                         outlier_frac=0.1, seed=0)
    xval, yval = synthetic.blobs_with_outliers(512, n_features=4,
                                               outlier_frac=0.1, seed=1)
    engine = HSSSVMEngine(spec=KernelSpec(h=2.0), comp=COMP, leaf_size=64,
                          max_it=30, task="oneclass")
    engine.prepare(x)                        # unsupervised: no y
    warm = None
    scores = {}
    for nu in (0.05, 0.1, 0.2):             # warm-started ν sweep
        model, warm = engine.train(nu, warm=warm)
        scores[nu] = tasks_mod.oneclass_score(model, jnp.asarray(xval), yval)
    assert calls == {"compress": 1, "factorize": 1}, calls
    assert max(scores.values()) > 0.8, scores


# --------------------------------------------------------------------- #
# grid drivers: ε / ν sweep in place of C                               #
# --------------------------------------------------------------------- #
def test_grid_search_svr_shares_compression():
    xtr, ytr, xte, yte = synthetic.train_test("noisy_sine", 1024, 256,
                                              seed=0, noise=0.1)
    model, info = tasks_mod.grid_search_svr(
        xtr, ytr, xte, yte, hs=[1.0], epsilons=[0.05, 0.1, 0.3],
        c_value=2.0, trainer_kwargs=dict(comp=COMP, leaf_size=64, max_it=10))
    assert len(info["results"]) == 3
    assert -info["best_accuracy"] < 0.2     # scores are negated RMSE
    comp_times = {v["compression_s"] for v in info["results"].values()}
    assert len(comp_times) == 1             # one compression per h
    pred = model.predict(jnp.asarray(xte))
    assert pred.shape == (256,)


def test_grid_search_oneclass_shares_compression():
    xtr, _ = synthetic.blobs_with_outliers(1024, n_features=4,
                                           outlier_frac=0.1, seed=0)
    xval, yval = synthetic.blobs_with_outliers(512, n_features=4,
                                               outlier_frac=0.1, seed=2)
    model, info = tasks_mod.grid_search_oneclass(
        xtr, xval, yval, hs=[2.0], nus=[0.05, 0.1, 0.2],
        trainer_kwargs=dict(comp=COMP, leaf_size=64, max_it=30))
    assert len(info["results"]) == 3
    assert info["best_accuracy"] > 0.8
    comp_times = {v["compression_s"] for v in info["results"].values()}
    assert len(comp_times) == 1


# --------------------------------------------------------------------- #
# residual-based early stopping                                         #
# --------------------------------------------------------------------- #
def test_early_stop_freezes_exactly_at_stopping_iteration():
    rng = np.random.default_rng(0)
    n = 256
    x = rng.normal(size=(n, 3)).astype(np.float32)
    y = np.sign(rng.normal(size=n)).astype(np.float32)
    xj = jnp.asarray(x)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    beta = 10.0
    solver = pt.dense_solver_mat(k_mat, beta)
    ys = jnp.asarray(y)[None, :]
    state, trace = admm_mod.admm_svm_batched(solver, ys, 1.0, beta,
                                             max_it=300, tol=1e-2)
    it = int(trace.iters_run[0])
    assert 0 < it < 300, it
    # frozen state == the plain run truncated at the stopping iteration
    ref, _ = admm_mod.admm_svm_batched(solver, ys, 1.0, beta, max_it=it)
    for a, b in zip(state, ref):
        assert float(jnp.max(jnp.abs(a - b))) == 0.0
    # post-freeze trace: primal constant, dual exactly 0 (z stopped moving)
    primal = np.asarray(trace.primal_res[:, 0])
    dual = np.asarray(trace.dual_res[:, 0])
    np.testing.assert_array_equal(primal[it:], primal[it])
    np.testing.assert_array_equal(dual[it:], 0.0)
    # tol=None path is untouched: runs all iterations
    _, tr_full = admm_mod.admm_svm_batched(solver, ys, 1.0, beta, max_it=20)
    assert int(tr_full.iters_run[0]) == 20


def test_early_stop_is_per_problem_and_reported_in_fitreport():
    rng = np.random.default_rng(4)
    n = 256
    x = rng.normal(size=(n, 3)).astype(np.float32)
    y = np.sign(rng.normal(size=n)).astype(np.float32)
    xj = jnp.asarray(x)
    k_mat = gaussian_block_xla(xj, xj, 1.0)
    beta = 10.0
    solver = pt.dense_solver_mat(k_mat, beta)
    # two problems with very different conditioning: tiny C converges fast
    ys = jnp.asarray(np.stack([y, y]))
    c_kd = jnp.asarray(np.stack([np.full(n, 0.01), np.full(n, 5.0)])
                       .astype(np.float32))
    _, trace = admm_mod.admm_svm_batched(solver, ys, c_kd, beta,
                                         max_it=300, tol=1e-3)
    iters = np.asarray(trace.iters_run)
    assert iters[0] < iters[1], iters       # per-column freeze, not global

    # the engine surfaces iters_run through FitReport
    xtr, ytr = synthetic.blobs(512, n_features=4, sep=2.5, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64,
                          max_it=200, tol=1e-2, beta=10.0)
    engine.prepare(xtr, ytr)
    engine.train(1.0)
    assert engine.report.iters_run is not None
    assert 0 < engine.report.iters_run[0] < 200, engine.report.iters_run


# --------------------------------------------------------------------- #
# slow tier: 8-device mesh parity per task                              #
# --------------------------------------------------------------------- #
def _run_sub(code: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


_MESH_PARITY_TMPL = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.compression import CompressionParams
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.data import synthetic

    kw = dict(spec=KernelSpec(h={h}),
              comp=CompressionParams(rank=24, n_near=32, n_far=48),
              leaf_size=64, max_it={max_it}, beta=100.0, task="{task}",
              svr_c=2.0)
    {data}

    def fit(mesh):
        eng = HSSSVMEngine(mesh=mesh, **kw)
        eng.prepare(xtr, ytr)
        model, _ = eng.train({knob})
        return eng, model, np.asarray(
            model.decision_function(jnp.asarray(xte)))

    eng1, m1, s1 = fit(jax.make_mesh((1,), ("data",)))
    eng8, m8, s8 = fit(jax.make_mesh((8,), ("data",)))
    assert not m8.z_y.sharding.is_fully_replicated
    assert not eng8.hss.d_leaf.sharding.is_fully_replicated
    rel = np.linalg.norm(s1 - s8) / max(np.linalg.norm(s1), 1e-30)
    assert rel <= 1e-5, rel
    print("TASK_MESH_PARITY_OK", rel)
"""


@pytest.mark.slow
def test_svr_mesh_parity_8_devices():
    """SVR through the engine: 1-device vs 8-device mesh scores ≤ 1e-5."""
    code = textwrap.dedent(_MESH_PARITY_TMPL.format(
        task="svr", h=1.0, max_it=10, knob=0.1,
        data=('xtr, ytr, xte, yte = synthetic.train_test('
              '"noisy_sine", 4096, 512, seed=0, noise=0.1)')))
    r = _run_sub(code)
    assert "TASK_MESH_PARITY_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_oneclass_mesh_parity_8_devices():
    """One-class through the engine: 1- vs 8-device mesh scores ≤ 1e-5."""
    code = textwrap.dedent(_MESH_PARITY_TMPL.format(
        task="oneclass", h=2.0, max_it=30, knob=0.1,
        data=('xtr, _ = synthetic.blobs_with_outliers('
              '4096, n_features=4, outlier_frac=0.1, seed=0)\n'
              '    xte, _yte = synthetic.blobs_with_outliers('
              '512, n_features=4, outlier_frac=0.1, seed=1)\n'
              '    ytr = None')))
    r = _run_sub(code)
    assert "TASK_MESH_PARITY_OK" in r.stdout, r.stdout + r.stderr
