"""Distribution-layer tests: sharding rules + small-mesh end-to-end parity.

The heavy 512-device sweep lives in launch/dryrun.py (results in
EXPERIMENTS.md); here we verify on 8 host devices that (a) a train step
LOWERS and RUNS under a mesh, and (b) the distributed result matches the
single-device result (the shard_map MoE path vs the fallback path).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.dist import api as dist_api
from repro.dist import sharding as shd


def test_resolve_spec_divisibility_fallback():
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("model",))
    with dist_api.use_mesh(mesh):
        spec = dist_api.resolve_spec(("model", None), (7, 3))
        # 7 % 1 == 0 -> keeps axis
        assert spec[0] == "model"


def test_param_shardings_cover_all_leaves():
    from repro.configs import get_config
    from repro.models.transformer import Model

    cfg = get_config("arctic-480b").reduced()
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = shd.param_shardings(shapes, mesh)
    n = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n == len(jax.tree.leaves(shapes))


@pytest.mark.slow
def test_distributed_train_step_matches_single_device():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.transformer import Model
        from repro.train import optim
        from repro.train.step import make_train_step
        from repro.data.tokens import batch_for_config
        from repro.dist import api as dist_api, sharding as shd

        # MoE arch exercises the shard_map dispatch path
        cfg = get_config("granite-moe-3b-a800m").reduced(
            n_layers=2, remat="none", param_dtype="float32",
            compute_dtype="float32")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, batch_for_config(cfg, 8, 32, 0))

        # single device reference
        loss_ref, _ = jax.jit(model.loss_fn)(params, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with dist_api.use_mesh(mesh), mesh:
            psh = shd.param_shardings(
                jax.eval_shape(lambda: params), mesh, fsdp=True)
            bsh = shd.batch_shardings(
                jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                             batch), mesh)
            fn = jax.jit(model.loss_fn, in_shardings=(psh, bsh))
            loss_dist, _ = fn(jax.device_put(params, psh),
                              jax.device_put(batch, bsh))
        rel = abs(float(loss_ref) - float(loss_dist)) / abs(float(loss_ref))
        assert rel < 2e-2, (float(loss_ref), float(loss_dist))
        print("DIST_OK", float(loss_ref), float(loss_dist))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "DIST_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_sharded_attention_matches_single_device():
    """The shard_map head-parallel attention (incl. GQA kv slicing) must
    match the single-device path bit-for-bit-ish on an 8-device mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models.transformer import Model
        from repro.data.tokens import batch_for_config
        from repro.dist import api as dist_api, sharding as shd

        # h=16, kv=8: with mp=4 -> h_loc=4, group=2, kv_loc=2 (slicing path)
        cfg = get_config("gemma2-9b").reduced(
            n_layers=2, n_heads=16, n_kv_heads=8, head_dim=16, d_model=128,
            remat="none", param_dtype="float32", compute_dtype="float32")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = jax.tree.map(jnp.asarray, batch_for_config(cfg, 4, 64, 0))
        loss_ref, _ = jax.jit(model.loss_fn)(params, batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with dist_api.use_mesh(mesh), mesh:
            psh = shd.param_shardings(jax.eval_shape(lambda: params), mesh)
            fn = jax.jit(model.loss_fn)
            loss_dist, _ = fn(jax.device_put(params, psh), batch)
        rel = abs(float(loss_ref) - float(loss_dist)) / abs(float(loss_ref))
        assert rel < 1e-4, (float(loss_ref), float(loss_dist))
        print("ATTN_SHARD_OK", float(loss_ref), float(loss_dist))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ATTN_SHARD_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_distributed_svm_solve_matches_local():
    """HSS factorization solve under an 8-device mesh == local solve."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import compression, factorization, tree as tree_mod
        from repro.core.kernelfn import KernelSpec
        from repro.core.distributed import fac_shardings, vec_sharding

        rng = np.random.default_rng(0)
        n = 1024
        x = rng.normal(size=(n, 3)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=64)
        xp = jnp.asarray(x[t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=1.0),
            compression.CompressionParams(rank=24, n_near=32, n_far=48))
        fac = factorization.factorize(hss, 10.0)
        b = jnp.asarray(rng.normal(size=n), jnp.float32)
        ref = np.asarray(fac.solve(b))

        mesh = jax.make_mesh((8,), ("data",))
        fac_sh = fac_shardings(jax.eval_shape(lambda: fac), mesh)
        fac_d = jax.device_put(fac, fac_sh)
        b_d = jax.device_put(b, vec_sharding(mesh))
        with mesh:
            out = np.asarray(jax.jit(lambda f, v: f.solve(v))(fac_d, b_d))
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
        print("SVM_DIST_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "SVM_DIST_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_distributed_admm_c_grid_matches_single_device():
    """admm_train_distributed on 8 host devices == the 1-device mesh, per C,
    including the warm-start chaining across the grid."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import compression, factorization, tree as tree_mod
        from repro.core.distributed import admm_train_distributed
        from repro.core.kernelfn import KernelSpec
        from repro.data import synthetic

        n = 1024
        x, y = synthetic.blobs(n, n_features=4, sep=1.6, seed=0)
        t = tree_mod.build_tree(x, leaf_size=64)
        xp = jnp.asarray(x[t.perm])
        yp = jnp.asarray(y[t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=1.0),
            compression.CompressionParams(rank=24, n_near=32, n_far=48))
        fac = factorization.factorize(hss, beta=100.0)

        c_grid = [0.5, 1.0, 2.0]
        res1 = admm_train_distributed(
            fac, yp, c_grid, jax.make_mesh((1,), ("data",)), max_it=10)
        res8 = admm_train_distributed(
            fac, yp, c_grid, jax.make_mesh((8,), ("data",)), max_it=10)
        for i in range(len(c_grid)):
            np.testing.assert_allclose(
                np.asarray(res8[i][0]), np.asarray(res1[i][0]),
                rtol=1e-4, atol=1e-5)
        print("ADMM_GRID_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ADMM_GRID_OK" in r.stdout, r.stdout + r.stderr
