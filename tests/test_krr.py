"""Kernel linear-algebra task family: KRR / GP mean / Lanczos eigenpairs.

Golden tier: KRR on noisy-sine recovers the noise floor with ZERO ADMM
iterations and matches the dense (K̃+λI)⁻¹y solve to 1e-5 at the accurate
tolerance; the Hutchinson GP log marginal tracks the dense logdet and ranks
the true noise level first; Lanczos top-k eigenpairs match a dense eigh of
the SAME compressed operator.

Property tier: Lanczos Ritz residuals ‖K̃v−θv‖ stay small over randomized
trees/bandwidths, and the KRR solve residual tracks the factorization
tolerance across λ.

Precision/transfer pins for the satellites ride along: the streamed
scoring matvec keeps f32 accumulation under bf16 inputs (numeric pin + raw
jaxpr probe), and ``observed_ranks()`` costs exactly ONE host transfer.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, factorization, tree as tree_mod
from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec, kernel_matvec_streamed
from repro.core.krr import gp_log_marginal, krr_solve
from repro.core.lanczos import lanczos, top_eigenpairs, tridiag_eigh
from repro.data import synthetic
from tests import proptest as pt

COMP = CompressionParams(rank=32, n_near=48, n_far=64)
COMP_ACC = CompressionParams(rank=48, n_near=48, n_far=64, rtol=1e-4)


def _dense_operator(hss):
    """K̃ (+pads) as a dense array — the operator the solves/Lanczos see."""
    return np.asarray(hss.matmat(jnp.eye(hss.n, dtype=jnp.float32)))


# --------------------------------------------------------------------- #
# golden: KRR                                                           #
# --------------------------------------------------------------------- #
def test_golden_krr_noise_floor_zero_admm_iterations():
    """KRR must hit the 0.1 noise floor with iters_run pinned at 0."""
    xtr, ytr, xte, yte = synthetic.train_test("noisy_sine", 1024, 256,
                                              seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=128,
                          task="krr")
    engine.prepare(xtr, ytr)
    model, _ = engine.train(0.5)
    assert engine.report.iters_run == (0,)        # no ADMM ever ran
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
    assert rmse < 0.12, rmse                      # measured 0.0977


def test_krr_matches_dense_solve_at_accurate_tolerance():
    """α from the HSS path vs dense (K̃+λI)⁻¹y on the same operator."""
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 1024, 128,
                                          seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP_ACC,
                          leaf_size=128, task="krr")
    engine.prepare(xtr, ytr)
    lam = 8.0
    model, _ = engine.train(lam)
    alpha = np.asarray(jax.device_get(model.z_y))[:, 0]
    kt = _dense_operator(engine._hss)
    y = np.asarray(jax.device_get(engine._ys))[0]
    ref = np.linalg.solve(kt + lam * np.eye(kt.shape[0]), y)
    rel = np.linalg.norm(alpha - ref) / np.linalg.norm(ref)
    assert rel <= 1e-5, rel                       # measured 6.1e-6


def test_krr_rejects_nonpositive_lambda():
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 256, 64, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64,
                          task="krr")
    engine.prepare(xtr, ytr)
    with pytest.raises(ValueError):
        engine.train(0.0)


# --------------------------------------------------------------------- #
# golden: GP log marginal                                               #
# --------------------------------------------------------------------- #
def test_gp_log_marginal_tracks_dense():
    """Hutchinson+Lanczos log p(y) vs the dense slogdet reference on the
    real (pad-masked) block."""
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 512, 64,
                                          seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP_ACC,
                          leaf_size=64, task="gp")
    engine.prepare(xtr, ytr)
    lam = 0.5
    engine.train(lam)
    lml = engine.log_marginal(lam, n_probes=8, num_iters=30)

    kt = _dense_operator(engine._hss)
    mask = np.asarray(jax.device_get(engine._pmask))[0] > 0
    kr = kt[np.ix_(mask, mask)]
    y = np.asarray(jax.device_get(engine._ys))[0][mask]
    n = kr.shape[0]
    a = kr + lam * np.eye(n)
    _, logdet = np.linalg.slogdet(a)
    ref = (-0.5 * y @ np.linalg.solve(a, y) - 0.5 * logdet
           - 0.5 * n * math.log(2 * math.pi))
    rel = abs(lml - ref) / abs(ref)
    assert rel < 0.1, (lml, ref)                  # measured 0.025


def test_gp_evidence_ranks_true_noise_first():
    """log p(y) must prefer λ near the generating noise variance (0.1² =
    0.01) over a 100x-too-large λ — the model-selection property the GP
    grid driver relies on."""
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 512, 64,
                                          seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP_ACC,
                          leaf_size=64, task="gp")
    engine.prepare(xtr, ytr)
    lmls = {}
    for lam in (0.01, 1.0):
        engine.train(lam)
        lmls[lam] = engine.log_marginal(lam, n_probes=4, num_iters=25)
    assert lmls[0.01] > lmls[1.0], lmls


# --------------------------------------------------------------------- #
# golden: Lanczos eigenpairs                                            #
# --------------------------------------------------------------------- #
def test_lanczos_top_eigenpairs_match_dense_eigh():
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 512, 64, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP_ACC,
                          leaf_size=64, task="krr")
    engine.prepare(xtr, ytr)
    k = 4
    evals, vecs = engine.top_eigenpairs(k)
    evals = np.asarray(jax.device_get(evals))
    vecs = np.asarray(jax.device_get(vecs))
    kt = _dense_operator(engine._hss)
    ref = np.linalg.eigvalsh(kt)[::-1][:k]
    np.testing.assert_allclose(evals, ref, rtol=1e-3)
    # Ritz residuals: K̃v = θv to a scale-relative tolerance
    for i in range(k):
        res = np.linalg.norm(kt @ vecs[:, i] - evals[i] * vecs[:, i])
        assert res <= 1e-3 * evals[0], (i, res)
    # descending order and normalized vectors
    assert np.all(np.diff(evals) <= 0)
    np.testing.assert_allclose(np.linalg.norm(vecs, axis=0), 1.0, atol=1e-4)


def test_spectral_embed_unmaps_to_input_order():
    """Embedding rows must line up with the INPUT point order (the engine
    stores permuted+padded points internally)."""
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 300, 64, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64,
                          task="krr")
    engine.prepare(xtr, ytr)                      # 300 pads to 512
    k = 3
    emb = engine.spectral_embed(k)
    assert emb.shape == (300, k)
    evals, vecs = engine.top_eigenpairs(k)
    vecs = np.asarray(jax.device_get(vecs))
    scaled = vecs * np.sqrt(np.maximum(np.asarray(jax.device_get(evals)), 0))
    perm = engine._perm_host
    real = perm < 300
    np.testing.assert_allclose(emb[perm[real]], scaled[real], atol=1e-6)


def test_top_eigenpairs_validates_k():
    xtr, ytr, _, _ = synthetic.train_test("noisy_sine", 256, 64, seed=0)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64,
                          task="krr")
    engine.prepare(xtr, ytr)
    with pytest.raises(ValueError):
        engine.top_eigenpairs(0)


# --------------------------------------------------------------------- #
# property: Lanczos residuals + solve residual over random trees        #
# --------------------------------------------------------------------- #
def _random_hss(case, rank=24, rtol=None):
    n = case["leaf"] * 2 ** case["depth"]
    rng = np.random.default_rng(case["data_seed"])
    x = rng.normal(size=(n, 3)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=case["leaf"], levels=case["depth"])
    xp = jnp.asarray(x[t.perm])
    return compression.compress(
        xp, t, KernelSpec(h=case["h"]),
        CompressionParams(rank=rank, n_near=32, n_far=48, rtol=rtol))


def test_property_lanczos_ritz_residuals_random_trees():
    """‖K̃v − θv‖ ≤ tol·θ_max for every returned Ritz pair, across random
    tree depths, leaf sizes and bandwidths."""
    for case in pt.Cases(n_cases=5, seed=11).draw(dict(
            leaf=pt.choice(32, 64),
            depth=pt.ints(1, 3),
            h=pt.floats(0.5, 4.0, log=True),
            data_seed=pt.ints(0, 1000))):
        hss = _random_hss(case)
        k = 3
        evals, vecs = top_eigenpairs(hss, k, seed=0)
        kt = np.asarray(hss.matmat(jnp.eye(hss.n, dtype=jnp.float32)))
        evals = np.asarray(evals)
        vecs = np.asarray(vecs)
        for i in range(k):
            res = np.linalg.norm(kt @ vecs[:, i] - evals[i] * vecs[:, i])
            assert res <= 5e-3 * max(evals[0], 1.0), (case, i, res)


def test_property_krr_solve_residual_tracks_factorization():
    """‖(K̃+λI)α − y‖/‖y‖ stays at factorization accuracy across sampled
    (λ, tree) — the multi-RHS path inherits the solver's tolerance."""
    for case in pt.Cases(n_cases=5, seed=12).draw(dict(
            leaf=pt.choice(32, 64),
            depth=pt.ints(1, 3),
            h=pt.floats(0.5, 4.0, log=True),
            lam=pt.floats(0.5, 50.0, log=True),
            data_seed=pt.ints(0, 1000))):
        hss = _random_hss(case)
        rng = np.random.default_rng(case["data_seed"] + 1)
        y = jnp.asarray(rng.normal(size=(hss.n, 2)), jnp.float32)
        fac = factorization.factorize(hss, float(case["lam"]))
        alpha = krr_solve(fac, y)
        resid = hss.matmat(alpha) + case["lam"] * alpha - y
        rel = float(jnp.linalg.norm(resid) / jnp.linalg.norm(y))
        assert rel < 1e-3, (case, rel)


def test_property_gp_log_marginal_finite_random_trees():
    for case in pt.Cases(n_cases=3, seed=13).draw(dict(
            h=pt.floats(0.5, 4.0, log=True),
            lam=pt.floats(0.1, 10.0, log=True),
            data_seed=pt.ints(0, 1000))):
        case = dict(case, leaf=32, depth=2)
        hss = _random_hss(case)
        rng = np.random.default_rng(case["data_seed"] + 1)
        y = jnp.asarray(rng.normal(size=hss.n), jnp.float32)
        fac = factorization.factorize(hss, float(case["lam"]))
        lml = gp_log_marginal(hss, fac, y, n_probes=2, num_iters=15)
        assert np.isfinite(lml), case


def test_lanczos_tridiagonal_matches_operator_projection():
    """T = Vᵀ K̃ V on the built Krylov basis (the Rayleigh-Ritz identity
    full reorthogonalization is supposed to preserve)."""
    case = dict(leaf=32, depth=2, h=1.5, data_seed=7)
    hss = _random_hss(case)
    m = 12
    v0 = jax.random.normal(jax.random.PRNGKey(0), (hss.n,), jnp.float32)
    alphas, betas, basis = lanczos(hss.matvec, v0, m)
    alphas, betas = np.asarray(alphas), np.asarray(betas)
    v = np.asarray(basis)[:m].T                       # (n, m)
    kt = np.asarray(hss.matmat(jnp.eye(hss.n, dtype=jnp.float32)))
    t_full = v.T @ kt @ v
    t_ref = np.diag(alphas) + np.diag(betas[:-1], 1) + np.diag(betas[:-1], -1)
    np.testing.assert_allclose(t_full, t_ref, atol=5e-3)
    theta, _ = tridiag_eigh(jnp.asarray(alphas), jnp.asarray(betas[:-1]))
    np.testing.assert_allclose(np.asarray(theta), np.linalg.eigvalsh(t_ref),
                               atol=5e-3)


# --------------------------------------------------------------------- #
# satellite pins: bf16 scoring accumulation + single-transfer ranks     #
# --------------------------------------------------------------------- #
def test_streamed_matvec_bf16_inputs_accumulate_f32():
    """bf16 queries/support/coefficients must produce an f32 result that
    stays within bf16 INPUT rounding of the all-f32 path — the pin that
    fails if the contraction itself accumulates in bf16."""
    rng = np.random.default_rng(0)
    spec = KernelSpec(h=1.0)
    xq = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(256, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(256, 2)), jnp.float32)
    ref = kernel_matvec_streamed(spec, xq, xs, v, block=64)
    out = kernel_matvec_streamed(spec, xq.astype(jnp.bfloat16),
                                 xs.astype(jnp.bfloat16),
                                 v.astype(jnp.bfloat16), block=64)
    assert out.dtype == jnp.float32
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 2e-2, rel


def test_streamed_matvec_bf16_jaxpr_has_no_bf16_contractions():
    """Raw jaxpr probe: every dot_general in the streamed scoring matvec
    must land in f32 even when every INPUT is bf16."""
    from repro.analysis.jaxpr_check import dtype_downcasts

    spec = KernelSpec(h=1.0)
    xq = jnp.zeros((32, 4), jnp.bfloat16)
    xs = jnp.zeros((64, 4), jnp.bfloat16)
    v = jnp.zeros((64, 3), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(
        lambda a, c, w: kernel_matvec_streamed(spec, a, c, w, block=32))(
            xq, xs, v)
    assert dtype_downcasts(jaxpr) == []


def test_observed_ranks_single_host_transfer(monkeypatch):
    """Adaptive observed_ranks() must batch ALL rank vectors into ONE
    jax.device_get — the per-level version serialized K+1 round-trips on
    every shrink_report."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(512, 2)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=64)
    hss = compression.compress(
        jnp.asarray(x[t.perm]), t, KernelSpec(h=1.5),
        CompressionParams(rank=24, n_near=32, n_far=48, rtol=1e-2))
    assert hss.adaptive
    calls = []
    real_get = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda tree: calls.append(1) or real_get(tree))
    obs = hss.observed_ranks()
    assert len(calls) == 1, len(calls)
    assert len(obs) == len(hss.ranks)
    assert all(isinstance(r, int) for r in obs)
