"""Property-based tests of the system's invariants (see tests/proptest.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core.kernelfn import KernelSpec, gaussian_block_xla
from tests import proptest as pt


def test_property_shifted_kernel_spd():
    """K̃ + beta I stays SPD for all sampled (h, beta, data) — the property
    the Cholesky leaf factorization relies on."""
    for case in pt.Cases(n_cases=6, seed=1).draw(dict(
            h=pt.floats(0.3, 10.0, log=True),
            beta=pt.floats(1.0, 1e4, log=True),
            n_feat=pt.ints(2, 8),
            x=pt.arrays(lambda rng: (256, int(rng.integers(2, 9)))))):
        x = case["x"][:, :case["n_feat"]]
        t = tree_mod.build_tree(x, leaf_size=64)
        xp = jnp.asarray(x[t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=case["h"]),
            compression.CompressionParams(rank=16, n_near=24, n_far=24))
        dense = np.asarray(hss.todense()) + case["beta"] * np.eye(256)
        evals = np.linalg.eigvalsh(dense)
        assert evals.min() > 0, case


def test_property_tree_permutation_equivariance():
    """Shuffling input rows must not change the (sorted) leaf contents."""
    for case in pt.Cases(n_cases=5, seed=2).draw(dict(
            x=pt.arrays((128, 3)), perm_seed=pt.ints(0, 1000))):
        x = case["x"]
        rng = np.random.default_rng(case["perm_seed"])
        p = rng.permutation(len(x))
        t1 = tree_mod.build_tree(x, leaf_size=32)
        t2 = tree_mod.build_tree(x[p], leaf_size=32)
        a = np.sort(x[t1.perm].reshape(4, 32, 3).sum(axis=1), axis=0)
        b = np.sort(x[p][t2.perm].reshape(4, 32, 3).sum(axis=1), axis=0)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_property_skeletons_subset_of_node():
    """Every node's skeleton indices must lie inside the node's span."""
    for case in pt.Cases(n_cases=4, seed=3).draw(dict(
            x=pt.arrays((256, 4)))):
        t = tree_mod.build_tree(case["x"], leaf_size=64)
        xp = jnp.asarray(case["x"][t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=1.0),
            compression.CompressionParams(rank=16, n_near=24, n_far=24))
        skel = np.asarray(hss.skel_leaf)
        for leaf in range(hss.n_leaves):
            lo, hi = leaf * 64, (leaf + 1) * 64
            assert ((skel[leaf] >= lo) & (skel[leaf] < hi)).all()
        for k, sk in enumerate(hss.skels, start=1):
            width = 64 * 2 ** k
            sk = np.asarray(sk)
            for node in range(sk.shape[0]):
                lo, hi = node * width, (node + 1) * width
                assert ((sk[node] >= lo) & (sk[node] < hi)).all()


def test_property_solve_residual_small_across_betas():
    for case in pt.Cases(n_cases=5, seed=4).draw(dict(
            beta=pt.floats(1.0, 1e3, log=True),
            x=pt.arrays((256, 4)), b=pt.arrays((256,)))):
        t = tree_mod.build_tree(case["x"], leaf_size=64)
        xp = jnp.asarray(case["x"][t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=1.0),
            compression.CompressionParams(rank=24, n_near=32, n_far=48))
        fac = factorization.factorize(hss, case["beta"])
        b = jnp.asarray(case["b"])
        xsol = fac.solve(b)
        resid = hss.matvec(xsol) + case["beta"] * xsol - b
        rel = float(jnp.linalg.norm(resid) / jnp.linalg.norm(b))
        assert rel < 1e-3, (rel, case["beta"])


def test_property_admm_iterates_feasible():
    """For all sampled (beta, C): z in box, |yᵀx| ~ 0 after every run."""
    for case in pt.Cases(n_cases=5, seed=5).draw(dict(
            beta=pt.floats(1.0, 300.0, log=True),
            c=pt.floats(0.1, 10.0, log=True),
            x=pt.arrays((96, 3)), labels=pt.arrays((96,)))):
        import jax.scipy.linalg as jsl
        xj = jnp.asarray(case["x"])
        y = jnp.sign(jnp.asarray(case["labels"]) + 1e-9)
        k_mat = gaussian_block_xla(xj, xj, 1.0)
        chol = jsl.cholesky(k_mat + case["beta"] * jnp.eye(96), lower=True)
        state, _ = admm_mod.admm_svm(
            lambda b: jsl.cho_solve((chol, True), b), y, case["c"],
            case["beta"], max_it=15)
        assert float(state.z.min()) >= 0
        assert float(state.z.max()) <= case["c"] + 1e-5
        assert float(jnp.abs(y @ state.x)) < 1e-2 * 96, case


def test_property_hss_invariants_randomized_trees():
    """Structural HSS invariants over randomized tree depths, leaf sizes and
    ranks: matvec ≡ todense()@v, symmetry, shift identity, and O(N r) storage
    strictly below dense storage."""
    for case in pt.Cases(n_cases=6, seed=8).draw(dict(
            leaf=pt.choice(32, 64),
            depth=pt.ints(1, 3),
            rank=pt.choice(8, 16),
            h=pt.floats(0.5, 4.0, log=True),
            beta=pt.floats(1.0, 1e3, log=True),
            data_seed=pt.ints(0, 1000))):
        leaf, depth = case["leaf"], case["depth"]
        n = leaf * 2 ** depth
        rng = np.random.default_rng(case["data_seed"])
        x = rng.normal(size=(n, 4)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=leaf, levels=depth)
        xp = jnp.asarray(x[t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=case["h"]),
            compression.CompressionParams(
                rank=case["rank"], n_near=24, n_far=32))
        dense = hss.todense()
        # matvec consistent with the dense reconstruction
        v = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(hss.matvec(v)), np.asarray(dense @ v),
            rtol=2e-4, atol=2e-4, err_msg=str(case))
        # symmetry of the reconstruction
        np.testing.assert_allclose(
            np.asarray(dense), np.asarray(dense).T, atol=1e-5,
            err_msg=str(case))
        # shifted(beta) adds exactly beta*I
        np.testing.assert_allclose(
            np.asarray(hss.shifted(case["beta"]).todense()),
            np.asarray(dense) + case["beta"] * np.eye(n, dtype=np.float32),
            rtol=1e-5, atol=1e-4, err_msg=str(case))
        # storage strictly below the dense kernel matrix
        assert hss.memory_bytes() < n * n * 4, case


def _random_tree_kernel(case):
    """Dense kernel reconstructed from an HSS build over a RANDOM tree —
    the KKT checks then measure ADMM optimality against the exact kernel
    the solver used, while still exercising randomized tree geometry."""
    leaf, depth = case["leaf"], case["depth"]
    n = leaf * 2 ** depth
    rng = np.random.default_rng(case["data_seed"])
    x = rng.normal(size=(n, 3)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=leaf, levels=depth)
    hss = compression.compress(
        jnp.asarray(x[t.perm]), t, KernelSpec(h=case["h"]),
        compression.CompressionParams(rank=16, n_near=24, n_far=32))
    k_mat = np.asarray(hss.todense(), np.float64)
    k_mat = 0.5 * (k_mat + k_mat.T)           # exact symmetry for the checks
    return jnp.asarray(k_mat, jnp.float32), rng


_TREE_SPEC = dict(
    leaf=pt.choice(32, 64),
    depth=pt.ints(1, 2),
    h=pt.floats(0.8, 3.0, log=True),
    beta=pt.floats(3.0, 30.0, log=True),
    data_seed=pt.ints(0, 1000),
    knob_seed=pt.ints(0, 1000),
)

# Residual bounds for the KKT tier: ADMM at 800 iterations on float32
# iterates (measured worst case across the drawn cases: stationarity
# 9.3e-3 — the slowest-converging residual at the large-β draws — eq
# 4.4e-5, split 1.7e-5, comp_slack 1.5e-6; box is exact by construction
# of the clip).  comp_slack is near-zero by construction of the z-step
# (z IS a prox output) up to float32 rounding of the μ update.
_KKT_TOL = dict(stationarity=2e-2, eq=1e-3, box=1e-6, split=2e-4,
                comp_slack=1e-5)


def _assert_kkt(k_mat, task, state, case, label):
    res = pt.kkt_residuals(k_mat, task, state)
    for name, bound in _KKT_TOL.items():
        assert np.all(res[name] <= bound), (
            label, name, res[name], case)


def test_property_kkt_all_tasks_random_trees():
    """The generic ADMM drives EVERY box-QP task to a KKT point: SVM, ε-SVR
    and one-class verified by the same stationarity / feasibility /
    complementary-slackness residuals over random trees and knobs."""
    from repro.core import tasks as tasks_mod

    for case in pt.Cases(n_cases=4, seed=11).draw(_TREE_SPEC):
        k_mat, rng = _random_tree_kernel(case)
        n = k_mat.shape[0]
        beta = case["beta"]
        solver = pt.dense_solver_mat(k_mat, beta)
        krng = np.random.default_rng(case["knob_seed"])
        c_val = float(krng.uniform(0.3, 3.0))

        y = np.sign(krng.normal(size=n)).astype(np.float32)
        svm = admm_mod.svm_task(jnp.asarray(y)[None, :], c_val)
        state, _ = admm_mod.admm_boxqp(solver, svm, beta, max_it=800)
        _assert_kkt(k_mat, svm, state, case, "svm")

        targets = np.sin(2.0 * krng.normal(size=n)).astype(np.float32)
        svr = tasks_mod.svr_task(jnp.asarray(targets)[None, :], c_val,
                                 float(krng.uniform(0.02, 0.3)))
        state, _ = admm_mod.admm_boxqp(solver, svr, beta, max_it=800)
        _assert_kkt(k_mat, svr, state, case, "svr")

        ocl = tasks_mod.one_class_task(jnp.ones((1, n), jnp.float32),
                                       float(krng.uniform(0.05, 0.4)))
        state, _ = admm_mod.admm_boxqp(solver, ocl, beta, max_it=800)
        _assert_kkt(k_mat, ocl, state, case, "oneclass")


def test_property_kkt_warm_equals_cold_fixed_point():
    """Warm starts are an accelerator, not a different algorithm: for every
    task the warm-started run must land on a KKT point of the NEW knob's
    problem (the correctness contract of every knob-grid sweep)."""
    from repro.core import tasks as tasks_mod

    for case in pt.Cases(n_cases=3, seed=12).draw(_TREE_SPEC):
        k_mat, _ = _random_tree_kernel(case)
        n = k_mat.shape[0]
        beta = case["beta"]
        solver = pt.dense_solver_mat(k_mat, beta)
        krng = np.random.default_rng(case["knob_seed"])
        y = np.sign(krng.normal(size=n)).astype(np.float32)
        targets = np.sin(2.0 * krng.normal(size=n)).astype(np.float32)
        mask = jnp.ones((1, n), jnp.float32)

        def build(task_name, knob):
            if task_name == "svm":
                return admm_mod.svm_task(jnp.asarray(y)[None, :], knob)
            if task_name == "svr":
                return tasks_mod.svr_task(
                    jnp.asarray(targets)[None, :], 1.5, knob)
            return tasks_mod.one_class_task(mask, knob)

        for task_name, k0, k1 in (("svm", 0.5, 1.5), ("svr", 0.3, 0.08),
                                  ("oneclass", 0.3, 0.12)):
            t_first = build(task_name, k0)
            s_first, _ = admm_mod.admm_boxqp(solver, t_first, beta,
                                             max_it=800)
            t_next = build(task_name, k1)
            s_warm, _ = admm_mod.admm_boxqp(solver, t_next, beta, max_it=800,
                                            z0=s_first.z, mu0=s_first.mu)
            s_cold, _ = admm_mod.admm_boxqp(solver, t_next, beta, max_it=800)
            _assert_kkt(k_mat, t_next, s_warm, case, f"{task_name}-warm")
            _assert_kkt(k_mat, t_next, s_cold, case, f"{task_name}-cold")
            # The dual QP is convex but not strictly so (PSD kernel): z may
            # be non-unique, but the objective and the primal image K(Sz)
            # ARE unique — compare those, not raw coordinates.
            kn = np.asarray(k_mat, np.float64)

            def objective(st):
                z = np.asarray(st.z, np.float64)[:, 0]
                s = np.asarray(t_next.sign, np.float64)[:, 0]
                p = np.asarray(t_next.lin, np.float64)[:, 0]
                gam = (0.0 if t_next.l1 is None
                       else float(np.asarray(t_next.l1)[0]))
                sz = s * z
                return (0.5 * sz @ kn @ sz + p @ z
                        + gam * np.abs(z).sum()), kn @ sz

            f_w, ksz_w = objective(s_warm)
            f_c, ksz_c = objective(s_cold)
            assert abs(f_w - f_c) <= 1e-3 * (1.0 + abs(f_c)), (
                task_name, f_w, f_c, case)
            assert np.abs(ksz_w - ksz_c).max() <= 3e-2, (
                task_name, np.abs(ksz_w - ksz_c).max(), case)


def test_property_rope_norm_preserving():
    """RoPE is a rotation: per-head vector norms are invariant."""
    from repro.models.layers import apply_rope

    for case in pt.Cases(n_cases=5, seed=6).draw(dict(
            x=pt.arrays((2, 16, 4, 32)), theta=pt.floats(1e3, 1e6, log=True))):
        x = jnp.asarray(case["x"])
        pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
        out = apply_rope(x, pos, case["theta"])
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(case["x"], axis=-1), rtol=2e-4, atol=1e-5)


def test_property_moe_capacity_drop_bounded():
    """MoE output differs from unlimited-capacity only on dropped tokens;
    total routed weight never exceeds 1 per token."""
    from repro.models.layers import MoEParams, moe_block

    for case in pt.Cases(n_cases=3, seed=7).draw(dict(
            seed=pt.ints(0, 99), e=pt.choice(4, 8), k=pt.choice(1, 2))):
        rng = np.random.default_rng(case["seed"])
        e, k, d, bsz, s = case["e"], case["k"], 16, 2, 32
        p = MoEParams(
            router=jnp.asarray(rng.normal(size=(d, e)) * 0.1, jnp.float32),
            w_gate=jnp.asarray(rng.normal(size=(e, d, 32)) * 0.1, jnp.float32),
            w_up=jnp.asarray(rng.normal(size=(e, d, 32)) * 0.1, jnp.float32),
            w_down=jnp.asarray(rng.normal(size=(e, 32, d)) * 0.1, jnp.float32),
        )
        x = jnp.asarray(rng.normal(size=(bsz, s, d)), jnp.float32)
        out_small, _ = moe_block(x, p, k, capacity_factor=0.5)
        out_big, _ = moe_block(x, p, k, capacity_factor=1e9)
        # capped-capacity output is a "partial" version: where it differs it
        # must be strictly smaller in magnitude (dropped contributions)
        n_small = float(jnp.linalg.norm(out_small))
        n_big = float(jnp.linalg.norm(out_big))
        assert n_small <= n_big * 1.05 + 1e-6
        assert jnp.all(jnp.isfinite(out_small))


def test_property_kernel_eval_count_matches_instrumentation():
    """``kernel_eval_count`` (the bench's perf-trajectory denominator) must
    EXACTLY equal a counting-kernel instrumentation of ``compress`` across
    random trees/params — and the fused Pallas path must leave the count
    unchanged (it dispatches at the same seam, after the count is taken)."""
    for case in pt.Cases(n_cases=5, seed=13).draw(dict(
            levels=pt.ints(1, 3), leaf=pt.choice(8, 16, 32),
            rank=pt.ints(4, 24), n_near=pt.ints(4, 24),
            n_far=pt.ints(4, 24), seed=pt.ints(0, 99),
            rtol=pt.choice(None, 1e-2),
            name=pt.choice("gaussian", "laplacian"))):
        rng = np.random.default_rng(case["seed"])
        n = case["leaf"] * 2 ** case["levels"]
        x = rng.normal(size=(n, 3)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=case["leaf"])
        xp = jnp.asarray(x[t.perm])
        params = compression.CompressionParams(
            rank=case["rank"], n_near=min(case["n_near"], n - case["leaf"]),
            n_far=case["n_far"], rtol=case["rtol"])
        spec = KernelSpec(name=case["name"], h=1.0)
        with compression.counting_kernel_evals() as ctr:
            compression.compress(xp, t, spec, params)
        pred = compression.kernel_eval_count(t, params)
        assert ctr["count"] == pred, (case, ctr["count"], pred)


def test_property_streamed_kernel_eval_count_batching_independent():
    """The streamed out-of-core build counts the SAME kernel evaluations as
    ``kernel_eval_count`` predicts (= the resident build) at EVERY batch
    size — tiling the batch axis must not change what reaches the counting
    seams, or the bench's perf-trajectory denominator silently forks."""
    for case in pt.Cases(n_cases=3, seed=15).draw(dict(
            levels=pt.ints(2, 3), leaf=pt.choice(16, 32),
            rank=pt.ints(4, 12), seed=pt.ints(0, 99),
            rtol=pt.choice(None, 1e-2))):
        rng = np.random.default_rng(case["seed"])
        n = case["leaf"] * 2 ** case["levels"]
        x = rng.normal(size=(n, 3)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=case["leaf"])
        params = compression.CompressionParams(
            rank=case["rank"], n_near=8, n_far=8, rtol=case["rtol"])
        spec = KernelSpec(h=1.0)
        pred = compression.kernel_eval_count(t, params)
        for bl in (1, 3, 64):
            with compression.counting_kernel_evals() as ctr:
                compression.compress_streamed(
                    x[t.perm], t, spec, params,
                    stream=compression.StreamParams(batch_leaves=bl))
            assert ctr["count"] == pred, (case, bl, ctr["count"], pred)


def test_property_pallas_path_kernel_eval_count_unchanged():
    """impl='pallas_interpret' counts the SAME logical kernel evaluations as
    impl='xla' (tiny sizes — interpret mode is slow)."""
    for case in pt.Cases(n_cases=2, seed=14).draw(dict(
            seed=pt.ints(0, 99), name=pt.choice("gaussian", "laplacian"))):
        rng = np.random.default_rng(case["seed"])
        n, leaf = 64, 16
        x = rng.normal(size=(n, 3)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=leaf)
        xp = jnp.asarray(x[t.perm])
        params = compression.CompressionParams(rank=8, n_near=8, n_far=8)
        counts = {}
        for impl in ("xla", "pallas_interpret"):
            spec = KernelSpec(name=case["name"], h=1.0, impl=impl)
            with compression.counting_kernel_evals() as ctr:
                compression.compress(xp, t, spec, params)
            counts[impl] = ctr["count"]
        pred = compression.kernel_eval_count(t, params)
        assert counts["xla"] == counts["pallas_interpret"] == pred, (
            case, counts, pred)
