"""Property-based tests of the system's invariants (see tests/proptest.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core.kernelfn import KernelSpec, gaussian_block_xla
from tests import proptest as pt


def test_property_shifted_kernel_spd():
    """K̃ + beta I stays SPD for all sampled (h, beta, data) — the property
    the Cholesky leaf factorization relies on."""
    for case in pt.Cases(n_cases=6, seed=1).draw(dict(
            h=pt.floats(0.3, 10.0, log=True),
            beta=pt.floats(1.0, 1e4, log=True),
            n_feat=pt.ints(2, 8),
            x=pt.arrays(lambda rng: (256, int(rng.integers(2, 9)))))):
        x = case["x"][:, :case["n_feat"]]
        t = tree_mod.build_tree(x, leaf_size=64)
        xp = jnp.asarray(x[t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=case["h"]),
            compression.CompressionParams(rank=16, n_near=24, n_far=24))
        dense = np.asarray(hss.todense()) + case["beta"] * np.eye(256)
        evals = np.linalg.eigvalsh(dense)
        assert evals.min() > 0, case


def test_property_tree_permutation_equivariance():
    """Shuffling input rows must not change the (sorted) leaf contents."""
    for case in pt.Cases(n_cases=5, seed=2).draw(dict(
            x=pt.arrays((128, 3)), perm_seed=pt.ints(0, 1000))):
        x = case["x"]
        rng = np.random.default_rng(case["perm_seed"])
        p = rng.permutation(len(x))
        t1 = tree_mod.build_tree(x, leaf_size=32)
        t2 = tree_mod.build_tree(x[p], leaf_size=32)
        a = np.sort(x[t1.perm].reshape(4, 32, 3).sum(axis=1), axis=0)
        b = np.sort(x[p][t2.perm].reshape(4, 32, 3).sum(axis=1), axis=0)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_property_skeletons_subset_of_node():
    """Every node's skeleton indices must lie inside the node's span."""
    for case in pt.Cases(n_cases=4, seed=3).draw(dict(
            x=pt.arrays((256, 4)))):
        t = tree_mod.build_tree(case["x"], leaf_size=64)
        xp = jnp.asarray(case["x"][t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=1.0),
            compression.CompressionParams(rank=16, n_near=24, n_far=24))
        skel = np.asarray(hss.skel_leaf)
        for leaf in range(hss.n_leaves):
            lo, hi = leaf * 64, (leaf + 1) * 64
            assert ((skel[leaf] >= lo) & (skel[leaf] < hi)).all()
        for k, sk in enumerate(hss.skels, start=1):
            width = 64 * 2 ** k
            sk = np.asarray(sk)
            for node in range(sk.shape[0]):
                lo, hi = node * width, (node + 1) * width
                assert ((sk[node] >= lo) & (sk[node] < hi)).all()


def test_property_solve_residual_small_across_betas():
    for case in pt.Cases(n_cases=5, seed=4).draw(dict(
            beta=pt.floats(1.0, 1e3, log=True),
            x=pt.arrays((256, 4)), b=pt.arrays((256,)))):
        t = tree_mod.build_tree(case["x"], leaf_size=64)
        xp = jnp.asarray(case["x"][t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=1.0),
            compression.CompressionParams(rank=24, n_near=32, n_far=48))
        fac = factorization.factorize(hss, case["beta"])
        b = jnp.asarray(case["b"])
        xsol = fac.solve(b)
        resid = hss.matvec(xsol) + case["beta"] * xsol - b
        rel = float(jnp.linalg.norm(resid) / jnp.linalg.norm(b))
        assert rel < 1e-3, (rel, case["beta"])


def test_property_admm_iterates_feasible():
    """For all sampled (beta, C): z in box, |yᵀx| ~ 0 after every run."""
    for case in pt.Cases(n_cases=5, seed=5).draw(dict(
            beta=pt.floats(1.0, 300.0, log=True),
            c=pt.floats(0.1, 10.0, log=True),
            x=pt.arrays((96, 3)), labels=pt.arrays((96,)))):
        import jax.scipy.linalg as jsl
        xj = jnp.asarray(case["x"])
        y = jnp.sign(jnp.asarray(case["labels"]) + 1e-9)
        k_mat = gaussian_block_xla(xj, xj, 1.0)
        chol = jsl.cholesky(k_mat + case["beta"] * jnp.eye(96), lower=True)
        state, _ = admm_mod.admm_svm(
            lambda b: jsl.cho_solve((chol, True), b), y, case["c"],
            case["beta"], max_it=15)
        assert float(state.z.min()) >= 0
        assert float(state.z.max()) <= case["c"] + 1e-5
        assert float(jnp.abs(y @ state.x)) < 1e-2 * 96, case


def test_property_hss_invariants_randomized_trees():
    """Structural HSS invariants over randomized tree depths, leaf sizes and
    ranks: matvec ≡ todense()@v, symmetry, shift identity, and O(N r) storage
    strictly below dense storage."""
    for case in pt.Cases(n_cases=6, seed=8).draw(dict(
            leaf=pt.choice(32, 64),
            depth=pt.ints(1, 3),
            rank=pt.choice(8, 16),
            h=pt.floats(0.5, 4.0, log=True),
            beta=pt.floats(1.0, 1e3, log=True),
            data_seed=pt.ints(0, 1000))):
        leaf, depth = case["leaf"], case["depth"]
        n = leaf * 2 ** depth
        rng = np.random.default_rng(case["data_seed"])
        x = rng.normal(size=(n, 4)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=leaf, levels=depth)
        xp = jnp.asarray(x[t.perm])
        hss = compression.compress(
            xp, t, KernelSpec(h=case["h"]),
            compression.CompressionParams(
                rank=case["rank"], n_near=24, n_far=32))
        dense = hss.todense()
        # matvec consistent with the dense reconstruction
        v = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        np.testing.assert_allclose(
            np.asarray(hss.matvec(v)), np.asarray(dense @ v),
            rtol=2e-4, atol=2e-4, err_msg=str(case))
        # symmetry of the reconstruction
        np.testing.assert_allclose(
            np.asarray(dense), np.asarray(dense).T, atol=1e-5,
            err_msg=str(case))
        # shifted(beta) adds exactly beta*I
        np.testing.assert_allclose(
            np.asarray(hss.shifted(case["beta"]).todense()),
            np.asarray(dense) + case["beta"] * np.eye(n, dtype=np.float32),
            rtol=1e-5, atol=1e-4, err_msg=str(case))
        # storage strictly below the dense kernel matrix
        assert hss.memory_bytes() < n * n * 4, case


def test_property_rope_norm_preserving():
    """RoPE is a rotation: per-head vector norms are invariant."""
    from repro.models.layers import apply_rope

    for case in pt.Cases(n_cases=5, seed=6).draw(dict(
            x=pt.arrays((2, 16, 4, 32)), theta=pt.floats(1e3, 1e6, log=True))):
        x = jnp.asarray(case["x"])
        pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
        out = apply_rope(x, pos, case["theta"])
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out), axis=-1),
            np.linalg.norm(case["x"], axis=-1), rtol=2e-4, atol=1e-5)


def test_property_moe_capacity_drop_bounded():
    """MoE output differs from unlimited-capacity only on dropped tokens;
    total routed weight never exceeds 1 per token."""
    from repro.models.layers import MoEParams, moe_block

    for case in pt.Cases(n_cases=3, seed=7).draw(dict(
            seed=pt.ints(0, 99), e=pt.choice(4, 8), k=pt.choice(1, 2))):
        rng = np.random.default_rng(case["seed"])
        e, k, d, bsz, s = case["e"], case["k"], 16, 2, 32
        p = MoEParams(
            router=jnp.asarray(rng.normal(size=(d, e)) * 0.1, jnp.float32),
            w_gate=jnp.asarray(rng.normal(size=(e, d, 32)) * 0.1, jnp.float32),
            w_up=jnp.asarray(rng.normal(size=(e, d, 32)) * 0.1, jnp.float32),
            w_down=jnp.asarray(rng.normal(size=(e, 32, d)) * 0.1, jnp.float32),
        )
        x = jnp.asarray(rng.normal(size=(bsz, s, d)), jnp.float32)
        out_small, _ = moe_block(x, p, k, capacity_factor=0.5)
        out_big, _ = moe_block(x, p, k, capacity_factor=1e9)
        # capped-capacity output is a "partial" version: where it differs it
        # must be strictly smaller in magnitude (dropped contributions)
        n_small = float(jnp.linalg.norm(out_small))
        n_big = float(jnp.linalg.norm(out_big))
        assert n_small <= n_big * 1.05 + 1e-6
        assert jnp.all(jnp.isfinite(out_small))
