"""Roofline machinery tests — including the facts the design rests on."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.roofline import analysis as ra
from repro.roofline import hlo_cost


HLO_SAMPLE = """
HloModule test

%region_body (arg: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %arg = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%arg), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot.1 = f32[128,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[128,128]) tuple(%i2, %ar)
}

%region_cond (arg2: (s32[], f32[128,128])) -> pred[] {
  %arg2 = (s32[], f32[128,128]) parameter(0)
  %i3 = s32[] get-tuple-element(%arg2), index=0
  %lim = s32[] constant(12)
  ROOT %cmp = pred[] compare(%i3, %lim), direction=LT
}

ENTRY %main.1 (p0: f32[128,128]) -> f32[128,128] {
  %p0 = f32[128,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[128,128]) tuple(%c0, %p0)
  %while.1 = (s32[], f32[128,128]) while(%tup), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %res = f32[128,128]{1,0} get-tuple-element(%while.1), index=1
}
"""


def test_loop_multiplier_from_known_trip_count():
    t = hlo_cost.analyze(HLO_SAMPLE, entry="main.1")
    # 12 iterations x one 128^3 matmul
    assert t["flops"] == 12 * 2 * 128 ** 3
    assert t["computation_multipliers"]["region_body"] == 12.0


def test_collective_bytes_multiplied_and_ring_model():
    t = hlo_cost.analyze(HLO_SAMPLE, entry="main.1")
    op_bytes = 128 * 128 * 4
    assert t["collective_bytes"] == 12 * op_bytes
    # ring all-reduce over group size 4: 2 * (4-1)/4
    assert abs(t["collective_ring_bytes"] - 12 * 2 * op_bytes * 0.75) < 1.0


def test_trip_count_fallback_from_condition():
    hlo = HLO_SAMPLE.replace(
        ', backend_config={"known_trip_count":{"n":"12"}}', "")
    t = hlo_cost.analyze(hlo, entry="main.1")
    assert t["flops"] == 12 * 2 * 128 ** 3   # constant(12) in the condition


def test_roofline_report_terms():
    coll = dict(operand_bytes=50e9, ring_bytes=75e9, per_op={}, n_collectives=1)
    rep = ra.roofline_report(
        dict(flops=197e12, **{"bytes accessed": 819e9}), coll)
    assert abs(rep["t_compute_s"] - 1.0) < 1e-9
    assert abs(rep["t_memory_s"] - 1.0) < 1e-9
    assert abs(rep["t_collective_s"] - 1.0) < 1e-9
    assert rep["dominant"] in ("compute", "memory", "collective")


def test_xla_cost_analysis_counts_while_once():
    """The fact the whole loop-correction design rests on (DESIGN.md §7)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from repro.roofline import hlo_cost

        def f(x, w):
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            return jax.lax.scan(body, x, w)[0]

        flops = {}
        for L in (4, 8):
            c = jax.jit(f).lower(
                jax.ShapeDtypeStruct((64, 64), jnp.float32),
                jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)).compile()
            flops[L] = (hlo_cost.xla_cost_analysis(c)["flops"],
                        hlo_cost.analyze(c.as_text())["flops"])
        raw4, fix4 = flops[4]
        raw8, fix8 = flops[8]
        assert raw4 == raw8, "XLA now multiplies trip counts?!"
        assert fix8 == 2 * fix4
        assert fix4 == 4 * 2 * 64**3
        print("LOOPFACT_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "LOOPFACT_OK" in r.stdout, r.stdout + r.stderr


def test_model_flops_formulas():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config("llama3-405b")
    n = ra.active_param_count(cfg)
    assert 3.8e11 < n < 4.3e11, n      # ~405B
    mf = ra.model_flops_train(cfg, SHAPES["train_4k"])
    assert 2.3e18 < mf < 2.7e18        # 6 * N * (256*4096)

    moe = get_config("arctic-480b")
    n_act = ra.active_param_count(moe)
    assert n_act < 4e10                # active << total for top-2 of 128
