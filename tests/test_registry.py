"""Model-registry tests: bit-exact round trips, fingerprint trust, pruning."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt
from repro.serve import (
    FORMAT_VERSION, ModelRegistry, RegistryError, model_fingerprint,
)
from test_serve import TASKS, mk_model


@pytest.fixture()
def registry(tmp_path):
    return ModelRegistry(str(tmp_path / "models"))


def _assert_models_equal(a, b):
    assert np.array_equal(np.asarray(a.x_perm), np.asarray(b.x_perm))
    assert np.array_equal(np.asarray(a.z_y), np.asarray(b.z_y))
    assert np.array_equal(np.asarray(a.biases), np.asarray(b.biases))
    assert np.array_equal(np.asarray(a.classes), np.asarray(b.classes))
    if a.pairs is None:
        assert b.pairs is None
    else:
        assert np.array_equal(np.asarray(a.pairs), np.asarray(b.pairs))
    assert (a.task, a.strategy, a.binary) == (b.task, b.strategy, b.binary)
    assert (a.spec.name, a.spec.h, a.spec.impl) \
        == (b.spec.name, b.spec.h, b.spec.impl)
    assert a.c_value == b.c_value and a.beta == b.beta


@pytest.mark.parametrize("task", TASKS)
def test_round_trip_bit_identical(registry, task):
    """save → load returns bit-identical duals/bias/metadata for every
    task shape (svm binary, OVR, OVO, SVR, one-class)."""
    model = mk_model(task, seed=13)
    version = registry.save(task, model)
    loaded, info = registry.load(task)
    assert version == 1 and info.version == 1
    assert info.n_support_kept == info.n_support_stored
    _assert_models_equal(model, loaded)
    # and the loaded model scores identically
    xq = np.random.default_rng(2).normal(
        size=(20, model.x_perm.shape[1])).astype(np.float32)
    assert np.array_equal(np.asarray(model.predict(jnp.asarray(xq))),
                          np.asarray(loaded.predict(jnp.asarray(xq))))


def test_versions_accumulate_and_load_by_version(registry):
    m1, m2 = mk_model("binary", seed=1), mk_model("binary", seed=2)
    assert registry.save("m", m1) == 1
    assert registry.save("m", m2) == 2
    assert registry.versions("m") == [1, 2]
    assert registry.names() == ["m"]
    latest, info = registry.load("m")
    _assert_models_equal(m2, latest)
    v1, info1 = registry.load("m", version=1)
    _assert_models_equal(m1, v1)
    assert info.version == 2 and info1.version == 1


def test_missing_model_raises(registry):
    with pytest.raises(RegistryError, match="no such model"):
        registry.load("nope")
    with pytest.raises(RegistryError, match="no such model"):
        registry.load("nope", version=3)


def test_bad_names_rejected(registry):
    for name in ("", ".hidden", f"a{__import__('os').sep}b"):
        with pytest.raises(RegistryError, match="bad model name"):
            registry.save(name, mk_model("binary"))


def test_foreign_artifact_rejected(registry, tmp_path):
    """A training checkpoint (or anything without the serve fingerprint)
    under a model directory must be refused, not reinterpreted."""
    path = registry._dir("foreign")
    ckpt.save_checkpoint(
        path, dict(z=np.zeros((4, 1), np.float32)), step=1,
        extra=dict(stream_fingerprint={"kind": "hss_stream_build"}))
    with pytest.raises(RegistryError, match="foreign artifact"):
        registry.load("foreign")


def test_stale_format_version_rejected(registry):
    model = mk_model("binary", seed=3)
    fp = model_fingerprint(model)
    fp["format_version"] = FORMAT_VERSION + 1
    ckpt.save_checkpoint(
        registry._dir("stale"),
        dict(x_perm=np.asarray(model.x_perm), z_y=np.asarray(model.z_y),
             biases=np.asarray(model.biases),
             classes=np.asarray(model.classes)),
        step=1, extra=dict(fingerprint=fp))
    with pytest.raises(RegistryError, match="stale artifact format"):
        registry.load("stale")


def test_tampered_shape_fingerprint_rejected(registry):
    model = mk_model("binary", seed=4)
    fp = model_fingerprint(model)
    fp["n_support"] = fp["n_support"] + 1
    ckpt.save_checkpoint(
        registry._dir("bad"),
        dict(x_perm=np.asarray(model.x_perm), z_y=np.asarray(model.z_y),
             biases=np.asarray(model.biases),
             classes=np.asarray(model.classes)),
        step=1, extra=dict(fingerprint=fp))
    with pytest.raises(RegistryError, match="fingerprint/n_support"):
        registry.load("bad")


def test_missing_array_rejected(registry):
    model = mk_model("binary", seed=5)
    ckpt.save_checkpoint(
        registry._dir("partial"),
        dict(x_perm=np.asarray(model.x_perm)),
        step=1, extra=dict(fingerprint=model_fingerprint(model)))
    with pytest.raises(RegistryError, match="missing"):
        registry.load("partial")


# --------------------------------------------------------------------- #
# the SV-pruning load transform                                          #
# --------------------------------------------------------------------- #
def test_prune_drops_zero_weight_rows_exactly(registry):
    model = mk_model("binary", seed=6)
    zy = np.asarray(model.z_y).copy()
    zy[::3] = 0.0                       # every third row carries no weight
    import dataclasses
    model = dataclasses.replace(model, z_y=jnp.asarray(zy))
    registry.save("z", model)
    loaded, info = registry.load("z", prune_tol=0.0)
    keep = np.abs(zy[:, 0]) > 0
    assert info.n_support_kept == int(keep.sum())
    assert info.pruned_frac > 0.3
    assert np.array_equal(np.asarray(loaded.x_perm),
                          np.asarray(model.x_perm)[keep])
    assert np.array_equal(np.asarray(loaded.z_y), zy[keep])


def test_prune_degenerate_keeps_top_sv(registry):
    model = mk_model("binary", seed=7)
    registry.save("d", model)
    loaded, info = registry.load("d", prune_tol=1e9)   # prunes everything
    assert info.n_support_kept == 1
    top = int(np.argmax(np.abs(np.asarray(model.z_y)[:, 0])))
    assert np.array_equal(np.asarray(loaded.x_perm),
                          np.asarray(model.x_perm)[top][None])


def test_prune_golden_accuracy(registry, trained_binary):
    """On the trained golden case, a pruned load must stay within 0.01
    holdout accuracy of the unpruned model (approximate-extreme-points:
    near-zero duals contribute nothing detectable)."""
    _, model, xq, yq = trained_binary
    registry.save("golden", model)
    full, _ = registry.load("golden")
    pruned, info = registry.load("golden", prune_tol=1e-4)
    assert info.n_support_kept < info.n_support_stored  # pads at least
    acc_full = float(np.mean(
        np.asarray(full.predict(jnp.asarray(xq))) == yq))
    acc_pruned = float(np.mean(
        np.asarray(pruned.predict(jnp.asarray(xq))) == yq))
    assert acc_full >= 0.9                      # the golden case itself
    assert abs(acc_full - acc_pruned) <= 0.01
    # and served predictions through the engine agree with direct predict
    from repro.serve import ServingEngine

    serve = ServingEngine(registry=registry)
    mid = serve.load("golden", prune_tol=1e-4)
    _, preds = serve.score(mid, xq)
    assert np.array_equal(preds, np.asarray(pruned.predict(jnp.asarray(xq))))
