"""Mini property-based testing harness (hypothesis is not installable in the
offline container — DESIGN.md §6).  Seeded random case generation with
shrink-free reporting: on failure the full case dict is in the assert.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class Cases:
    n_cases: int = 10
    seed: int = 0

    def draw(self, spec: dict[str, Callable[[np.random.Generator], Any]]):
        """Yield dicts of drawn values, one per case."""
        for i in range(self.n_cases):
            rng = np.random.default_rng(self.seed * 7919 + i)
            yield {k: fn(rng) for k, fn in spec.items()}


def ints(lo, hi):
    return lambda rng: int(rng.integers(lo, hi + 1))


def floats(lo, hi, log=False):
    if log:
        return lambda rng: float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
    return lambda rng: float(rng.uniform(lo, hi))


def choice(*opts):
    return lambda rng: opts[int(rng.integers(0, len(opts)))]


def arrays(shape_fn, scale=1.0, dtype=np.float32):
    def gen(rng):
        shape = shape_fn(rng) if callable(shape_fn) else shape_fn
        return (rng.normal(size=shape) * scale).astype(dtype)
    return gen
