"""Mini property-based testing harness (hypothesis is not installable in the
offline container — DESIGN.md §6).  Seeded random case generation with
shrink-free reporting: on failure the full case dict is in the assert.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class Cases:
    n_cases: int = 10
    seed: int = 0

    def draw(self, spec: dict[str, Callable[[np.random.Generator], Any]]):
        """Yield dicts of drawn values, one per case."""
        for i in range(self.n_cases):
            rng = np.random.default_rng(self.seed * 7919 + i)
            yield {k: fn(rng) for k, fn in spec.items()}


def ints(lo, hi):
    return lambda rng: int(rng.integers(lo, hi + 1))


def floats(lo, hi, log=False):
    if log:
        return lambda rng: float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
    return lambda rng: float(rng.uniform(lo, hi))


def choice(*opts):
    return lambda rng: opts[int(rng.integers(0, len(opts)))]


def arrays(shape_fn, scale=1.0, dtype=np.float32):
    def gen(rng):
        shape = shape_fn(rng) if callable(shape_fn) else shape_fn
        return (rng.normal(size=shape) * scale).astype(dtype)
    return gen


def dense_solver_mat(k_mat, beta):
    """(K + βI)^{-1} multi-RHS solver via dense Cholesky — the exact-solve
    reference the ADMM/KKT tiers share (tests/test_property.py,
    tests/test_tasks.py)."""
    import jax.numpy as jnp
    import jax.scipy.linalg as jsl

    chol = jsl.cholesky(
        k_mat + beta * jnp.eye(k_mat.shape[0], dtype=k_mat.dtype), lower=True)
    return lambda b: jsl.cho_solve((chol, True), b)


def kkt_residuals(k_mat, task, state) -> dict[str, np.ndarray]:
    """Named KKT residuals of a BoxQPTask at an ADMM iterate (x, z, μ).

    The problem is  min ½ xᵀSKSx + pᵀx + γ‖x‖₁  s.t. aᵀx = b, lo ≤ x ≤ hi
    (repro.core.admm.BoxQPTask); the ADMM split multiplier is u = −μ.  At a
    KKT point: ∇f(z) + λa + u = 0 with u ∈ γ∂‖z‖₁ + N_box(z).  Every task —
    SVM, ε-SVR, one-class — is checked by the SAME residuals, all evaluated
    in float64 from the float32 iterates:

      stationarity — ‖∇f(z) + λ*a + u‖∞ / (1 + ‖∇f(z)‖∞) with λ* the
                     least-squares equality multiplier (the u-orthogonality
                     of the gradient, i.e. dual stationarity);
      eq / box     — primal feasibility |aᵀz − b| and box violation;
      split        — ‖x − z‖∞ (consensus between the two ADMM blocks);
      comp_slack   — dual feasibility + complementary slackness via the
                     prox fixed point: ‖z − Π_box(soft(z + u, γ))‖∞
                     normalized by (1 + ‖u‖∞); zero iff u lies in the
                     subdifferential γ∂‖z‖₁ + N_box(z) — at an interior
                     coordinate this forces u_i = ∓γ (u_i = 0 for γ = 0,
                     the classic free-SV condition) and at a bound it
                     enforces the sign condition, so one residual covers
                     every complementary-slackness case uniformly.

    ``k_mat`` is the dense kernel the solver approximated (so residuals
    measure ADMM optimality, not kernel-compression error).  Returns
    per-problem (k,) arrays.
    """
    x = np.asarray(state.x, np.float64)
    z = np.asarray(state.z, np.float64)
    mu = np.asarray(state.mu, np.float64)
    s = np.asarray(task.sign, np.float64)
    p = np.asarray(task.lin, np.float64)
    lo = np.broadcast_to(np.asarray(task.lo, np.float64), z.shape)
    hi = np.broadcast_to(np.asarray(task.hi, np.float64), z.shape)
    k_mat = np.asarray(k_mat, np.float64)
    n_prob = z.shape[1]
    gam = (np.zeros(n_prob) if task.l1 is None
           else np.broadcast_to(np.asarray(task.l1, np.float64), (n_prob,)))

    grad = s * (k_mat @ (s * z)) + p          # ∇(½ zᵀSKSz + pᵀz)
    u = -mu                                   # the split multiplier
    if task.eq_sa is not None:
        sa = np.asarray(task.eq_sa, np.float64)
        a = s * (sa[:, None] if sa.ndim == 1 else sa)
        b = (np.zeros(n_prob) if task.eq_b is None
             else np.asarray(task.eq_b, np.float64))
        lam = -np.sum(a * (grad + u), axis=0) / np.sum(a * a, axis=0)
        r_eq = np.abs(np.sum(a * z, axis=0) - b)
        stat_vec = grad + lam[None, :] * a + u
    else:
        r_eq = np.zeros(n_prob)
        stat_vec = grad + u
    r_stat = np.abs(stat_vec).max(axis=0) / (1.0 + np.abs(grad).max(axis=0))
    r_box = np.maximum(np.maximum(lo - z, 0.0),
                       np.maximum(z - hi, 0.0)).max(axis=0)
    r_split = np.abs(x - z).max(axis=0)
    v = z + u
    prox = np.clip(np.sign(v) * np.maximum(np.abs(v) - gam[None, :], 0.0),
                   lo, hi)
    r_cs = np.abs(z - prox).max(axis=0) / (1.0 + np.abs(u).max(axis=0))
    return dict(stationarity=r_stat, eq=r_eq, box=r_box, split=r_split,
                comp_slack=r_cs)
