"""Per-arch smoke tests: reduced config, one forward/train step, decode step.

Asserts output shapes and finiteness (no NaN/Inf) for every assigned arch.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.shapes import SHAPES, cell_status
from repro.models.transformer import Model
from repro.train import optim
from repro.train.step import make_train_step

B, S = 2, 64


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    if cfg.frontend == "audio_stub":
        return {
            "frames": jax.random.normal(ks[0], (B, S, cfg.frontend_dim),
                                        jnp.float32),
            "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
            "mask_indices": jax.random.bernoulli(ks[2], 0.3, (B, S)),
        }
    if cfg.frontend == "vision_stub":
        s_txt = S - cfg.n_prefix_tokens
        return {
            "patches": jax.random.normal(
                ks[0], (B, cfg.n_prefix_tokens, cfg.frontend_dim),
                jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, s_txt), 0, cfg.vocab),
            "labels": jax.random.randint(ks[2], (B, s_txt), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    opt = optim.adamw_init(params)
    step = jax.jit(make_train_step(model))
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), (arch, metrics)
    assert float(metrics["loss"]) > 0
    # params actually changed
    delta = optim.global_norm(
        jax.tree.map(lambda a, b: a - b, params, params2))
    assert float(delta) > 0
    # one more step reduces nothing catastrophic (finite)
    params3, _, m3 = step(params2, opt2, batch)
    assert jnp.isfinite(m3["loss"])


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "encoder":
        pytest.skip("encoder-only arch has no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.cache_init(B, max_len=S)
    tokens = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, tokens)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    logits2, cache = step(params, cache, tokens + 1)
    assert int(cache["pos"]) == 2
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["gemma2-9b", "mamba2-780m", "zamba2-1.2b",
                                  "paligemma-3b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(s tokens) + decode == forward(s+1 tokens) logits."""
    cfg = get_config(arch).reduced(remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    s = 16
    if cfg.frontend == "vision_stub":
        batch = {
            "patches": jax.random.normal(
                key, (1, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.float32),
            "tokens": jax.random.randint(key, (1, s), 0, cfg.vocab),
        }
        total = cfg.n_prefix_tokens + s
    else:
        batch = {"tokens": jax.random.randint(key, (1, s), 0, cfg.vocab)}
        total = s
    logits_pre, cache = model.prefill(params, batch, max_len=total + 4)
    full = model.forward_logits(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(full[:, -1]), rtol=2e-2, atol=2e-2)
    # decode one token and compare with forward over the extended sequence
    nxt = jnp.argmax(logits_pre, -1)[:, None].astype(jnp.int32)
    logits_dec, _ = model.decode_step(params, cache, nxt)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    full2 = model.forward_logits(params, batch2)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(full2[:, -1]), rtol=5e-2, atol=5e-2)


def test_cell_status_matrix():
    rows = []
    for arch in list_archs():
        cfg = get_config(arch)
        for sname, sh in SHAPES.items():
            ok, why = cell_status(cfg, sh)
            rows.append((arch, sname, ok))
    assert len(rows) == 40
    skipped = [(a, s) for a, s, ok in rows if not ok]
    # hubert decode shapes + 7 pure-attention long_500k
    assert ("hubert-xlarge", "decode_32k") in skipped
    assert ("hubert-xlarge", "long_500k") in skipped
    assert ("llama3-405b", "long_500k") in skipped
    assert ("mamba2-780m", "long_500k") not in skipped
    assert ("zamba2-1.2b", "long_500k") not in skipped
    # 7 pure-attention archs skip long_500k + hubert skips both decode shapes
    assert len(skipped) == 9


def test_full_configs_construct():
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.name == arch
        if cfg.family in ("ssm", "hybrid"):
            assert cfg.d_inner % cfg.ssm_head_dim == 0
        elif cfg.family == "moe":
            assert cfg.n_experts > 0 and cfg.top_k > 0
