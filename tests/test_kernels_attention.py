import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention.ref import attention_ref


def _qkv(b=1, h=4, hkv=2, s=128, d=32, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)) * 0.5, dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)) * 0.5, dtype)
    return q, k, v


@pytest.mark.parametrize("cfg", [
    dict(causal=True, window=None, softcap=0.0),
    dict(causal=True, window=32, softcap=0.0),
    dict(causal=True, window=None, softcap=30.0),
    dict(causal=False, window=None, softcap=0.0),   # encoder (hubert)
    dict(causal=True, window=16, softcap=50.0),     # gemma2-style local
])
def test_flash_matches_ref(cfg):
    q, k, v = _qkv()
    out = attn_ops.fused_attention(q, k, v, interpret=True, **cfg)
    ref = attention_ref(q, k, v, **cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [
    dict(b=2, h=2, hkv=1, s=64, d=16),    # MQA
    dict(b=1, h=8, hkv=8, s=64, d=64),    # MHA
    dict(b=1, h=6, hkv=2, s=96, d=32),    # GQA, non-pow2 seq
])
def test_flash_gqa_shapes(shape):
    q, k, v = _qkv(**shape)
    out = attn_ops.fused_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q, k, v = _qkv(dtype=jnp.bfloat16)
    out = attn_ops.fused_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=0.05, atol=0.05)


def test_causality_property():
    """Perturbing a future token must not change past outputs."""
    q, k, v = _qkv(b=1, h=2, hkv=2, s=64, d=16)
    out1 = attn_ops.fused_attention(q, k, v, causal=True, interpret=True)
    k2 = k.at[:, :, -1].add(10.0)
    v2 = v.at[:, :, -1].add(10.0)
    out2 = attn_ops.fused_attention(q, k2, v2, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, :-1]),
                               np.asarray(out2[:, :, :-1]), atol=1e-5)


def test_window_property():
    """With window w, token i must ignore keys j <= i-w."""
    q, k, v = _qkv(b=1, h=2, hkv=2, s=64, d=16)
    w = 8
    out1 = attn_ops.fused_attention(q, k, v, causal=True, window=w,
                                    interpret=True)
    # perturb keys far in the past of the last query
    k2 = k.at[:, :, :32].add(5.0)
    v2 = v.at[:, :, :32].add(5.0)
    out2 = attn_ops.fused_attention(q, k2, v2, causal=True, window=w,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, :, -8:]),
                               np.asarray(out2[:, :, -8:]), atol=1e-5)
