import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ops as sops
from repro.kernels.ssd import ref as sref


def _inputs(b=2, s=64, h=4, p=16, g=2, n=8, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), dtype)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, s, h))) * 0.1 + 0.01, dtype)
    a = jnp.asarray(-np.abs(rng.normal(size=h)) - 0.1, dtype)
    b_mat = jnp.asarray(rng.normal(size=(b, s, g, n)) * 0.3, dtype)
    c_mat = jnp.asarray(rng.normal(size=(b, s, g, n)) * 0.3, dtype)
    d_vec = jnp.asarray(rng.normal(size=h) * 0.1, dtype)
    return x, dt, a, b_mat, c_mat, d_vec


def test_chunked_ref_matches_scan_ref():
    """The semiseparable chunked evaluation == exact recurrence."""
    rng = np.random.default_rng(1)
    s, p, n = 64, 8, 4
    x = jnp.asarray(rng.normal(size=(s, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=s)) * 0.1 + 0.01, jnp.float32)
    a = -0.5
    b_mat = jnp.asarray(rng.normal(size=(s, n)) * 0.3, jnp.float32)
    c_mat = jnp.asarray(rng.normal(size=(s, n)) * 0.3, jnp.float32)
    y_scan, h_scan = sref.ssd_scan_ref(x, dt, a, b_mat, c_mat, 0.1)
    for chunk in (8, 16, 32):
        y_chunk, h_chunk = sref.ssd_chunked_ref(x, dt, a, b_mat, c_mat, 0.1,
                                                chunk=chunk)
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_scan),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_scan),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", [
    dict(b=1, s=32, h=2, p=8, g=1, n=4, chunk=8),
    dict(b=2, s=64, h=4, p=16, g=2, n=8, chunk=16),
    dict(b=1, s=128, h=2, p=32, g=2, n=16, chunk=32),
])
def test_pallas_matches_ref(shape):
    chunk = shape.pop("chunk")
    x, dt, a, b_mat, c_mat, d_vec = _inputs(**shape)
    y_k = sops.ssd_forward(x, dt, a, b_mat, c_mat, d_vec, chunk=chunk,
                           interpret=True, use_pallas=True)
    y_r = sops.ssd_forward(x, dt, a, b_mat, c_mat, d_vec, chunk=chunk,
                           use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-4, atol=1e-4)


def test_pallas_state_resets_between_sequences():
    """Batch elements must not leak state into each other (scratch reset)."""
    x, dt, a, b_mat, c_mat, d_vec = _inputs(b=2, s=32, h=2, p=8, g=1, n=4)
    y_batch = sops.ssd_forward(x, dt, a, b_mat, c_mat, d_vec, chunk=8,
                               interpret=True)
    y_single = sops.ssd_forward(x[1:], dt[1:], a, b_mat[1:], c_mat[1:], d_vec,
                                chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(y_batch[1]), np.asarray(y_single[0]),
                               rtol=1e-5, atol=1e-6)


def test_decay_long_range_forgetting():
    """Strong decay ⇒ early tokens cannot influence late outputs."""
    x, dt, a, b_mat, c_mat, d_vec = _inputs(b=1, s=64, h=2, p=8, g=1, n=4)
    a_strong = jnp.full_like(a, -50.0)
    y1 = sops.ssd_forward(x, dt, a_strong, b_mat, c_mat, d_vec, chunk=16,
                          interpret=True)
    x2 = x.at[:, :8].set(0.0)
    y2 = sops.ssd_forward(x2, dt, a_strong, b_mat, c_mat, d_vec, chunk=16,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y1[:, -16:]), np.asarray(y2[:, -16:]),
                               rtol=1e-4, atol=1e-5)
