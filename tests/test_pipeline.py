import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_pipeline_matches_sequential():
    """4-stage GPipe over 8 host devices == sequential reference (fp32)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_forward

        n_stages, n_micro, mb, d = 4, 6, 2, 16
        mesh = jax.make_mesh((n_stages,), ("stage",))
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(n_stages, d, d)) * 0.3, jnp.float32)
        params = {"w": w}
        x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)

        def stage_fn(p, a):
            return jnp.tanh(a @ p["w"])

        out = pipeline_forward(stage_fn, params, x, mesh, axis="stage")

        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ w[s])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        print("PIPELINE_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
