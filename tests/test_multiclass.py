"""Multiclass subsystem tests: the shared-factorization economy + correctness.

The load-bearing assertion (ISSUE acceptance): ONE HSS compression and ONE
factorization per (h, beta) serve ALL k class subproblems AND the whole C
grid — verified by call counting, plus batched-vs-sequential equivalence.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core import compression, factorization
from repro.core import multiclass as mc
from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


@pytest.fixture(scope="module")
def blobs4():
    # 1000 is NOT leaf_size * 2**levels — exercises multiclass padding too.
    return synthetic.train_test("multiclass_blobs", 1000, 256, seed=0,
                                n_classes=4, sep=3.0)


@pytest.fixture(scope="module")
def trained4(blobs4):
    xtr, ytr, _, _ = blobs4
    trainer = mc.MulticlassHSSSVMTrainer(
        spec=KernelSpec(h=1.5), comp=COMP, leaf_size=64, max_it=10)
    trainer.prepare(xtr, ytr)
    model, warm = trainer.train(1.0)
    return trainer, model, warm


def test_one_compression_one_factorization_serve_all_classes_and_c_grid(
        blobs4, monkeypatch):
    xtr, ytr, xte, yte = blobs4
    calls = {"compress": 0, "factorize": 0}
    orig_compress, orig_factorize = compression.compress, factorization.factorize

    def counting_compress(*a, **kw):
        calls["compress"] += 1
        return orig_compress(*a, **kw)

    def counting_factorize(*a, **kw):
        calls["factorize"] += 1
        return orig_factorize(*a, **kw)

    monkeypatch.setattr(compression, "compress", counting_compress)
    monkeypatch.setattr(factorization, "factorize", counting_factorize)

    trainer = mc.MulticlassHSSSVMTrainer(
        spec=KernelSpec(h=1.5), comp=COMP, leaf_size=64, max_it=10)
    trainer.prepare(xtr, ytr)
    warm = None
    for c in (0.5, 1.0, 2.0):                    # C grid x 4 classes = 12 runs
        model, warm = trainer.train(c, warm=warm)
    assert calls["compress"] == 1, calls
    assert calls["factorize"] == 1, calls
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == jnp.asarray(yte)))
    assert acc > 0.9, acc


def test_multiclass_accuracy_and_shapes(blobs4, trained4):
    xtr, ytr, xte, yte = blobs4
    trainer, model, warm = trained4
    assert trainer.n_problems == 4
    assert model.z_y.shape[1] == 4 and model.biases.shape == (4,)
    scores = model.decision_function(jnp.asarray(xte))
    assert scores.shape == (xte.shape[0], 4)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == jnp.asarray(yte)))
    assert acc > 0.9, acc
    # warm-start state has one column per class
    assert warm[0].shape == warm[1].shape == (trainer._ys.shape[1], 4)


def test_batched_admm_matches_sequential_per_class(trained4):
    """The (d, k)-block iteration must equal k independent binary runs."""
    trainer, _, _ = trained4
    fac, ys, pmask = trainer._fac, trainer._ys, trainer._pmask
    state_b, trace_b = admm_mod.admm_svm_batched(
        fac.solve_mat, ys, 1.0 * pmask, fac.beta, max_it=10)
    for i in range(ys.shape[0]):
        state_i, trace_i = admm_mod.admm_svm(
            fac.solve, ys[i], 1.0 * pmask[i], fac.beta, max_it=10)
        np.testing.assert_allclose(
            np.asarray(state_b.z[:, i]), np.asarray(state_i.z),
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(trace_b.primal_res[:, i]), np.asarray(trace_i.primal_res),
            rtol=1e-4, atol=1e-5)


def test_pads_carry_zero_weight(trained4):
    trainer, model, _ = trained4
    n_pad = model.z_y.shape[0] - 1000
    assert n_pad > 0
    # padded coordinates sit at the end in pre-permutation order; in permuted
    # order find them via the participation mask instead
    dead = np.asarray(trainer._pmask[0]) == 0
    assert dead.sum() == n_pad
    np.testing.assert_array_equal(np.asarray(model.z_y)[dead], 0.0)


def test_one_vs_one_pairs_and_accuracy():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 512, 128, seed=1, n_classes=3, sep=3.0)
    trainer = mc.MulticlassHSSSVMTrainer(
        spec=KernelSpec(h=1.5), comp=COMP, leaf_size=64, max_it=10,
        strategy="ovo")
    trainer.prepare(xtr, ytr)
    assert trainer.n_problems == 3          # 3*(3-1)/2 pairs
    model, _ = trainer.train(1.0)
    assert model.pairs.shape == (3, 2)
    # points outside a pair are pinned to the [0, 0] box -> zero coefficient
    z_y = np.asarray(model.z_y)
    for p in range(3):
        outsiders = np.asarray(trainer._pmask[p]) == 0
        np.testing.assert_array_equal(z_y[outsiders, p], 0.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == jnp.asarray(yte)))
    assert acc > 0.9, acc


def test_predict_returns_original_label_values():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 512, 128, seed=2, n_classes=3, sep=3.5)
    ytr2, yte2 = ytr * 3 + 5, yte * 3 + 5       # labels {5, 8, 11}
    trainer = mc.MulticlassHSSSVMTrainer(
        spec=KernelSpec(h=1.5), comp=COMP, leaf_size=64, max_it=10)
    model = trainer.fit(xtr, ytr2, c_value=1.0)
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    assert set(np.unique(pred)) <= {5, 8, 11}
    assert float(np.mean(pred == yte2)) > 0.85


def test_grid_search_multiclass_shares_compression():
    xtr, ytr, xte, yte = synthetic.train_test(
        "spirals", 1024, 256, seed=0, n_classes=3)
    model, info = mc.grid_search_multiclass(
        xtr, ytr, xte, yte, hs=[0.2], cs=[0.5, 2.0, 8.0],
        trainer_kwargs=dict(comp=COMP, leaf_size=64, max_it=10))
    assert len(info["results"]) == 3
    assert info["best_accuracy"] > 0.85
    comp_times = {v["compression_s"] for v in info["results"].values()}
    assert len(comp_times) == 1             # one compression per h
    assert model.n_classes == 3


def test_multiclass_distributed_matches_local(trained4):
    """Data-parallel batched C-grid == local batched run (1-device mesh)."""
    from repro.core.distributed import admm_train_multiclass_distributed

    trainer, _, _ = trained4
    fac, ys, pmask = trainer._fac, trainer._ys, trainer._pmask
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    out = admm_train_multiclass_distributed(
        fac, ys, [0.5, 1.0], mesh, max_it=8, pmask=pmask)
    st1, _ = admm_mod.admm_svm_batched(
        fac.solve_mat, ys, 0.5 * pmask, fac.beta, max_it=8)
    st2, _ = admm_mod.admm_svm_batched(
        fac.solve_mat, ys, 1.0 * pmask, fac.beta, max_it=8,
        z0=st1.z, mu0=st1.mu)
    np.testing.assert_allclose(
        np.asarray(out[-1][0]), np.asarray(st2.z), rtol=2e-4, atol=2e-5)
