import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import grad_compress as gc


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=8192), jnp.float32)
    y = gc.compress_roundtrip(x)
    err = float(jnp.max(jnp.abs(x - y)))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert err <= scale * 1.01


def test_error_feedback_unbiased_over_time():
    """Sum of compressed grads with EF converges to sum of true grads."""
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros((256,), jnp.float32)}
    err = gc.ErrorFeedback.init(params)
    true_sum = np.zeros(256)
    comp_sum = np.zeros(256)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=256), jnp.float32)}
        cg, err = gc.ErrorFeedback.apply(g, err)
        true_sum += np.asarray(g["w"])
        comp_sum += np.asarray(cg["w"])
    resid = np.abs(true_sum - comp_sum).max()
    # residual stays bounded by one quantization step, not O(n_steps)
    assert resid < 0.2, resid


@pytest.mark.slow
def test_compressed_allreduce_multidevice():
    """int8 all-to-all reduce-scatter + all-gather == plain sum (8 devices)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.train import grad_compress as gc

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(8, 4096)), jnp.float32)
        reduce_fn = gc.make_compressed_allreduce(mesh, "data")
        out = np.asarray(reduce_fn(g))
        ref = np.asarray(g).sum(axis=0)
        rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
        assert rel < 0.05, rel
        print("ALLREDUCE_OK", rel)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "ALLREDUCE_OK" in r.stdout, r.stdout + r.stderr
