import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idqr


def _lowrank(m, n, r, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, r)) @ rng.normal(size=(r, n))
    if noise:
        a = a + noise * rng.normal(size=(m, n))
    return jnp.asarray(a, jnp.float32)


def test_cpqr_pivots_unique():
    a = _lowrank(40, 30, 10, noise=1e-3)
    piv, q = idqr.cpqr_select(a, 12)
    assert len(set(np.asarray(piv).tolist())) == 12
    # q orthonormal
    qtq = np.asarray(q.T @ q)
    np.testing.assert_allclose(qtq, np.eye(12), atol=1e-4)


@pytest.mark.parametrize("rank,k", [(5, 8), (10, 12), (15, 20)])
def test_interp_decomp_reconstructs(rank, k):
    a = _lowrank(64, 48, rank)
    piv, t = idqr.interp_decomp(a, k)
    rec = jnp.take(a, piv, axis=1) @ t
    err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert err < 1e-3, err


def test_interp_identity_on_skeleton():
    a = _lowrank(32, 24, 6, noise=1e-4)
    piv, t = idqr.interp_decomp(a, 8)
    sub = np.asarray(jnp.take(t, piv, axis=1))
    np.testing.assert_allclose(sub, np.eye(8), atol=1e-5)


def test_row_interp_decomp():
    a = _lowrank(48, 64, 7).T  # (64, 48) rank 7, ID the rows of a 48x64... keep simple
    a = _lowrank(48, 64, 7)
    piv, p = idqr.row_interp_decomp(a, 10)
    rec = p @ jnp.take(a, piv, axis=0)
    err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert err < 1e-3
