import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import idqr


def _lowrank(m, n, r, seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, r)) @ rng.normal(size=(r, n))
    if noise:
        a = a + noise * rng.normal(size=(m, n))
    return jnp.asarray(a, jnp.float32)


def test_cpqr_pivots_unique():
    a = _lowrank(40, 30, 10, noise=1e-3)
    piv, q = idqr.cpqr_select(a, 12)
    assert len(set(np.asarray(piv).tolist())) == 12
    # q orthonormal
    qtq = np.asarray(q.T @ q)
    np.testing.assert_allclose(qtq, np.eye(12), atol=1e-4)


@pytest.mark.parametrize("rank,k", [(5, 8), (10, 12), (15, 20)])
def test_interp_decomp_reconstructs(rank, k):
    a = _lowrank(64, 48, rank)
    piv, t = idqr.interp_decomp(a, k)
    rec = jnp.take(a, piv, axis=1) @ t
    err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert err < 1e-3, err


def test_interp_identity_on_skeleton():
    a = _lowrank(32, 24, 6, noise=1e-4)
    piv, t = idqr.interp_decomp(a, 8)
    sub = np.asarray(jnp.take(t, piv, axis=1))
    np.testing.assert_allclose(sub, np.eye(8), atol=1e-5)


def test_row_interp_decomp():
    a = _lowrank(48, 64, 7).T  # (64, 48) rank 7, ID the rows of a 48x64... keep simple
    a = _lowrank(48, 64, 7)
    piv, p = idqr.row_interp_decomp(a, 10)
    rec = p @ jnp.take(a, piv, axis=0)
    err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert err < 1e-3


# --------------------------------------------------------------------- #
# adaptive (tolerance-driven) rank detection                            #
# --------------------------------------------------------------------- #
def _decaying(m, n, sigmas, seed=0):
    """Matrix with prescribed singular-value-like decay."""
    rng = np.random.default_rng(seed)
    r = len(sigmas)
    u, _ = np.linalg.qr(rng.normal(size=(m, r)))
    v, _ = np.linalg.qr(rng.normal(size=(n, r)))
    return jnp.asarray(u @ np.diag(sigmas) @ v.T, jnp.float32)


@pytest.mark.parametrize("true_rank", [3, 6, 10])
def test_ranked_detects_exact_numerical_rank(true_rank):
    """A matrix with exactly ``true_rank`` non-negligible directions is
    detected at that rank (cap 16) and reconstructed to the noise floor."""
    sigmas = [2.0 ** -i for i in range(true_rank)] + [1e-7] * 4
    a = _decaying(40, 32, sigmas)
    piv, t, rank = idqr.interp_decomp_ranked(a, 16, rtol=1e-4)
    assert int(rank) == true_rank, (int(rank), true_rank)
    rec = jnp.take(a, piv, axis=1) @ t
    err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert err < 1e-3, err
    # truncated rows of T are exact zeros -> column masks are exact
    assert float(jnp.abs(t[true_rank:]).max()) == 0.0


def test_ranked_rank_decreases_with_looser_rtol():
    """Monotone knob: looser tolerance => smaller detected rank, and the
    reconstruction error tracks the tolerance."""
    a = _decaying(64, 48, [3.0 ** -i for i in range(14)])
    prev_rank = 15
    for rtol in (1e-6, 1e-4, 1e-2, 1e-1):
        piv, t, rank = idqr.interp_decomp_ranked(a, 14, rtol=rtol)
        assert int(rank) <= prev_rank
        prev_rank = int(rank)
        rec = jnp.take(a, piv, axis=1) @ t
        err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
        assert err < 40 * rtol + 1e-5, (rtol, int(rank), err)
    assert prev_rank < 14  # 10% tolerance must actually truncate


def test_ranked_interpolates_truncated_pivots():
    """A truncated pivot's column must be interpolated by the live
    skeletons, NOT zeroed: zeroing drops the whole column, not just the
    below-tolerance residual (the bug this pins)."""
    a = _decaying(48, 36, [2.0 ** -i for i in range(12)])
    piv, t, rank = idqr.interp_decomp_ranked(a, 12, rtol=1e-2)
    assert int(rank) < 12
    dead = np.asarray(piv)[int(rank):]
    rec = np.asarray(jnp.take(a, piv, axis=1) @ t)
    a_n = np.asarray(a)
    col_err = np.linalg.norm(rec[:, dead] - a_n[:, dead], axis=0)
    col_nrm = np.linalg.norm(a_n[:, dead], axis=0)
    assert (col_err < 0.5 * col_nrm).all(), (col_err, col_nrm)


def test_ranked_padded_leaf_rank_deficient():
    """The padded-leaf case: rows/columns of inert (near-zero kernel)
    padding make the block rank-deficient — detection must not count the
    dead directions and everything must stay finite (the seed-era NaN)."""
    a_live = _lowrank(24, 18, 5, seed=3)
    a = jnp.zeros((24, 30), jnp.float32).at[:, :18].set(a_live)
    piv, t, rank = idqr.interp_decomp_ranked(a, 12, rtol=1e-5)
    assert bool(jnp.isfinite(t).all())
    assert int(rank) <= 6            # ~5 real directions, never the 12 cap
    rec = jnp.take(a, piv, axis=1) @ t
    err = float(jnp.linalg.norm(rec - a) / jnp.linalg.norm(a))
    assert err < 1e-3, err
    # row ID view: zero ROWS (pad points) keep zero interpolation weights
    piv_r, p, rank_r = idqr.row_interp_decomp_ranked(a.T, 12, rtol=1e-5)
    assert bool(jnp.isfinite(p).all())
    rec_r = p @ jnp.take(a.T, piv_r, axis=0)
    assert float(jnp.linalg.norm(rec_r - a.T) /
                 jnp.linalg.norm(a)) < 1e-3


def test_ranked_full_rank_matches_fixed():
    """On a full-rank-at-cap block the adaptive ID detects the cap and the
    fixed path's reconstruction quality is preserved."""
    a = _lowrank(40, 30, 10, noise=1e-3)
    piv_f, t_f = idqr.interp_decomp(a, 8)
    piv_a, t_a, rank = idqr.interp_decomp_ranked(a, 8, rtol=1e-4)
    assert int(rank) == 8
    np.testing.assert_array_equal(np.asarray(piv_f), np.asarray(piv_a))
    rec_f = jnp.take(a, piv_f, axis=1) @ t_f
    rec_a = jnp.take(a, piv_a, axis=1) @ t_a
    np.testing.assert_allclose(np.asarray(rec_a), np.asarray(rec_f),
                               rtol=1e-4, atol=1e-5)
