import time

import numpy as np
import pytest

from repro.dist import fault


def test_step_guard_passes_results():
    g = fault.StepGuard(deadline_s=5.0)
    assert g.run(0, lambda: 42) == 42


def test_step_guard_timeout():
    g = fault.StepGuard(deadline_s=0.1)
    with pytest.raises(fault.StepTimeout):
        g.run(0, lambda: time.sleep(1.0))


def test_step_guard_detects_straggler():
    g = fault.StepGuard(deadline_s=10.0, straggler_ratio=3.0)
    for i in range(6):
        g.run(i, lambda: time.sleep(0.02))
    g.run(6, lambda: time.sleep(0.25))
    assert len(g.stragglers) == 1
    assert g.stragglers[0].ratio > 3.0


def test_run_resilient_restarts_from_checkpoint():
    saved = {}

    def build():
        return {"x": 0.0}

    def step(state, i):
        return {"x": state["x"] + 1.0}

    def save(state, step_no):
        saved["state"], saved["step"] = dict(state), step_no

    def restore():
        if "state" in saved:
            return dict(saved["state"]), saved["step"]
        return None

    injector = fault.FailureInjector((7,))

    def guarded_step(state, i):
        injector.check(i)
        return step(state, i)

    final, report = fault.run_resilient(
        12, build, guarded_step, save, restore, ckpt_every=5,
        guard=fault.StepGuard(deadline_s=5.0))
    assert report["restarts"] == 1
    assert final["x"] == 12.0      # no steps lost or double-counted


def test_run_resilient_gives_up_after_max_restarts():
    def step(state, i):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        fault.run_resilient(
            3, lambda: {}, step, lambda s, i: None, lambda: None,
            max_restarts=2, guard=fault.StepGuard(deadline_s=5.0))


def test_failure_injector_fires_once():
    inj = fault.FailureInjector((2,))
    inj.check(1)
    with pytest.raises(fault.InjectedFailure):
        inj.check(2)
    inj.check(2)   # second pass after restart: no raise
