import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gaussian import ops as gops
from repro.kernels.gaussian.ref import gaussian_block_ref


@pytest.mark.parametrize("ma,mb,f", [
    (64, 64, 4), (128, 96, 8), (100, 130, 3), (256, 256, 128), (33, 257, 22),
])
@pytest.mark.parametrize("h", [0.5, 1.0, 10.0])
def test_gaussian_block_matches_ref(ma, mb, f, h):
    rng = np.random.default_rng(ma * mb + f)
    xa = jnp.asarray(rng.normal(size=(ma, f)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(mb, f)), jnp.float32)
    out = gops.gaussian_block(xa, xb, h, interpret=True)
    ref = gaussian_block_ref(xa, xb, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)


def test_gaussian_block_bf16():
    rng = np.random.default_rng(0)
    xa = jnp.asarray(rng.normal(size=(64, 8)), jnp.bfloat16)
    xb = jnp.asarray(rng.normal(size=(64, 8)), jnp.bfloat16)
    out = gops.gaussian_block(xa, xb, 1.0, interpret=True)
    ref = gaussian_block_ref(xa.astype(jnp.float32), xb.astype(jnp.float32), 1.0)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=0.05, atol=0.05)


def test_gaussian_symmetry_and_diag():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(96, 5)), jnp.float32)
    out = np.asarray(gops.gaussian_block(x, x, 2.0, interpret=True))
    np.testing.assert_allclose(out, out.T, atol=1e-6)
    np.testing.assert_allclose(np.diag(out), 1.0, atol=1e-6)


@pytest.mark.parametrize("ma,mb,f", [(1, 3, 2), (255, 129, 5), (300, 7, 11)])
def test_pallas_xla_parity_odd_shapes_f32(ma, mb, f):
    """Backend parity at odd / non-tile-aligned shapes: the padded+cropped
    Pallas path must agree with the XLA path, not just at MXU-friendly
    sizes."""
    from repro.core.kernelfn import gaussian_block_xla

    rng = np.random.default_rng(1000 * ma + mb)
    xa = jnp.asarray(rng.normal(size=(ma, f)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(mb, f)), jnp.float32)
    for h in (0.7, 3.0):
        out = gops.gaussian_block(xa, xb, h, interpret=True)
        ref = gaussian_block_xla(xa, xb, h)
        assert out.shape == (ma, mb)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("ma,mb,f", [(1, 3, 2), (255, 129, 5), (300, 7, 11)])
def test_pallas_xla_parity_odd_shapes_bf16(ma, mb, f):
    from repro.core.kernelfn import gaussian_block_xla

    rng = np.random.default_rng(2000 * ma + mb)
    xa = jnp.asarray(rng.normal(size=(ma, f)), jnp.bfloat16)
    xb = jnp.asarray(rng.normal(size=(mb, f)), jnp.bfloat16)
    out = gops.gaussian_block(xa, xb, 1.0, interpret=True)
    ref = gaussian_block_xla(xa.astype(jnp.float32), xb.astype(jnp.float32), 1.0)
    assert out.shape == (ma, mb)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=0.05, atol=0.05)


def test_core_dispatch_pallas_interpret():
    """KernelSpec(impl='pallas_interpret') must route through the kernel."""
    from repro.core.kernelfn import KernelSpec, kernel_block

    rng = np.random.default_rng(2)
    xa = jnp.asarray(rng.normal(size=(40, 6)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(52, 6)), jnp.float32)
    out = kernel_block(KernelSpec(h=1.5, impl="pallas_interpret"), xa, xb)
    ref = kernel_block(KernelSpec(h=1.5, impl="xla"), xa, xb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-6)
