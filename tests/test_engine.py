"""HSSSVMEngine: one code path for local and mesh-parallel training.

Fast tier: the local engine must reproduce the per-subsystem trainers
(binary + multiclass) and auto-detect the problem type.

Slow tier (8 emulated devices, subprocess like tests/test_dist.py): the
mesh-parallel build — compress_sharded / factorize_sharded — must match the
single-device build to <=1e-5 relative on solves, every O(N·m) artifact must
actually be sharded (never resident unsharded on one device), and the
1-device-mesh vs 8-device-mesh engines must train to matching results
end-to-end.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.core.multiclass import MulticlassHSSSVMTrainer
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

COMP = CompressionParams(rank=24, n_near=32, n_far=48)


def _run_sub(code: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


# --------------------------------------------------------------------- #
# fast tier: local engine vs the per-subsystem trainers                 #
# --------------------------------------------------------------------- #
def test_engine_local_binary_matches_trainer():
    xtr, ytr, xte, yte = synthetic.train_test("blobs", 1024, 256, seed=0,
                                              sep=1.6)
    kw = dict(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64, max_it=10)
    trainer = HSSSVMTrainer(**kw)
    ref_model = trainer.fit(xtr, ytr, c_value=1.0)
    engine = HSSSVMEngine(**kw)
    model = engine.fit(xtr, ytr, c_value=1.0)
    assert model.binary
    assert engine.n_problems == 1
    pred_ref = np.asarray(ref_model.predict(jnp.asarray(xte)))
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    # identical pipeline (same compression, factorization, ADMM): identical
    # predictions, not merely similar accuracy
    assert (pred == pred_ref).mean() > 0.99, (pred != pred_ref).sum()
    np.testing.assert_allclose(float(model.biases[0]), float(ref_model.bias),
                               rtol=1e-4, atol=1e-5)


def test_engine_local_multiclass_matches_trainer():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 1024, 256, seed=0, n_classes=4, sep=3.0)
    kw = dict(spec=KernelSpec(h=1.5), comp=COMP, leaf_size=64, max_it=10)
    ref = MulticlassHSSSVMTrainer(**kw).fit(xtr, ytr, c_value=1.0)
    engine = HSSSVMEngine(**kw)
    model = engine.fit(xtr, ytr, c_value=1.0)
    assert not model.binary
    assert engine.n_problems == 4
    pred_ref = np.asarray(ref.predict(jnp.asarray(xte)))
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    assert (pred == pred_ref).mean() > 0.99


def test_engine_train_grid_warm_start():
    xtr, ytr, xte, yte = synthetic.train_test("blobs", 512, 128, seed=1,
                                              sep=1.6)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=64,
                          max_it=10)
    engine.prepare(xtr, ytr)
    models = engine.train_grid([0.1, 1.0, 10.0])
    assert len(models) == 3
    accs = [float(jnp.mean(m.predict(jnp.asarray(xte)) == yte))
            for m in models]
    assert max(accs) > 0.85, accs
    # one compression, one factorization for the whole sweep
    assert engine.report.compression_s > 0
    assert engine.report.admm_s > 0


def test_engine_multilevel_warm_start_reduces_iters():
    """AML-SVM-style coarse->fine warm start: train on a stratified
    subsample, prolong the duals by nearest-skeleton interpolation (scaled
    by n_c/n_f — copied coarse duals are ~n_f/n_c too large, see
    tasks.prolong_scale), and finish with early-stopping ADMM.  The warm
    run must CONVERGE IN FEWER ITERATIONS than the cold run at matched
    holdout accuracy — the measured quantity the subsystem exists for."""
    from repro.core.compression import CompressionParams as CP

    xtr, ytr, xte, yte = synthetic.train_test("blobs", 2048, 256, seed=0,
                                              n_features=5, sep=3.0)
    engine = HSSSVMEngine(spec=KernelSpec(h=2.0), comp=CP.crude(),
                          leaf_size=128, beta=100.0, tol=3e-2, max_it=400)
    engine.prepare(xtr, ytr)
    m_cold, _ = engine.train(1.0)
    iters_cold = int(np.max(np.asarray(engine.report.iters_run)))
    acc_cold = float(jnp.mean(m_cold.predict(jnp.asarray(xte)) == yte))

    m_warm, info = engine.train_multilevel(1.0, coarse_frac=0.25,
                                           coarse_leaf_size=64, seed=0)
    iters_warm = int(np.max(np.asarray(info["iters_run"])))
    acc_warm = float(jnp.mean(m_warm.predict(jnp.asarray(xte)) == yte))

    assert iters_warm < iters_cold, (iters_warm, iters_cold)
    assert iters_cold < 400, "cold run hit the cap - tolerance unreachable"
    assert info["coarse_n"] < len(xtr) // 2
    assert abs(acc_warm - acc_cold) <= 0.01, (acc_warm, acc_cold)


def test_engine_ovo_strategy():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 512, 128, seed=0, n_classes=3, sep=3.0)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.5), comp=COMP, leaf_size=64,
                          max_it=10, strategy="ovo")
    model = engine.fit(xtr, ytr, c_value=1.0)
    assert engine.n_problems == 3          # 3 choose 2
    assert model.pairs is not None
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == jnp.asarray(yte)))
    assert acc > 0.9, acc


# --------------------------------------------------------------------- #
# slow tier: multi-device parity + sharding guarantees                  #
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_sharded_build_matches_local_build():
    """compress_sharded + factorize_sharded on 8 devices == local build:
    solve results to <=1e-5 relative, and every O(N·m) artifact sharded."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import compression, factorization, tree as tree_mod
        from repro.core.distributed import fac_shardings
        from repro.core.kernelfn import KernelSpec
        from repro.dist import api as dist_api

        rng = np.random.default_rng(0)
        n, leaf = 4096, 64
        x = rng.normal(size=(n, 4)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=leaf)
        xp = x[t.perm]
        spec = KernelSpec(h=1.0)
        params = compression.CompressionParams(rank=24, n_near=32, n_far=48)
        mesh = jax.make_mesh((8,), ("data",))

        hss_ref = compression.compress(jnp.asarray(xp), t, spec, params)
        fac_ref = factorization.factorize(hss_ref, 10.0)
        hss = compression.compress_sharded(xp, t, spec, params, mesh)
        fac = factorization.factorize_sharded(hss, 10.0, mesh)

        ndev = 8
        n_leaf = n // leaf
        # -- sharding guarantees: no unsharded O(N*m) / O(N*r) array --
        for name in ("d_leaf", "u_leaf", "x"):
            a = getattr(hss, name)
            assert not a.sharding.is_fully_replicated, name
            shard = a.addressable_shards[0].data.shape
            assert shard[0] == a.shape[0] // ndev, (name, shard, a.shape)
        for name in ("e_leaf", "g_leaf"):
            a = getattr(fac, name)
            assert not a.sharding.is_fully_replicated, name
            assert a.addressable_shards[0].data.shape[0] == n_leaf // ndev
        # factorization emitted already placed per fac_shardings (no
        # build-then-device_put round trip)
        want = fac_shardings(jax.eval_shape(lambda: fac), mesh)
        for a, s in zip(jax.tree.leaves(fac), jax.tree.leaves(want)):
            assert a.sharding.is_equivalent_to(s, a.ndim), (a.shape, s)

        # -- value parity: representation-level matvec and solve --
        v = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
        mv_ref = np.asarray(hss_ref.matmat(v))
        with dist_api.use_mesh(mesh), mesh:
            mv = np.asarray(jax.jit(lambda h, b: h.matmat(b))(hss, v))
            out = np.asarray(jax.jit(lambda f, b: f.solve_mat(b))(fac, v))
        ref = np.asarray(fac_ref.solve_mat(v))
        rel_mv = np.linalg.norm(mv - mv_ref) / np.linalg.norm(mv_ref)
        rel = np.linalg.norm(out - ref) / np.linalg.norm(ref)
        assert rel_mv <= 1e-5, rel_mv
        assert rel <= 1e-5, rel

        # -- non-f32 dtype: the sharded build must PRESERVE the caller's
        # dtype (it used to silently downcast everything to f32) and still
        # match the local bf16 build --
        xp_bf = jnp.asarray(xp, jnp.bfloat16)
        hss_bf_ref = compression.compress(xp_bf, t, spec, params)
        hss_bf = compression.compress_sharded(xp_bf, t, spec, params, mesh)
        for name in ("d_leaf", "u_leaf", "x"):
            got = getattr(hss_bf, name).dtype
            ref_dt = getattr(hss_bf_ref, name).dtype
            assert got == ref_dt == jnp.bfloat16, (name, got, ref_dt)
        # bf16 pivot ties may resolve differently between the eager local
        # and jitted sharded builds, so compare both against the EXACT
        # kernel matvec instead of against each other.
        from repro.core.kernelfn import gaussian_block_xla, kernel_matvec_streamed
        xf = xp_bf.astype(jnp.float32)
        vb = v.astype(jnp.bfloat16)
        ref_bf = np.asarray(kernel_matvec_streamed(spec, xf, xf, v))
        mv_lo = np.asarray(hss_bf_ref.matmat(vb), np.float32)
        with dist_api.use_mesh(mesh), mesh:
            mv_sh = np.asarray(
                jax.jit(lambda h, b: h.matmat(b))(hss_bf, vb), np.float32)
        rel_lo = np.linalg.norm(mv_lo - ref_bf) / np.linalg.norm(ref_bf)
        rel_sh = np.linalg.norm(mv_sh - ref_bf) / np.linalg.norm(ref_bf)
        assert rel_lo <= 0.35 and rel_sh <= 0.35, (rel_lo, rel_sh)
        assert abs(rel_lo - rel_sh) <= 0.05, (rel_lo, rel_sh)
        print("BUILD_PARITY_OK", rel_mv, rel, rel_lo, rel_sh)
    """)
    r = _run_sub(code)
    assert "BUILD_PARITY_OK" in r.stdout, r.stdout + r.stderr


def test_sharded_build_preserves_dtype_bf16():
    """Fast leg of the dtype-preservation fix: under a 1-device mesh the
    sharded build keeps bf16 end-to-end (no silent f32 downcast) and agrees
    with the local bf16 build."""
    import jax

    from repro.core import compression, tree as tree_mod
    from repro.core.kernelfn import KernelSpec

    rng = np.random.default_rng(5)
    n, leaf = 256, 32
    x = rng.normal(size=(n, 4)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=leaf)
    xp_bf = jnp.asarray(x[t.perm], jnp.bfloat16)
    spec = KernelSpec(h=1.0)
    params = compression.CompressionParams(rank=16, n_near=16, n_far=16)
    mesh = jax.make_mesh((1,), ("data",))
    hss_lo = compression.compress(xp_bf, t, spec, params)
    hss_sh = compression.compress_sharded(xp_bf, t, spec, params, mesh)
    for name in ("d_leaf", "u_leaf", "x"):
        got = getattr(hss_sh, name).dtype
        assert got == getattr(hss_lo, name).dtype == jnp.bfloat16, (name, got)
    # bf16 pivot selection is tie-prone (the sampled blocks only carry ~3
    # significant digits), so eager-local and jitted-sharded builds may pick
    # different — equally valid — skeletons.  Parity at bf16 therefore means
    # BOTH builds approximate the exact kernel equally well, not that they
    # are bitwise equal.
    from repro.core.kernelfn import gaussian_block_xla

    xf = xp_bf.astype(jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    ref = np.asarray(gaussian_block_xla(xf, xf, 1.0) @ v)
    rels = {}
    for name, h in (("local", hss_lo), ("sharded", hss_sh)):
        mv = np.asarray(h.matmat(v.astype(jnp.bfloat16)), np.float32)
        rels[name] = np.linalg.norm(mv - ref) / np.linalg.norm(ref)
    assert rels["local"] <= 0.35 and rels["sharded"] <= 0.35, rels
    assert abs(rels["local"] - rels["sharded"]) <= 0.05, rels


@pytest.mark.slow
def test_engine_end_to_end_1_vs_8_devices():
    """The engine trains identically under a 1-device and an 8-device mesh
    (and matches the meshless local path), with sharded iterates/model."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.compression import CompressionParams
        from repro.core.engine import HSSSVMEngine
        from repro.core.kernelfn import KernelSpec
        from repro.data import synthetic

        xtr, ytr, xte, yte = synthetic.train_test(
            "blobs", 4096, 512, seed=0, n_features=6, sep=1.6)
        kw = dict(spec=KernelSpec(h=1.0),
                  comp=CompressionParams(rank=24, n_near=32, n_far=48),
                  leaf_size=64, max_it=10, beta=100.0)

        def fit(mesh):
            eng = HSSSVMEngine(mesh=mesh, **kw)
            model = eng.fit(xtr, ytr, c_value=1.0)
            scores = np.asarray(model.decision_function(jnp.asarray(xte)))
            acc = float(np.mean(np.where(scores >= 0, 1, -1) == yte))
            return eng, model, scores, acc

        eng1, m1, s1, acc1 = fit(jax.make_mesh((1,), ("data",)))
        eng8, m8, s8, acc8 = fit(jax.make_mesh((8,), ("data",)))
        eng0, m0, s0, acc0 = fit(None)

        # 8-device model is genuinely sharded
        assert not m8.z_y.sharding.is_fully_replicated
        assert m8.z_y.addressable_shards[0].data.shape[0] == m8.z_y.shape[0] // 8
        assert not eng8.hss.d_leaf.sharding.is_fully_replicated

        rel18 = (np.linalg.norm(s1 - s8) /
                 max(np.linalg.norm(s1), 1e-30))
        rel08 = (np.linalg.norm(s0 - s8) /
                 max(np.linalg.norm(s0), 1e-30))
        assert rel18 <= 1e-5, rel18
        assert rel08 <= 1e-4, rel08            # meshless path: same math,
        assert acc1 == acc8, (acc1, acc8)      # different partitioning
        assert abs(acc0 - acc8) <= 0.004, (acc0, acc8)
        print("ENGINE_PARITY_OK", rel18, rel08, acc8)
    """)
    r = _run_sub(code)
    assert "ENGINE_PARITY_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_engine_multiclass_8_devices():
    """k-class engine under the mesh: sharded (d, P) iterates, accuracy
    matching the local multiclass trainer."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.compression import CompressionParams
        from repro.core.engine import HSSSVMEngine
        from repro.core.kernelfn import KernelSpec
        from repro.core.multiclass import MulticlassHSSSVMTrainer
        from repro.data import synthetic

        xtr, ytr, xte, yte = synthetic.train_test(
            "multiclass_blobs", 2048, 512, seed=0, n_classes=4, sep=3.0)
        kw = dict(spec=KernelSpec(h=1.5),
                  comp=CompressionParams(rank=24, n_near=32, n_far=48),
                  leaf_size=64, max_it=10)
        ref = MulticlassHSSSVMTrainer(**kw).fit(xtr, ytr, c_value=1.0)
        acc_ref = float(jnp.mean(ref.predict(jnp.asarray(xte))
                                 == jnp.asarray(yte)))
        mesh = jax.make_mesh((8,), ("data",))
        eng = HSSSVMEngine(mesh=mesh, **kw)
        model = eng.fit(xtr, ytr, c_value=1.0)
        assert model.z_y.shape[1] == 4
        assert not model.z_y.sharding.is_fully_replicated
        acc = float(jnp.mean(model.predict(jnp.asarray(xte))
                             == jnp.asarray(yte)))
        assert abs(acc - acc_ref) <= 0.01, (acc, acc_ref)
        print("MC_ENGINE_OK", acc, acc_ref)
    """)
    r = _run_sub(code)
    assert "MC_ENGINE_OK" in r.stdout, r.stdout + r.stderr
