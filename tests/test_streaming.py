"""Streamed out-of-core HSS build (compression.compress_streamed).

Fast tier: batching-parity against the resident build (exact skeletons,
1e-5 matvec/solve), peak-device-bytes bounded by the batch size and flat in
N, checkpointed kill-and-resume (in-process restart budget AND a fresh call
against the same directory) producing BIT-IDENTICAL output, fingerprint
rejection of foreign checkpoints, host assembly, and the engine end-to-end.

Slow tier (8 emulated devices, subprocess like tests/test_dist.py): the
mesh-assembled streamed build feeds factorize_sharded and matches the local
resident pipeline's solve.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, factorization, tree as tree_mod
from repro.core.compression import (CompressionParams, StreamParams,
                                    compress, compress_streamed)
from repro.core.kernelfn import KernelSpec
from repro.dist.fault import FailureInjector, InjectedFailure

SPEC = KernelSpec(h=1.5)


def _problem(n=512, f=4, leaf=32, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, f)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=leaf)
    return x[t.perm], t


def _params(adaptive):
    return CompressionParams(rank=12, n_near=16, n_far=16,
                             rtol=1e-3 if adaptive else None)


def _assert_bit_identical(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for u, v in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# --------------------------------------------------------------------- #
# parity vs the resident build                                          #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("adaptive", [False, True])
@pytest.mark.parametrize("batch_leaves", [1, 3, 64])
def test_streamed_matches_resident(adaptive, batch_leaves):
    """Same points reach the same seams in the same order: skeletons are
    EXACT (integer ids), floats agree to matvec tolerance — at batch sizes
    that divide the leaf count, exceed it, and straddle it (3 on 16)."""
    xp, t = _problem()
    params = _params(adaptive)
    ref = compress(xp, t, SPEC, params)
    hss, stats = compress_streamed(
        xp, t, SPEC, params, stream=StreamParams(batch_leaves=batch_leaves))
    np.testing.assert_array_equal(np.asarray(hss.skel_leaf),
                                  np.asarray(ref.skel_leaf))
    for got, want in zip(hss.skels, ref.skels):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    v = np.asarray(
        np.random.default_rng(1).normal(size=(t.n, 3)), np.float32)
    mv_ref = np.asarray(ref.matmat(jnp.asarray(v)))
    mv = np.asarray(hss.matmat(jnp.asarray(v)))
    np.testing.assert_allclose(mv, mv_ref, rtol=1e-5, atol=1e-5)
    assert stats.peak_stream_bytes > 0
    assert stats.n_batches > 0
    assert stats.resumed_level is None and stats.restarts == 0


def test_streamed_solve_matches_resident():
    """The factorization consumes the streamed build unchanged."""
    xp, t = _problem()
    params = _params(True)
    ref = compress(xp, t, SPEC, params)
    hss, _ = compress_streamed(xp, t, SPEC, params,
                               stream=StreamParams(batch_leaves=4))
    v = jnp.asarray(
        np.random.default_rng(2).normal(size=(t.n, 2)), jnp.float32)
    s_ref = np.asarray(factorization.factorize(ref, 4.0).solve_mat(v))
    s = np.asarray(factorization.factorize(hss, 4.0).solve_mat(v))
    np.testing.assert_allclose(s, s_ref, rtol=1e-5, atol=1e-5)


def test_streamed_peak_bytes_batch_bounded_and_flat_in_n():
    """The measured peak grows with batch_leaves but NOT with N — the
    out-of-core claim in its two directions."""
    params = _params(False)
    peaks = {}
    for bl in (2, 32):
        xp, t = _problem(n=512)
        _, stats = compress_streamed(xp, t, SPEC, params,
                                     stream=StreamParams(batch_leaves=bl))
        peaks[bl] = stats.peak_stream_bytes
    assert peaks[2] < peaks[32], peaks
    xp2, t2 = _problem(n=2048, seed=3)
    _, stats2 = compress_streamed(xp2, t2, SPEC, params,
                                  stream=StreamParams(batch_leaves=2))
    # 4x the data, same batch: the peak is the same batch-shaped footprint
    # (root-level candidate counts differ by at most the level geometry)
    assert stats2.peak_stream_bytes <= int(1.05 * peaks[2]), (
        stats2.peak_stream_bytes, peaks[2])


def test_streamed_host_assembly_matches_device():
    xp, t = _problem()
    params = _params(False)
    dev, _ = compress_streamed(xp, t, SPEC, params,
                               stream=StreamParams(batch_leaves=8))
    host, _ = compress_streamed(
        xp, t, SPEC, params,
        stream=StreamParams(batch_leaves=8, assemble="host"))
    assert isinstance(host.d_leaf, np.ndarray)
    _assert_bit_identical(jax.tree.map(jnp.asarray, host), dev)


def test_streamed_rejects_flat_tree():
    xp, t = _problem(n=32, leaf=32)
    assert t.levels == 0
    with pytest.raises(ValueError, match="at least one tree level"):
        compress_streamed(xp, t, SPEC, _params(False))


# --------------------------------------------------------------------- #
# checkpointed resume                                                   #
# --------------------------------------------------------------------- #
def test_streamed_kill_and_resume_bit_identical(tmp_path):
    """An injected failure mid-build restores from the level checkpoint and
    finishes with output bit-identical to the uninterrupted build."""
    xp, t = _problem(n=1024, leaf=32)        # 5 levels -> failure at level 2
    params = _params(True)
    ref, _ = compress_streamed(xp, t, SPEC, params,
                               stream=StreamParams(batch_leaves=8))
    inj = FailureInjector(fail_at=(2,))
    hss, stats = compress_streamed(
        xp, t, SPEC, params,
        stream=StreamParams(batch_leaves=8, ckpt_dir=str(tmp_path)),
        on_level=inj.check)
    _assert_bit_identical(hss, ref)
    assert stats.restarts == 1
    assert stats.resumed_level == 2
    assert stats.checkpointed_levels >= 2


def test_streamed_fresh_call_resumes_from_directory(tmp_path):
    """With the restart budget exhausted the failure propagates; a FRESH
    call pointed at the same directory resumes at the last completed level
    instead of recomputing, and still matches bit-for-bit."""
    xp, t = _problem(n=1024, leaf=32)
    params = _params(False)
    ref, _ = compress_streamed(xp, t, SPEC, params,
                               stream=StreamParams(batch_leaves=8))
    inj = FailureInjector(fail_at=(3,))
    with pytest.raises(InjectedFailure):
        compress_streamed(
            xp, t, SPEC, params,
            stream=StreamParams(batch_leaves=8, ckpt_dir=str(tmp_path),
                                max_restarts=0),
            on_level=inj.check)
    hss, stats = compress_streamed(
        xp, t, SPEC, params,
        stream=StreamParams(batch_leaves=8, ckpt_dir=str(tmp_path)))
    _assert_bit_identical(hss, ref)
    assert stats.resumed_level == 3
    assert stats.restarts == 0


def test_streamed_foreign_checkpoint_ignored(tmp_path):
    """A checkpoint whose fingerprint (here: kernel bandwidth) does not
    match the requested build is ignored, not resumed into garbage."""
    xp, t = _problem(n=1024, leaf=32)
    params = _params(False)
    sp = StreamParams(batch_leaves=8, ckpt_dir=str(tmp_path))
    compress_streamed(xp, t, SPEC, params, stream=sp)
    other = KernelSpec(h=7.0)
    ref, _ = compress_streamed(xp, t, other, params,
                               stream=StreamParams(batch_leaves=8))
    hss, stats = compress_streamed(xp, t, other, params, stream=sp)
    assert stats.resumed_level is None
    _assert_bit_identical(hss, ref)


# --------------------------------------------------------------------- #
# engine end-to-end                                                     #
# --------------------------------------------------------------------- #
def test_engine_streamed_end_to_end():
    from repro.core.engine import HSSSVMEngine
    from repro.data import synthetic

    xtr, ytr, xte, yte = synthetic.train_test("blobs", 1024, 256, seed=0,
                                              sep=1.6)
    kw = dict(spec=KernelSpec(h=1.0),
              comp=CompressionParams(rank=16, n_near=16, n_far=24),
              leaf_size=64, max_it=10)
    resident = HSSSVMEngine(**kw)
    m_res = resident.fit(xtr, ytr, c_value=1.0)
    streamed = HSSSVMEngine(**kw, stream=StreamParams(batch_leaves=4))
    m_str = streamed.fit(xtr, ytr, c_value=1.0)
    pred_res = np.asarray(m_res.predict(jnp.asarray(xte)))
    pred_str = np.asarray(m_str.predict(jnp.asarray(xte)))
    # same skeletons, same factorization, same ADMM: same predictions
    assert (pred_res == pred_str).mean() > 0.99
    assert streamed.report.peak_stream_bytes > 0
    assert streamed.report.stream_batches > 0
    assert resident.report.peak_stream_bytes is None


# --------------------------------------------------------------------- #
# slow tier: mesh-assembled streamed build on 8 emulated devices        #
# --------------------------------------------------------------------- #
def _run_sub(code: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.mark.slow
def test_streamed_mesh_assembly_subprocess():
    code = """
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.core import compression, factorization, tree as tree_mod
from repro.core.compression import CompressionParams, StreamParams
from repro.core.kernelfn import KernelSpec

assert jax.device_count() == 8
rng = np.random.default_rng(0)
x = rng.normal(size=(2048, 4)).astype(np.float32)
t = tree_mod.build_tree(x, leaf_size=64)
xp = x[t.perm]
spec = KernelSpec(h=1.5)
params = CompressionParams(rank=12, n_near=16, n_far=16, rtol=1e-3)
mesh = jax.make_mesh((8,), ("data",))

ref = compression.compress(xp, t, spec, params)
hss, stats = compression.compress_streamed(
    xp, t, spec, params, stream=StreamParams(batch_leaves=8), mesh=mesh)
np.testing.assert_array_equal(np.asarray(hss.skel_leaf),
                              np.asarray(ref.skel_leaf))
assert not hss.d_leaf.sharding.is_fully_replicated, "leaf blocks replicated"

v = jnp.asarray(rng.normal(size=(t.n, 2)), jnp.float32)
s_ref = np.asarray(factorization.factorize(ref, 4.0).solve_mat(v))
fac = factorization.factorize_sharded(hss, 4.0, mesh)
s = np.asarray(fac.solve_mat(v))
# sharded vs local factorization reduce in different orders: a few 1e-4s
# of float drift on top of the (exact-skeleton) streamed build parity
np.testing.assert_allclose(s, s_ref, rtol=1e-3, atol=5e-4)
print("STREAMED_MESH_OK", stats.peak_stream_bytes)
"""
    r = _run_sub(code)
    assert r.returncode == 0, r.stderr[-4000:]
    assert "STREAMED_MESH_OK" in r.stdout
