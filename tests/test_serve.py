"""Serving-tier tests: parity, the shared-factorization cache, batching.

The synthetic ``EngineModel``s here skip training on purpose — scoring is
a pure function of (x_perm, z_y, biases, spec), so random coefficients
exercise every decode path at zero build cost.  The one trained model
(``trained_binary``) is reserved for the tests that need real dual
structure (the warm C-sweep shared-cache proof)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineModel
from repro.core.kernelfn import DEFAULT_SCORE_BLOCK, KernelSpec
from repro.serve import BatchPolicy, ServingEngine, batched_scores

TASKS = ("binary", "ovr", "ovo", "svr", "oneclass", "krr", "gp")


def mk_model(task="binary", d=96, f=4, h=1.3, beta=64.0, seed=0):
    """A synthetic EngineModel of the given task shape (no training)."""
    r = np.random.default_rng(seed)
    x = r.normal(size=(d, f)).astype(np.float32)
    n_prob = 3 if task in ("ovr", "ovo") else 1
    zy = (0.3 * r.normal(size=(d, n_prob))).astype(np.float32)
    biases = (0.1 * r.normal(size=n_prob)).astype(np.float32)
    classes = (np.arange(3.0, dtype=np.float32) if n_prob == 3
               else np.array([-1.0, 1.0], np.float32))
    pairs = (np.array([[0, 1], [0, 2], [1, 2]], np.int32)
             if task == "ovo" else None)
    return EngineModel(
        x_perm=jnp.asarray(x), z_y=jnp.asarray(zy),
        biases=jnp.asarray(biases), classes=classes,
        spec=KernelSpec(h=h), c_value=1.0,
        binary=task == "binary",
        strategy="ovo" if task == "ovo" else "ovr",
        task=task if task in ("svr", "oneclass", "krr", "gp") else "svm",
        pairs=pairs, beta=beta)


def _queries(model, n=37, seed=1):
    r = np.random.default_rng(seed)
    return r.normal(size=(n, model.x_perm.shape[1])).astype(np.float32)


# --------------------------------------------------------------------- #
# scoring parity                                                         #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("task", TASKS)
def test_f32_parity_bit_identical(task):
    """The engine's f32 tick path IS kernel_matvec_streamed: scores and
    predictions must equal the model's own predict bit for bit."""
    model = mk_model(task, seed=3)
    engine = ServingEngine()
    mid = engine.add_model(model)
    xq = _queries(model)
    scores, preds = engine.score(mid, xq)
    ref_s = np.asarray(model.decision_function(jnp.asarray(xq)))
    ref_p = np.asarray(model.predict(jnp.asarray(xq)))
    assert scores.shape == ref_s.shape
    assert np.array_equal(scores, ref_s)
    assert np.array_equal(preds, ref_p)


# pinned bf16 tolerance: block kernel evaluated from bf16 operands with f32
# accumulation — relative score error is bounded by a few bf16 ulps (~0.4%)
# times the kernel-sum conditioning; 2e-2 absolute on O(1) scores holds with
# ~4x margin on these problems (see measured maxima in test body asserts).
BF16_ATOL = 2e-2


@pytest.mark.parametrize("task", TASKS)
def test_bf16_parity_tolerance(task):
    model = mk_model(task, seed=5)
    f32 = ServingEngine()
    b16 = ServingEngine(policy=BatchPolicy(compute_dtype="bfloat16"))
    i32, i16 = f32.add_model(model), b16.add_model(model)
    xq = _queries(model, n=64)
    s32, p32 = f32.score(i32, xq)
    s16, p16 = b16.score(i16, xq)
    np.testing.assert_allclose(s16, s32, atol=BF16_ATOL)
    # decisions may legitimately flip only within the tolerance band of a
    # decision boundary; away from it they must agree
    if task in ("svr", "krr", "gp"):
        np.testing.assert_allclose(p16, p32, atol=BF16_ATOL)
    else:
        margin = (np.min(np.abs(s32), axis=-1) if s32.ndim > 1
                  else np.abs(s32))
        clear = margin > BF16_ATOL
        assert np.array_equal(np.asarray(p16)[clear],
                              np.asarray(p32)[clear])


def test_bf16_path_has_no_downcast_accumulators():
    """Dogfood repro.analysis on the batched score function itself: the
    bf16 path must accumulate every contraction in f32 and stay
    callback-free (satellite of the PR 3 precision convention)."""
    from repro.analysis import jaxpr_check

    model = mk_model("ovr")
    xq = jnp.asarray(_queries(model, n=16))
    for dt in ("float32", "bfloat16"):
        jaxpr = jax.make_jaxpr(
            lambda q, s, z, b: batched_scores(
                q, s, z, b, spec=model.spec, block=8, compute_dtype=dt)
        )(xq, model.x_perm, model.z_y, model.biases)
        assert jaxpr_check.dtype_downcasts(jaxpr) == []
        assert jaxpr_check.host_callbacks(jaxpr) == []


def test_laplacian_kernel_serves_too():
    model = dataclasses.replace(
        mk_model("binary"), spec=KernelSpec(name="laplacian", h=1.5))
    engine = ServingEngine()
    mid = engine.add_model(model)
    xq = _queries(model)
    scores, _ = engine.score(mid, xq)
    ref = np.asarray(model.decision_function(jnp.asarray(xq)))
    assert np.array_equal(scores, ref)


# --------------------------------------------------------------------- #
# the shared-factorization cache                                         #
# --------------------------------------------------------------------- #
def test_same_factorization_models_share_one_cache_entry(trained_binary):
    """k models off one warm C-sweep (same compression+factorization ⇒
    same (h, β, support set)) must occupy exactly ONE device-resident
    cache entry: one support upload, one launch scoring all of them."""
    eng, _, xq, _ = trained_binary
    models = eng.train_grid([0.5, 1.0, 2.0])
    serve = ServingEngine()
    ids = [serve.add_model(m) for m in models]
    assert serve.stats()["groups"] == 1

    tickets = [serve.submit(i, xq) for i in ids]
    assert serve.flush() == len(ids)
    st = serve.stats()
    assert st["cache_entries"] == 1
    assert st["support_uploads"] == 1          # k models, ONE upload
    assert st["launches"] == 1                 # k models, ONE kernel pass
    # the memory proof: resident bytes = one support copy, not k
    xs = np.asarray(jax.device_get(models[0].x_perm))
    assert st["resident_support_bytes"] == xs.nbytes
    group = serve.model_group(ids[0])
    assert all(serve.model_group(i) is group for i in ids)

    for t, m in zip(tickets, models):
        scores, preds = t.result(timeout=0)
        assert np.array_equal(scores,
                              np.asarray(m.decision_function(jnp.asarray(xq))))
        assert np.array_equal(preds, np.asarray(m.predict(jnp.asarray(xq))))


def test_distinct_bandwidths_do_not_share():
    a = mk_model("binary", seed=1, h=1.0)
    b = dataclasses.replace(a, spec=KernelSpec(h=2.0))
    serve = ServingEngine()
    serve.add_model(a), serve.add_model(b)
    assert serve.stats()["groups"] == 2


def test_lru_eviction_drops_device_state_only():
    serve = ServingEngine(max_resident=1)
    ia = serve.add_model(mk_model("binary", seed=1, h=1.0))
    ib = serve.add_model(mk_model("binary", seed=2, h=2.0))
    xq = _queries(mk_model("binary"))
    ra1 = serve.score(ia, xq)
    rb = serve.score(ib, xq)            # evicts a's device arrays
    st = serve.stats()
    assert st["cache_entries"] == 1 and st["evictions"] == 1
    ra2 = serve.score(ia, xq)           # transparent re-upload, b evicted
    st = serve.stats()
    assert st["support_uploads"] == 3 and st["evictions"] == 2
    assert np.array_equal(ra1[0], ra2[0])
    assert rb[0].shape == ra1[0].shape


# --------------------------------------------------------------------- #
# dynamic batching                                                       #
# --------------------------------------------------------------------- #
def test_tick_deinterleaves_mixed_requests():
    """Requests of different sizes and different same-group models in one
    tick come back correctly sliced per request and per model."""
    base = mk_model("ovr", seed=7)
    other = dataclasses.replace(           # same group: same spec/beta/xs
        base, z_y=base.z_y * 0.5, biases=base.biases + 1.0)
    serve = ServingEngine()
    i1, i2 = serve.add_model(base), serve.add_model(other)
    reqs = [(i1, _queries(base, n=5, seed=21)),
            (i2, _queries(base, n=17, seed=22)),
            (i1, _queries(base, n=1, seed=23)),
            (i2, _queries(base, n=30, seed=24))]
    tickets = [serve.submit(i, q) for i, q in reqs]
    assert serve.flush() == 4
    assert serve.stats()["launches"] == 1      # one pass for the whole tick
    for (mid, q), t in zip(reqs, tickets):
        m = base if mid == i1 else other
        scores, preds = t.result(timeout=0)
        assert np.array_equal(
            scores, np.asarray(m.decision_function(jnp.asarray(q))))
        assert np.array_equal(preds, np.asarray(m.predict(jnp.asarray(q))))


def test_occupancy_pads_to_buckets_one_compile_each():
    model = mk_model("binary", d=64)
    serve = ServingEngine(policy=BatchPolicy(buckets=(16, 64), block=32))
    mid = serve.add_model(model)
    for occ in (1, 3, 7, 11, 16, 20, 40, 64):
        serve.score(mid, _queries(model, n=occ, seed=occ))
    compiles = serve.scorer_compiles()
    assert compiles is None or compiles == 2, (
        f"8 occupancies over 2 buckets compiled {compiles}x")


def test_oversize_tick_chunks_at_top_bucket():
    model = mk_model("binary", d=64)
    serve = ServingEngine(policy=BatchPolicy(buckets=(16, 32), block=32))
    mid = serve.add_model(model)
    xq = _queries(model, n=70)              # 3 chunks: 32 + 32 + pad(6->16)
    scores, _ = serve.score(mid, xq)
    ref = np.asarray(model.decision_function(jnp.asarray(xq)))
    assert np.array_equal(scores, ref)
    assert serve.stats()["launches"] == 3


def test_max_batch_triggers_tick_without_flush():
    model = mk_model("binary", d=64)
    serve = ServingEngine(policy=BatchPolicy(max_batch=8, buckets=(16,)))
    mid = serve.add_model(model)
    t1 = serve.submit(mid, _queries(model, n=4, seed=1))
    assert not t1.done
    t2 = serve.submit(mid, _queries(model, n=4, seed=2))  # hits max_batch
    assert t1.done and t2.done


def test_threaded_driver_resolves_without_manual_flush():
    model = mk_model("binary", d=64)
    serve = ServingEngine(policy=BatchPolicy(max_wait_ms=1.0))
    mid = serve.add_model(model)
    serve.start()
    try:
        tickets = [serve.submit(mid, _queries(model, n=3, seed=s))
                   for s in range(5)]
        for t in tickets:
            scores, preds = t.result(timeout=10.0)
            assert scores.shape == (3,)
    finally:
        serve.stop()
    assert not serve.running


# --------------------------------------------------------------------- #
# decode details                                                         #
# --------------------------------------------------------------------- #
def test_ovo_host_decode_matches_device_vote():
    """The tick's numpy OVO decode must replicate multiclass.ovo_vote's
    tie-break (votes + 1e-3·tanh(margin)) exactly."""
    from repro.core.multiclass import ovo_vote
    from repro.serve.engine import _ovo_vote_np

    r = np.random.default_rng(9)
    pairs = np.array([[a, b] for a in range(4) for b in range(a + 1, 4)],
                     np.int32)
    scores = r.normal(size=(50, pairs.shape[0])).astype(np.float32)
    # include exact-tie rows (all-zero scores) and near-tie rows
    scores[0] = 0.0
    scores[1, :] = 1e-6
    dev = np.asarray(ovo_vote(jnp.asarray(scores), pairs, 4))
    host = _ovo_vote_np(scores, pairs, 4)
    assert np.array_equal(dev, host)


def test_block_kwarg_is_one_shared_constant():
    """Satellite: every predict/score path defaults to the ONE streaming
    block constant."""
    import inspect

    from repro.core.kernelfn import kernel_matvec_streamed
    from repro.core.multiclass import MulticlassSVMModel
    from repro.core.svm import SVMModel

    for fn in (SVMModel.predict, SVMModel.decision_function,
               MulticlassSVMModel.predict,
               MulticlassSVMModel.decision_function,
               EngineModel.predict, EngineModel.decision_function):
        assert inspect.signature(fn).parameters["block"].default \
            == DEFAULT_SCORE_BLOCK, fn
    assert inspect.signature(kernel_matvec_streamed).parameters[
        "block"].default == DEFAULT_SCORE_BLOCK
    assert BatchPolicy().block == DEFAULT_SCORE_BLOCK
