"""Golden regression tests: pinned training-quality numbers on fixed seeds.

Solver refactors (solve sweeps, ADMM updates, compression sampling) must not
silently regress convergence.  These pins were measured on the CPU backend
(binary/multiclass when the multiclass subsystem landed, SVR/one-class when
the box-QP task layer landed), with deliberate margin:

  binary blobs  (n=1024, seed 0): acc 0.953, dual_res 30.3 -> 21.3 over 10 it
  4-class blobs (n=1024, seed 0): acc 0.949, primal_res[-1] < 0.012/class
  SVR noisy sine (n=1024, seed 0, noise 0.1): rmse 0.0981 (the noise floor)
  one-class blobs+outliers (n=1024, seed 0, ν=0.1): precision 0.758,
    recall 0.980 on the seed-1 holdout

A failure here means convergence behaviour changed — inspect the solver diff
before touching the pins.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core.compression import CompressionParams
from repro.core.engine import HSSSVMEngine
from repro.core.kernelfn import KernelSpec
from repro.core.multiclass import MulticlassHSSSVMTrainer
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def test_golden_binary_accuracy_and_residual_decay():
    xtr, ytr, xte, yte = synthetic.train_test("blobs", 1024, 256, seed=0,
                                              sep=1.6)
    trainer = HSSSVMTrainer(spec=KernelSpec(h=1.0), comp=COMP,
                            leaf_size=128, max_it=10)
    trainer.prepare(xtr, ytr)
    model, _ = trainer.train(1.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    assert acc >= 0.93, acc                       # measured 0.9531

    fac, y, mask = trainer._fac, trainer._y, trainer._cmask
    _, trace = admm_mod.admm_svm(fac.solve, y, 1.0 * mask, fac.beta, max_it=10)
    primal = np.asarray(trace.primal_res)
    dual = np.asarray(trace.dual_res)
    assert primal[-1] < 0.05, primal              # measured 0.0
    # dual residual must decay (small slack for reduction-order noise
    # across backends) and by a pinned factor
    assert np.all(np.diff(dual) < 1e-3), dual     # measured 30.27 -> 21.27
    assert dual[-1] < 23.0, dual
    assert dual[-1] / dual[0] < 0.78, dual        # measured ratio 0.703


def test_golden_multiclass_accuracy_and_residual_decay():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 1024, 256, seed=0, n_classes=4, sep=3.0)
    trainer = MulticlassHSSSVMTrainer(spec=KernelSpec(h=1.5), comp=COMP,
                                      leaf_size=128, max_it=10)
    trainer.prepare(xtr, ytr)
    model, _ = trainer.train(1.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == jnp.asarray(yte)))
    assert acc >= 0.92, acc                       # measured 0.9492

    fac, ys, pmask = trainer._fac, trainer._ys, trainer._pmask
    _, trace = admm_mod.admm_svm_batched(
        fac.solve_mat, ys, 1.0 * pmask, fac.beta, max_it=10)
    primal = np.asarray(trace.primal_res)         # (10, 4)
    dual = np.asarray(trace.dual_res)
    assert np.all(primal[-1] < 0.05), primal[-1]  # measured <= 0.0113
    assert np.all(dual[-1] < 18.0), dual[-1]      # measured <= 14.58
    assert np.all(dual[-1] < dual[0]), (dual[0], dual[-1])


def test_golden_svr_rmse_noisy_sine():
    """ε-SVR on the engine must recover the sine to the noise floor."""
    xtr, ytr, xte, yte = synthetic.train_test("noisy_sine", 1024, 256,
                                              seed=0, noise=0.1)
    engine = HSSSVMEngine(spec=KernelSpec(h=1.0), comp=COMP, leaf_size=128,
                          max_it=30, task="svr", svr_c=2.0, beta=10.0)
    engine.prepare(xtr, ytr)
    model, _ = engine.train(0.1)
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    rmse = float(np.sqrt(np.mean((pred - yte) ** 2)))
    assert rmse < 0.12, rmse                      # measured 0.0981
    # the ε tube keeps a sparse dual: most coefficients soft-thresholded out
    sv_frac = float(np.mean(np.abs(np.asarray(model.z_y)) > 1e-5))
    assert sv_frac < 0.8, sv_frac


def test_golden_oneclass_precision_recall_blobs_with_outliers():
    """ν one-class SVM must separate the planted outlier shell."""
    xtr, _ = synthetic.blobs_with_outliers(1024, n_features=4,
                                           outlier_frac=0.1, seed=0)
    xte, yte = synthetic.blobs_with_outliers(512, n_features=4,
                                             outlier_frac=0.1, seed=1)
    engine = HSSSVMEngine(spec=KernelSpec(h=2.0), comp=COMP, leaf_size=128,
                          max_it=30, task="oneclass")
    engine.prepare(xtr)
    model, _ = engine.train(0.1)
    pred = np.asarray(model.predict(jnp.asarray(xte)))
    flagged = pred < 0
    precision = (flagged & (yte < 0)).sum() / max(flagged.sum(), 1)
    recall = (flagged & (yte < 0)).sum() / max((yte < 0).sum(), 1)
    assert precision >= 0.65, precision           # measured 0.758
    assert recall >= 0.90, recall                 # measured 0.980
