"""Golden regression tests: pinned training-quality numbers on fixed seeds.

Solver refactors (solve sweeps, ADMM updates, compression sampling) must not
silently regress convergence.  These pins were measured on the CPU backend
at the time the multiclass subsystem landed, with deliberate margin:

  binary blobs  (n=1024, seed 0): acc 0.953, dual_res 30.3 -> 21.3 over 10 it
  4-class blobs (n=1024, seed 0): acc 0.949, primal_res[-1] < 0.012/class

A failure here means convergence behaviour changed — inspect the solver diff
before touching the pins.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admm as admm_mod
from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.multiclass import MulticlassHSSSVMTrainer
from repro.core.svm import HSSSVMTrainer
from repro.data import synthetic

COMP = CompressionParams(rank=32, n_near=48, n_far=64)


def test_golden_binary_accuracy_and_residual_decay():
    xtr, ytr, xte, yte = synthetic.train_test("blobs", 1024, 256, seed=0,
                                              sep=1.6)
    trainer = HSSSVMTrainer(spec=KernelSpec(h=1.0), comp=COMP,
                            leaf_size=128, max_it=10)
    trainer.prepare(xtr, ytr)
    model, _ = trainer.train(1.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    assert acc >= 0.93, acc                       # measured 0.9531

    fac, y, mask = trainer._fac, trainer._y, trainer._cmask
    _, trace = admm_mod.admm_svm(fac.solve, y, 1.0 * mask, fac.beta, max_it=10)
    primal = np.asarray(trace.primal_res)
    dual = np.asarray(trace.dual_res)
    assert primal[-1] < 0.05, primal              # measured 0.0
    # dual residual must decay (small slack for reduction-order noise
    # across backends) and by a pinned factor
    assert np.all(np.diff(dual) < 1e-3), dual     # measured 30.27 -> 21.27
    assert dual[-1] < 23.0, dual
    assert dual[-1] / dual[0] < 0.78, dual        # measured ratio 0.703


def test_golden_multiclass_accuracy_and_residual_decay():
    xtr, ytr, xte, yte = synthetic.train_test(
        "multiclass_blobs", 1024, 256, seed=0, n_classes=4, sep=3.0)
    trainer = MulticlassHSSSVMTrainer(spec=KernelSpec(h=1.5), comp=COMP,
                                      leaf_size=128, max_it=10)
    trainer.prepare(xtr, ytr)
    model, _ = trainer.train(1.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == jnp.asarray(yte)))
    assert acc >= 0.92, acc                       # measured 0.9492

    fac, ys, pmask = trainer._fac, trainer._ys, trainer._pmask
    _, trace = admm_mod.admm_svm_batched(
        fac.solve_mat, ys, 1.0 * pmask, fac.beta, max_it=10)
    primal = np.asarray(trace.primal_res)         # (10, 4)
    dual = np.asarray(trace.dual_res)
    assert np.all(primal[-1] < 0.05), primal[-1]  # measured <= 0.0113
    assert np.all(dual[-1] < 18.0), dual[-1]      # measured <= 14.58
    assert np.all(dual[-1] < dual[0]), (dual[0], dual[-1])
