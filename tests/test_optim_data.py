"""Optimizer + data-pipeline coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optim


def _quadratic_problem(seed=0, dim=32):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(dim, dim))
    a = jnp.asarray(a @ a.T / dim + np.eye(dim), jnp.float32)
    b = jnp.asarray(rng.normal(size=dim), jnp.float32)

    def loss(params):
        x = params["x"]
        return 0.5 * x @ a @ x - b @ x

    return loss, {"x": jnp.zeros(dim, jnp.float32)}


def test_adamw_decreases_quadratic():
    loss, params = _quadratic_problem()
    cfg = optim.AdamWConfig(lr=5e-2, weight_decay=0.0)
    state = optim.adamw_init(params, cfg)
    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = optim.adamw_update(grads, state, params, cfg)
    assert float(loss(params)) < l0 - 1.0


def test_adamw_bf16_moments_close_to_f32():
    loss, params = _quadratic_problem(1)
    outs = {}
    for mdt in ("float32", "bfloat16"):
        cfg = optim.AdamWConfig(lr=3e-2, weight_decay=0.0, moment_dtype=mdt)
        p, s = dict(params), optim.adamw_init(params, cfg)
        for _ in range(100):
            g = jax.grad(loss)(p)
            p, s = optim.adamw_update(g, s, p, cfg)
        outs[mdt] = float(loss(p))
    assert abs(outs["bfloat16"] - outs["float32"]) < \
        0.05 * abs(outs["float32"]) + 0.05


def test_adamw_grad_clip_bounds_update():
    loss, params = _quadratic_problem(2)
    cfg = optim.AdamWConfig(lr=1e-2, grad_clip=1e-6, weight_decay=0.0)
    state = optim.adamw_init(params, cfg)
    grads = jax.tree.map(lambda p: jnp.full_like(p, 1e6), params)
    p2, _ = optim.adamw_update(grads, state, params, cfg)
    delta = float(optim.global_norm(jax.tree.map(lambda a, b: a - b,
                                                 params, p2)))
    assert delta < 1.0   # clip kept the step bounded despite huge grads


def test_adafactor_decreases_quadratic():
    loss, params = _quadratic_problem(3)
    params = {"w": jnp.zeros((16, 16), jnp.float32)}
    rng = np.random.default_rng(3)
    target = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)

    def mloss(p):
        return jnp.sum((p["w"] - target) ** 2)

    state = optim.adafactor_init(params)
    l0 = float(mloss(params))
    for _ in range(300):
        g = jax.grad(mloss)(params)
        params, state = optim.adafactor_update(g, state, params, lr=5e-2)
    assert float(mloss(params)) < 0.2 * l0


def test_token_stream_deterministic_and_host_sharded():
    from repro.data.tokens import TokenStream

    ts = TokenStream(vocab=1000, global_batch=8, seq_len=16, seed=7)
    a = ts.batch_at(3)
    b = ts.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # host slice is a view of the same global batch
    half = ts.batch_at(3, host_slice=slice(4, 8))
    np.testing.assert_array_equal(half["tokens"], a["tokens"][4:8])
    # labels are next-token shifted
    c = ts.batch_at(4)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_batch_for_config_modalities():
    from repro.configs import get_config
    from repro.data.tokens import batch_for_config

    for arch in ("hubert-xlarge", "paligemma-3b", "gemma2-9b"):
        cfg = get_config(arch).reduced()
        b = batch_for_config(cfg, 2, 32, 0)
        assert "labels" in b
        if cfg.frontend == "audio_stub":
            assert b["frames"].shape == (2, 32, cfg.frontend_dim)
        if cfg.frontend == "vision_stub":
            assert b["patches"].shape[1] == cfg.n_prefix_tokens


def test_laplacian_kernel_svm():
    """The kernel abstraction supports non-Gaussian PD kernels end to end."""
    from repro.core.kernelfn import KernelSpec, kernel_block
    import jax.scipy.linalg as jsl
    from repro.core import admm as admm_mod
    from tests.conftest import make_blobs

    x, y = make_blobs(128, seed=9)
    xj, yj = jnp.asarray(x), jnp.asarray(y)
    k = kernel_block(KernelSpec(name="laplacian", h=1.0), xj, xj)
    # PD check + ADMM run
    evals = jnp.linalg.eigvalsh(k + 1e-4 * jnp.eye(128))
    assert float(evals.min()) > 0
    chol = jsl.cholesky(k + 10.0 * jnp.eye(128), lower=True)
    state, _ = admm_mod.admm_svm(
        lambda b: jsl.cho_solve((chol, True), b), yj, 1.0, 10.0, max_it=10)
    scores = k @ (yj * state.z)
    acc = float(jnp.mean(jnp.where(scores >= 0, 1, -1) == yj))
    assert acc > 0.9


def test_laplacian_pallas_impl_dispatches_without_warning():
    """Pins kernel_block's laplacian+pallas behavior: the request now
    dispatches to the real Pallas laplacian kernel (repro.kernels.compress)
    with NO warning (it used to warn-and-fall-back), matches the XLA path,
    and unknown impl strings still raise instead of silently running XLA."""
    import warnings

    import pytest

    from repro.core.kernelfn import (
        KernelSpec, kernel_block, laplacian_block_xla)

    rng = np.random.default_rng(11)
    xa = jnp.asarray(rng.normal(size=(12, 4)), jnp.float32)
    xb = jnp.asarray(rng.normal(size=(9, 4)), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = kernel_block(
            KernelSpec(name="laplacian", impl="pallas_interpret", h=1.3),
            xa, xb)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(laplacian_block_xla(xa, xb, 1.3)),
        rtol=1e-6, atol=1e-6)
    # the xla path must not warn either
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        kernel_block(KernelSpec(name="laplacian", impl="xla", h=1.3), xa, xb)
    with pytest.raises(ValueError, match="unknown kernel impl"):
        kernel_block(KernelSpec(name="gaussian", impl="cuda", h=1.3), xa, xb)


def test_laplacian_block_chunked_matches_broadcast():
    """The feature-chunked laplacian_block_xla == the naive (ma, mb, f)
    broadcast, across feature counts off/on/below the chunk boundary."""
    from repro.core.kernelfn import laplacian_block_xla

    rng = np.random.default_rng(4)
    for f in (1, 3, 16, 17, 40):
        xa = jnp.asarray(rng.normal(size=(33, f)), jnp.float32)
        xb = jnp.asarray(rng.normal(size=(21, f)), jnp.float32)
        ref = jnp.exp(
            -jnp.sum(jnp.abs(xa[:, None, :] - xb[None, :, :]), -1) / 1.7)
        out = laplacian_block_xla(xa, xb, 1.7)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
        out5 = laplacian_block_xla(xa, xb, 1.7, f_chunk=5)
        np.testing.assert_allclose(np.asarray(out5), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
