import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, tree as tree_mod
from repro.core.kernelfn import KernelSpec, gaussian_block_xla
from tests.conftest import make_blobs


def _build(n=512, leaf=64, rank=32, h=1.0, seed=0, n_features=4,
           n_near=64, n_far=128):
    x, y = make_blobs(n, n_features=n_features, seed=seed)
    t = tree_mod.build_tree(x, leaf_size=leaf)
    xp = jnp.asarray(x[t.perm])
    spec = KernelSpec(h=h)
    params = compression.CompressionParams(
        rank=rank, n_near=n_near, n_far=n_far, seed=seed)
    hss = compression.compress(xp, t, spec, params)
    k_dense = gaussian_block_xla(xp, xp, h)
    return hss, k_dense, xp, spec


def test_dense_reconstruction_error_small():
    hss, k_dense, _, _ = _build()
    rec = hss.todense()
    err = float(jnp.linalg.norm(rec - k_dense) / jnp.linalg.norm(k_dense))
    assert err < 6e-2, err


def test_rank_increases_accuracy():
    errs = []
    for rank in (8, 24, 48):
        hss, k_dense, _, _ = _build(rank=rank)
        rec = hss.todense()
        errs.append(float(jnp.linalg.norm(rec - k_dense) / jnp.linalg.norm(k_dense)))
    assert errs[0] > errs[1] > errs[2] or errs[2] < 1e-3


def test_matvec_matches_todense():
    hss, _, _, _ = _build(n=256, leaf=32, rank=16)
    v = jnp.asarray(np.random.default_rng(0).normal(size=256), jnp.float32)
    dense = hss.todense()
    np.testing.assert_allclose(
        np.asarray(hss.matvec(v)), np.asarray(dense @ v), rtol=2e-4, atol=2e-4
    )


def test_matvec_against_exact_kernel():
    hss, k_dense, _, _ = _build()
    v = jnp.asarray(np.random.default_rng(1).normal(size=hss.n), jnp.float32)
    approx = hss.matvec(v)
    exact = k_dense @ v
    rel = float(jnp.linalg.norm(approx - exact) / jnp.linalg.norm(exact))
    assert rel < 8e-2, rel


def test_matmat():
    hss, _, _, _ = _build(n=256, leaf=32, rank=16)
    v = jnp.asarray(np.random.default_rng(2).normal(size=(256, 3)), jnp.float32)
    out = hss.matmat(v)
    for j in range(3):
        np.testing.assert_allclose(
            np.asarray(out[:, j]), np.asarray(hss.matvec(v[:, j])),
            rtol=1e-4, atol=1e-4,
        )


def test_native_matmat_equals_columnwise_matvec():
    """Regression for the native multi-RHS telescoping sweep: the (N, k)
    matmat must match k column-wise matvecs to 1e-6."""
    hss, _, _, _ = _build(n=512, leaf=64, rank=24)
    v = jnp.asarray(np.random.default_rng(9).normal(size=(512, 5)), jnp.float32)
    out = hss.matmat(v)
    cols = jnp.stack([hss.matvec(v[:, j]) for j in range(5)], axis=1)
    # 2e-6 absolute: f32 reduction-order noise between the c=1 and c=k sweeps
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(cols), rtol=1e-6, atol=2e-6)


def test_shifted_adds_identity():
    hss, _, _, _ = _build(n=256, leaf=32, rank=16)
    v = jnp.ones(256, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(hss.shifted(3.0).matvec(v)),
        np.asarray(hss.matvec(v) + 3.0 * v),
        rtol=1e-5,
    )


def test_symmetry_of_reconstruction():
    hss, _, _, _ = _build(n=256, leaf=32, rank=16)
    d = np.asarray(hss.todense())
    np.testing.assert_allclose(d, d.T, atol=1e-5)


def test_memory_linear_in_n():
    hss_small, _, _, _ = _build(n=256, leaf=32, rank=16)
    hss_big, _, _, _ = _build(n=1024, leaf=32, rank=16)
    ratio = hss_big.memory_bytes() / hss_small.memory_bytes()
    assert ratio < 5.0  # O(N r): 4x data -> ~4x memory, NOT 16x (dense)


def test_compression_error_probe():
    hss, k_dense, xp, spec = _build()
    err = float(compression.compression_error(hss, spec, n_probe=4))
    assert err < 8e-2


def test_leaf_near_deficit_topup_has_no_duplicates():
    """Regression: on tiny problems the KD-tree candidate pool runs short and
    the deficit top-up used to sample the sibling leaf WITH possible repeats
    of already-placed candidates — duplicate NEAR proxies waste ID sample
    budget.  Each row must now be duplicate-free whenever the leaf's
    complement has at least n_near points, and never contain in-leaf points."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        m, levels = 8, 2                       # n = 32, n_near = 8
        n = m * 2 ** levels
        x = rng.normal(size=(n, 2)).astype(np.float32)
        t = tree_mod.build_tree(x, leaf_size=m)
        params = compression.CompressionParams(rank=4, n_near=8, n_far=4,
                                               seed=seed)
        near = compression._host_leaf_near(t, params, x[t.perm])
        assert near.shape == (2 ** levels, params.n_near)
        leaf_of = np.arange(n) // m
        for i in range(near.shape[0]):
            row = near[i]
            assert len(np.unique(row)) == len(row), (seed, i, row)
            assert not np.any(leaf_of[row] == i), (seed, i, row)


def test_leaf_near_data_free_fallback_shapes():
    """The data-free (x=None) fallback keeps its sibling-sampling contract."""
    rng = np.random.default_rng(0)
    m, levels = 16, 2
    x = rng.normal(size=(m * 2 ** levels, 3)).astype(np.float32)
    t = tree_mod.build_tree(x, leaf_size=m)
    params = compression.CompressionParams(rank=8, n_near=8, n_far=8)
    near = compression._host_leaf_near(t, params, None)
    for i in range(near.shape[0]):
        sib = i ^ 1
        assert np.all((near[i] >= sib * m) & (near[i] < (sib + 1) * m))
