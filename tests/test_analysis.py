"""repro.analysis: AST rules on seeded fixtures, baseline machinery, the
CLI, and the trace-level (jaxpr) checks.

Fast tier: every rule catches exactly its fixture's ``# VIOLATION`` lines
and nothing else; the repo itself lints clean modulo the baseline; the
jaxpr walkers flag a seeded bf16 accumulation; a warm-started 4-point
C-grid on the engine compiles the ADMM run exactly once.

Slow tier (8 emulated devices, subprocess like tests/test_engine.py): the
mesh-placement check passes — factors land per fac_shardings, the matmat /
solve graphs carry node_partition_spec-conformant pins.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis import jaxpr_check
from repro.analysis.__main__ import main as cli_main
from repro.analysis.findings import Finding
from repro.analysis.lint import lint_file, lint_paths, repo_root
from repro.analysis.rules import ALL_RULES

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "analysis_fixtures")


def _fixture(name: str):
    """(findings, expected ``# VIOLATION`` line numbers) for one fixture."""
    path = os.path.join(FIXTURES, name)
    findings = lint_file(path, f"tests/analysis_fixtures/{name}",
                         explicit=True)
    with open(path, encoding="utf-8") as fh:
        expected = {i for i, line in enumerate(fh, 1) if "# VIOLATION" in line}
    return findings, expected


# --------------------------------------------------------------------- #
# layer 1: each rule catches its seeded fixture, exactly                 #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name,rule", [
    ("viol_precision.py", "precision-accumulate"),
    ("viol_host_sync.py", "host-sync-in-traced"),
    ("viol_retrace.py", "retrace-knob"),
    ("viol_prng.py", "prng-key-reuse"),
    ("viol_tracer_branch.py", "python-branch-on-tracer"),
])
def test_rule_catches_seeded_fixture(name, rule):
    findings, expected = _fixture(name)
    assert expected, f"{name} has no # VIOLATION markers"
    assert {f.line for f in findings} == expected, \
        [f.render() for f in findings]
    assert all(f.rule == rule for f in findings), \
        [f.rule for f in findings]


def test_clean_fixture_has_no_findings():
    findings, _ = _fixture("clean.py")
    assert findings == [], [f.render() for f in findings]


def test_inline_disable_suppresses():
    findings, _ = _fixture("suppressed.py")
    assert findings == [], [f.render() for f in findings]
    # the same line WITHOUT the comment is caught (the disable is load-bearing)
    src_path = os.path.join(FIXTURES, "suppressed.py")
    with open(src_path, encoding="utf-8") as fh:
        assert "lint: disable=precision-accumulate" in fh.read()


def test_rule_registry_is_complete():
    names = {r.NAME for r in ALL_RULES}
    assert names == {"precision-accumulate", "host-sync-in-traced",
                     "retrace-knob", "prng-key-reuse",
                     "python-branch-on-tracer"}
    for r in ALL_RULES:
        assert r.DESCRIPTION and r.SCOPE


def test_repo_lints_clean_modulo_baseline():
    """The whole source tree is clean after this change; the baseline
    carries any justified exceptions (none today)."""
    findings = lint_paths(base=repo_root())
    entries = baseline_mod.load()
    new, _suppressed, stale = baseline_mod.partition(findings, entries)
    assert new == [], [f.render() for f in new]
    assert stale == [], stale


# --------------------------------------------------------------------- #
# baseline file machinery                                               #
# --------------------------------------------------------------------- #
def test_baseline_roundtrip_and_partition(tmp_path):
    f1 = Finding("precision-accumulate", "src/repro/x.py", 3, "m",
                 'c = jnp.einsum("ij,jk->ik", a, b)')
    f2 = Finding("prng-key-reuse", "src/repro/y.py", 9, "m",
                 "b = jax.random.normal(key, (4,))")
    path = str(tmp_path / "baseline.toml")
    entries = baseline_mod.from_findings([f1], reason="bench-only path")
    baseline_mod.dump(entries, path)
    loaded = baseline_mod.load(path)
    assert loaded == entries
    new, suppressed, stale = baseline_mod.partition([f1, f2], loaded)
    assert new == [f2] and suppressed == [f1] and stale == []
    # a stale entry (nothing matches it any more) is reported
    _, _, stale = baseline_mod.partition([f2], loaded)
    assert len(stale) == 1


def test_baseline_requires_reason(tmp_path):
    path = str(tmp_path / "baseline.toml")
    with open(path, "w", encoding="utf-8") as fh:
        fh.write('[[suppress]]\nrule = "r"\npath = "p"\n'
                 'line_content = "x = 1"\n')
    with pytest.raises(ValueError, match="reason"):
        baseline_mod.load(path)


# --------------------------------------------------------------------- #
# CLI                                                                   #
# --------------------------------------------------------------------- #
def test_cli_exit_codes(capsys):
    bad = os.path.join(FIXTURES, "viol_precision.py")
    clean = os.path.join(FIXTURES, "clean.py")
    assert cli_main([clean]) == 0
    assert "clean" in capsys.readouterr().out
    assert cli_main([bad]) == 1
    out = capsys.readouterr().out
    assert "precision-accumulate" in out and "2 finding(s)" in out
    assert cli_main(["--rules"]) == 0
    assert "prng-key-reuse" in capsys.readouterr().out


def test_cli_write_baseline(tmp_path, capsys):
    bad = os.path.join(FIXTURES, "viol_precision.py")
    path = str(tmp_path / "baseline.toml")
    assert cli_main([bad, "--write-baseline", "--baseline", path]) == 0
    capsys.readouterr()
    # the generated baseline suppresses exactly those findings
    assert cli_main([bad, "--baseline", path]) == 0
    assert "2 suppressed" in capsys.readouterr().out


# --------------------------------------------------------------------- #
# layer 2: jaxpr walkers + the recompile guard                          #
# --------------------------------------------------------------------- #
def test_dtype_downcast_walker_flags_bf16_accumulation():
    a = jnp.zeros((8, 8), jnp.bfloat16)

    def unprotected(x, y):
        return x @ y                      # bf16 accumulator

    def protected(x, y):
        return jax.lax.dot(x, y, preferred_element_type=jnp.float32)

    assert jaxpr_check.dtype_downcasts(jax.make_jaxpr(unprotected)(a, a))
    assert not jaxpr_check.dtype_downcasts(jax.make_jaxpr(protected)(a, a))


def test_dtype_downcast_walker_recurses_into_scan():
    a = jnp.zeros((4, 8, 8), jnp.bfloat16)

    def run(xs):
        def body(c, x):
            return c @ x, ()              # bf16 accumulation inside scan
        return jax.lax.scan(body, xs[0], xs[1:])

    assert jaxpr_check.dtype_downcasts(jax.make_jaxpr(run)(a))


def test_host_callback_walker():
    def with_cb(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    jaxpr = jax.make_jaxpr(with_cb)(jnp.zeros(3))
    assert jaxpr_check.host_callbacks(jaxpr)
    assert not jaxpr_check.host_callbacks(
        jax.make_jaxpr(lambda x: x * 2)(jnp.zeros(3)))


def test_abstract_signature_scalar_semantics():
    sig = jaxpr_check.abstract_signature
    # traced-scalar convention: identical signatures across the sweep
    assert (sig(jnp.asarray(0.5, jnp.float32))
            == sig(jnp.asarray(4.0, jnp.float32)))
    # a mixed int/float Python grid changes the weak dtype => retrace
    assert sig(1) != sig(1.0)


def test_engine_c_sweep_compiles_once():
    """The recompile-count guard: 4 grid points, ONE compile (PR 5)."""
    findings = jaxpr_check.check_recompile_engine()
    assert findings == [], [f.render() for f in findings]


# --------------------------------------------------------------------- #
# slow tier: mesh placement under 8 emulated devices                    #
# --------------------------------------------------------------------- #
@pytest.mark.slow
def test_mesh_placement_check_passes_on_8_devices():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        from repro.analysis import jaxpr_check
        findings = jaxpr_check.check_mesh_placement()
        for f in findings:
            print(f.render())
        assert not findings
        print("MESH_PLACEMENT_OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "MESH_PLACEMENT_OK" in r.stdout, r.stdout + r.stderr
