import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, factorization, tree as tree_mod
from repro.core.kernelfn import KernelSpec
from tests.conftest import make_blobs


def _hss(n=512, leaf=64, rank=24, h=1.0, seed=0):
    x, _ = make_blobs(n, seed=seed)
    t = tree_mod.build_tree(x, leaf_size=leaf)
    xp = jnp.asarray(x[t.perm])
    spec = KernelSpec(h=h)
    hss = compression.compress(
        xp, t, spec, compression.CompressionParams(rank=rank, n_near=32, n_far=32)
    )
    return hss


@pytest.mark.parametrize("beta", [1.0, 10.0, 100.0])
def test_solve_matches_dense(beta):
    hss = _hss()
    fac = factorization.factorize(hss, beta)
    dense = hss.todense() + beta * jnp.eye(hss.n)
    b = jnp.asarray(np.random.default_rng(0).normal(size=hss.n), jnp.float32)
    x_hss = fac.solve(b)
    x_dense = jnp.linalg.solve(dense, b)
    rel = float(jnp.linalg.norm(x_hss - x_dense) / jnp.linalg.norm(x_dense))
    assert rel < 1e-3, rel


def test_solve_is_inverse_of_matvec():
    hss = _hss(n=256, leaf=32, rank=16)
    beta = 10.0
    fac = factorization.factorize(hss, beta)
    b = jnp.asarray(np.random.default_rng(1).normal(size=hss.n), jnp.float32)
    x = fac.solve(b)
    b_back = hss.matvec(x) + beta * x
    rel = float(jnp.linalg.norm(b_back - b) / jnp.linalg.norm(b))
    assert rel < 1e-3, rel


def test_solve_mat_multiple_rhs():
    hss = _hss(n=256, leaf=32, rank=16)
    fac = factorization.factorize(hss, 5.0)
    b = jnp.asarray(np.random.default_rng(2).normal(size=(256, 3)), jnp.float32)
    xs = fac.solve_mat(b)
    for j in range(3):
        np.testing.assert_allclose(
            np.asarray(xs[:, j]), np.asarray(fac.solve(b[:, j])),
            rtol=1e-4, atol=1e-5,
        )


def test_native_multi_rhs_equals_columnwise_single_rhs():
    """Regression for the native (N, k) block sweep: the multi-RHS solve must
    reproduce k column-wise single-RHS solves to 1e-6 (the vmap path it
    replaced was exact column-wise by construction)."""
    hss = _hss(n=512, leaf=64, rank=24)
    for beta in (1.0, 100.0):
        fac = factorization.factorize(hss, beta)
        b = jnp.asarray(
            np.random.default_rng(7).normal(size=(512, 6)), jnp.float32)
        block = factorization.hss_solve_mat(fac, b)
        cols = jnp.stack(
            [factorization.hss_solve(fac, b[:, j]) for j in range(6)], axis=1)
        np.testing.assert_allclose(
            np.asarray(block), np.asarray(cols), rtol=1e-6, atol=1e-6)


def test_two_level_tree():
    # K = 1: only leaves + root coupling — exercises the boundary case.
    hss = _hss(n=128, leaf=64, rank=24)
    assert hss.levels == 1
    fac = factorization.factorize(hss, 2.0)
    dense = hss.todense() + 2.0 * jnp.eye(128)
    b = jnp.ones(128, jnp.float32)
    rel = float(
        jnp.linalg.norm(fac.solve(b) - jnp.linalg.solve(dense, b))
        / jnp.linalg.norm(jnp.linalg.solve(dense, b))
    )
    assert rel < 1e-3


def test_factorize_jits_and_caches():
    """factorize + solve must be jittable (the paper's ADMM loop requirement)."""
    hss = _hss(n=256, leaf=32, rank=16)
    fac = factorization.factorize(hss, 7.0)

    @jax.jit
    def solve(b):
        return fac.solve(b)

    b = jnp.ones(256, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(solve(b)), np.asarray(fac.solve(b)), rtol=1e-5
    )


def test_woodbury_identity_lemma():
    """The Gillman–Martinsson inversion lemma on random SPD data."""
    rng = np.random.default_rng(3)
    m, r = 24, 6
    d = rng.normal(size=(m, m))
    d = jnp.asarray(d @ d.T + m * np.eye(m), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m, r)), jnp.float32)
    a_tilde = rng.normal(size=(r, r))
    a_tilde = jnp.asarray(a_tilde + a_tilde.T, jnp.float32)
    a_full = d + u @ a_tilde @ u.T

    dinv = jnp.linalg.inv(d)
    d_hat = jnp.linalg.inv(u.T @ dinv @ u)
    e = dinv @ u @ d_hat
    g = dinv - e @ (dinv @ u).T
    a_inv_lemma = g + e @ jnp.linalg.inv(a_tilde + d_hat) @ e.T
    np.testing.assert_allclose(
        np.asarray(a_inv_lemma), np.asarray(jnp.linalg.inv(a_full)),
        rtol=5e-3, atol=5e-4,
    )


@pytest.mark.parametrize("beta", [10.0, 100.0])
def test_bf16_storage_solve_accuracy(beta):
    """store_dtype='bfloat16' must stay within bf16 rounding of the f32
    solve: every per-level einsum pins preferred_element_type=float32, so
    the only error source is factor STORAGE rounding (~1e-2), never bf16
    accumulation (which would be ~1e-1 at these depths).  Regression for
    the mixed-precision accumulation contract."""
    hss = _hss(n=1024, leaf=64, rank=24)
    fac32 = factorization.factorize(hss, beta)
    fac16 = factorization.factorize(hss, beta, store_dtype="bfloat16")
    assert fac16.e_leaf.dtype == jnp.bfloat16
    assert fac16.root_lu.dtype == jnp.float32    # root stays f32
    b = jnp.asarray(
        np.random.default_rng(0).normal(size=(hss.n, 3)), jnp.float32)
    x32 = fac32.solve_mat(b)
    x16 = fac16.solve_mat(b)
    assert x16.dtype == jnp.float32              # f32 accumulation contract
    rel = float(jnp.linalg.norm(x16 - x32) / jnp.linalg.norm(x32))
    assert rel < 1e-2, rel                       # measured ~3.3e-3


def test_bf16_inputs_bias_extraction_accuracy():
    """compute_bias_batched keeps f32 accumulation when its inputs arrive
    bf16: the bias einsums pin preferred_element_type=float32, so the only
    error vs the f32 path is INPUT rounding (~1e-2), never the ~1e-1 drift
    of a bf16 accumulator over d≈1000 terms.  Pins the core/svm.py fix;
    the jaxpr assertion proves no contraction anywhere in the bias graph
    accumulates below f32."""
    from repro.analysis import jaxpr_check
    from repro.core.svm import compute_bias_batched

    hss = _hss(n=1024, leaf=64, rank=24)
    d = hss.n
    rng = np.random.default_rng(5)
    ys = jnp.asarray(np.sign(rng.normal(size=(d, 1))), jnp.float32)
    z = jnp.asarray(rng.uniform(0.05, 0.95, size=(d, 1)), jnp.float32)
    ones = jnp.ones((d, 1), jnp.float32)
    b32 = compute_bias_batched(hss, ys, z, ones, ones)

    ys16, z16 = ys.astype(jnp.bfloat16), z.astype(jnp.bfloat16)
    ones16 = ones.astype(jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda y_, z_, m_: compute_bias_batched(
        hss, y_, z_, m_, m_))(ys16, z16, ones16)
    assert jaxpr_check.dtype_downcasts(jaxpr) == []
    b16 = compute_bias_batched(hss, ys16, z16, ones16, ones16)
    rel = float(jnp.abs(b16 - b32)[0] / jnp.maximum(jnp.abs(b32)[0], 1e-6))
    assert rel < 5e-2, rel


def test_bf16_storage_admm_no_downcast_and_accuracy():
    """The full ADMM graph (solve + equality projection + box clamp) over a
    bf16-STORED factorization: (a) its jaxpr contains no low-precision
    dot_general accumulator — pins the core/admm.py eq-projection fix and
    the solve chain together; (b) the iterates stay within bf16 storage
    rounding of the f32 run."""
    from repro.analysis import jaxpr_check
    from repro.core import admm as admm_mod

    hss = _hss(n=512, leaf=64, rank=24)
    fac32 = factorization.factorize(hss, 10.0)
    fac16 = factorization.factorize(hss, 10.0, store_dtype="bfloat16")
    rng = np.random.default_rng(4)
    ys = jnp.asarray(np.sign(rng.normal(size=(1, 512))), jnp.float32)
    cbox = jnp.ones((1, 512), jnp.float32)

    def run(fac):
        task = admm_mod.svm_task(ys, cbox)
        state, _ = admm_mod.admm_boxqp(fac.solve_mat, task, fac.beta, 8)
        return state.z

    jaxpr = jax.make_jaxpr(lambda f_: run(f_))(fac16)
    assert jaxpr_check.dtype_downcasts(jaxpr) == [], \
        jaxpr_check.dtype_downcasts(jaxpr)
    z32, z16 = run(fac32), run(fac16)
    rel = float(jnp.linalg.norm(z16 - z32) / jnp.linalg.norm(z32))
    assert rel < 2e-2, rel
