import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines
from repro.core.compression import CompressionParams
from repro.core.kernelfn import KernelSpec
from repro.core.svm import HSSSVMTrainer, grid_search
from tests.conftest import make_blobs


def _train_test(n_train=1000, n_test=400, seed=0, sep=1.6, n_features=4):
    x, y = make_blobs(n_train + n_test, n_features=n_features, seed=seed, sep=sep)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]


def test_hss_svm_end_to_end_accuracy():
    xtr, ytr, xte, yte = _train_test()
    trainer = HSSSVMTrainer(
        spec=KernelSpec(h=1.0),
        comp=CompressionParams(rank=32, n_near=64, n_far=96),
        leaf_size=128, max_it=10,
    )
    model = trainer.fit(xtr, ytr, c_value=1.0)
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    assert acc > 0.9, acc


def test_hss_matches_dense_exact_kernel_accuracy():
    """Paper's central claim (Tables 2 vs 4/5): approx kernel ≈ exact accuracy."""
    xtr, ytr, xte, yte = _train_test(n_train=512, n_test=256)
    spec = KernelSpec(h=1.0)
    # dense exact-kernel ADMM reference
    z, bias = baselines.dense_admm_fit(
        jnp.asarray(xtr), jnp.asarray(ytr), spec, c_value=1.0, beta=100.0,
        max_it=10)
    pred_dense = baselines.dense_predict(
        jnp.asarray(xtr), jnp.asarray(ytr), z, bias, spec, jnp.asarray(xte))
    acc_dense = float(jnp.mean(pred_dense == yte))
    # HSS
    trainer = HSSSVMTrainer(
        spec=spec, comp=CompressionParams(rank=32, n_near=64, n_far=96),
        leaf_size=64, max_it=10)
    model = trainer.fit(xtr, ytr, c_value=1.0)
    acc_hss = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    assert acc_hss > acc_dense - 0.03, (acc_hss, acc_dense)


def test_padding_is_inert():
    """Non-power-of-two dataset: pads must not change predictions materially."""
    xtr, ytr, xte, yte = _train_test(n_train=600, n_test=200)  # pads to 1024
    trainer = HSSSVMTrainer(
        spec=KernelSpec(h=1.0), comp=CompressionParams(rank=32, n_near=48, n_far=64),
        leaf_size=64, max_it=10)
    model = trainer.fit(xtr, ytr, c_value=1.0)
    # padded coordinates must carry exactly zero dual weight
    n_pad = model.z_y.shape[0] - 600
    assert n_pad > 0
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    assert acc > 0.88, acc


def test_grid_search_reuses_factorization():
    xtr, ytr, xte, yte = _train_test(n_train=512, n_test=128)
    model, info = grid_search(
        xtr, ytr, xte, yte, hs=[1.0], cs=[0.1, 1.0, 10.0],
        trainer_kwargs=dict(
            comp=CompressionParams(rank=24, n_near=48, n_far=64),
            leaf_size=64, max_it=10),
    )
    assert info["best_accuracy"] > 0.85
    assert len(info["results"]) == 3
    # compression ran once: all C share the same compression time
    comp_times = {v["compression_s"] for v in info["results"].values()}
    assert len(comp_times) == 1


def test_admm_time_much_smaller_than_compression():
    """Paper Tables 4/5: ADMM Time << Compression time (amortization claim)."""
    xtr, ytr, _, _ = _train_test(n_train=2048, n_test=10)
    trainer = HSSSVMTrainer(
        spec=KernelSpec(h=1.0), comp=CompressionParams(rank=32, n_near=48, n_far=64),
        leaf_size=128, max_it=10)
    rep = trainer.prepare(xtr, ytr)
    trainer.train(1.0)
    # ADMM per-C cost must be below compression+factorization cost
    assert trainer.report.admm_s < rep.compression_s + rep.factorization_s


def test_laplacian_kernel_end_to_end():
    """KernelSpec(name='laplacian'): compression -> factorization -> ADMM ->
    predict must work and classify (previously zero coverage)."""
    xtr, ytr, xte, yte = _train_test(n_train=640, n_test=128, seed=3, sep=1.8)
    trainer = HSSSVMTrainer(
        spec=KernelSpec(name="laplacian", h=2.0),
        comp=CompressionParams(rank=32, n_near=48, n_far=64),
        leaf_size=64, max_it=10)
    model = trainer.fit(xtr, ytr, c_value=1.0)
    assert model.spec.name == "laplacian"
    acc = float(jnp.mean(model.predict(jnp.asarray(xte)) == yte))
    assert acc > 0.85, acc


def test_report_fields():
    xtr, ytr, _, _ = _train_test(n_train=256, n_test=10)
    trainer = HSSSVMTrainer(
        spec=KernelSpec(h=1.0), comp=CompressionParams(rank=16, n_near=32, n_far=32),
        leaf_size=64, max_it=5)
    rep = trainer.prepare(xtr, ytr)
    assert rep.memory_mb > 0
    assert rep.beta == 100.0
