"""Data pipelines: synthetic SVM dataset family + deterministic LM tokens."""
