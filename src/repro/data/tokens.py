"""Deterministic synthetic LM token pipeline.

Host-sharded: each host materializes ONLY its slice of the global batch
(``host_slice``), so the pipeline scales to any number of hosts without a
central dataloader.  Deterministic in (seed, step) — a restart resumes the
exact stream, which is what makes checkpoint/resume bit-exact end to end.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int, host_slice: slice = slice(None)) -> dict:
        idx = np.arange(self.global_batch)[host_slice]
        rows = []
        for i in idx:
            r = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 65_537 + int(i))
            rows.append(r.integers(0, self.vocab, size=self.seq_len + 1,
                                   dtype=np.int32))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def batch_for_config(cfg, global_batch: int, seq_len: int, step: int,
                     seed: int = 0) -> dict:
    """Modality-aware synthetic batch for any assigned arch."""
    r = np.random.default_rng(seed * 7_919 + step)
    if cfg.frontend == "audio_stub":
        return {
            "frames": r.normal(size=(global_batch, seq_len, cfg.frontend_dim)
                               ).astype(np.float32),
            "labels": r.integers(0, cfg.vocab, size=(global_batch, seq_len),
                                 dtype=np.int32),
            "mask_indices": r.random((global_batch, seq_len)) < 0.3,
        }
    if cfg.frontend == "vision_stub":
        s_txt = seq_len - cfg.n_prefix_tokens
        return {
            "patches": r.normal(
                size=(global_batch, cfg.n_prefix_tokens, cfg.frontend_dim)
            ).astype(np.float32),
            "tokens": r.integers(0, cfg.vocab, size=(global_batch, s_txt),
                                 dtype=np.int32),
            "labels": r.integers(0, cfg.vocab, size=(global_batch, s_txt),
                                 dtype=np.int32),
        }
    ts = TokenStream(cfg.vocab, global_batch, seq_len, seed)
    return ts.batch_at(step)
