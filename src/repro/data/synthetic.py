"""Synthetic SVM dataset family.

Analogues of the paper's Table 1 regimes, with controllable size/geometry:
  blobs        — separable Gaussian clusters (a8a/a9a-like difficulty knob)
  circles      — concentric spheres (nonlinear boundary; small-h kernels,
                 the regime where low-rank Nyström fails and HSS wins)
  checkerboard — alternating grid (hard, many support vectors, ijcnn1-like)
  susy_like    — low-dim physics-ish mixture (8-18 features, millions of
                 rows possible — the paper's largest regime)
"""
from __future__ import annotations

import numpy as np


def blobs(n: int, n_features: int = 8, sep: float = 2.0, seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    mu = np.zeros(n_features)
    mu[0] = sep
    xa = r.normal(size=(half, n_features)) + mu
    xb = r.normal(size=(n - half, n_features)) - mu
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def circles(n: int, n_features: int = 4, gap: float = 1.0, noise: float = 0.15,
            seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    u = r.normal(size=(n, n_features))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    radii = np.concatenate([np.ones(half), np.full(n - half, 1.0 + gap)])
    x = (u * radii[:, None] + noise * r.normal(size=u.shape)).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def checkerboard(n: int, cells: int = 4, n_features: int = 2, seed: int = 0):
    r = np.random.default_rng(seed)
    x = r.uniform(0, cells, size=(n, n_features)).astype(np.float32)
    parity = np.sum(np.floor(x[:, :2]), axis=1) % 2
    y = (parity * 2 - 1).astype(np.float32)
    return x, y


def susy_like(n: int, n_features: int = 18, seed: int = 0):
    """Low-dimensional mixture with partially overlapping classes."""
    r = np.random.default_rng(seed)
    half = n // 2
    # signal: correlated features; background: broader, shifted
    cov = 0.6 * np.eye(n_features) + 0.4
    la = np.linalg.cholesky(cov)
    xa = r.normal(size=(half, n_features)) @ la.T
    xb = 1.4 * r.normal(size=(n - half, n_features)) + 0.8
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


DATASETS = {
    "blobs": blobs,
    "circles": circles,
    "checkerboard": checkerboard,
    "susy_like": susy_like,
}


def train_test(name: str, n_train: int, n_test: int, seed: int = 0, **kw):
    x, y = DATASETS[name](n_train + n_test, seed=seed, **kw)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]
