"""Synthetic SVM dataset family.

Analogues of the paper's Table 1 regimes, with controllable size/geometry:
  blobs        — separable Gaussian clusters (a8a/a9a-like difficulty knob)
  circles      — concentric spheres (nonlinear boundary; small-h kernels,
                 the regime where low-rank Nyström fails and HSS wins)
  checkerboard — alternating grid (hard, many support vectors, ijcnn1-like)
  susy_like    — low-dim physics-ish mixture (8-18 features, millions of
                 rows possible — the paper's largest regime)
"""
from __future__ import annotations

import numpy as np


def blobs(n: int, n_features: int = 8, sep: float = 2.0, seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    mu = np.zeros(n_features)
    mu[0] = sep
    xa = r.normal(size=(half, n_features)) + mu
    xb = r.normal(size=(n - half, n_features)) - mu
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def circles(n: int, n_features: int = 4, gap: float = 1.0, noise: float = 0.15,
            seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    u = r.normal(size=(n, n_features))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    radii = np.concatenate([np.ones(half), np.full(n - half, 1.0 + gap)])
    x = (u * radii[:, None] + noise * r.normal(size=u.shape)).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def checkerboard(n: int, cells: int = 4, n_features: int = 2, seed: int = 0):
    r = np.random.default_rng(seed)
    x = r.uniform(0, cells, size=(n, n_features)).astype(np.float32)
    parity = np.sum(np.floor(x[:, :2]), axis=1) % 2
    y = (parity * 2 - 1).astype(np.float32)
    return x, y


def susy_like(n: int, n_features: int = 18, seed: int = 0):
    """Low-dimensional mixture with partially overlapping classes."""
    r = np.random.default_rng(seed)
    half = n // 2
    # signal: correlated features; background: broader, shifted
    cov = 0.6 * np.eye(n_features) + 0.4
    la = np.linalg.cholesky(cov)
    xa = r.normal(size=(half, n_features)) @ la.T
    xb = 1.4 * r.normal(size=(n - half, n_features)) + 0.8
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def multiclass_blobs(n: int, n_classes: int = 4, n_features: int = 8,
                     sep: float = 3.0, seed: int = 0):
    """k Gaussian clusters on a simplex-ish layout; labels are 0..k-1 ints.

    The one-vs-rest workhorse: every class is compact, so each binary
    subproblem is blobs-vs-rest difficulty (controlled by ``sep``).
    """
    r = np.random.default_rng(seed)
    centers = r.normal(size=(n_classes, n_features))
    centers *= sep / np.maximum(
        np.linalg.norm(centers, axis=1, keepdims=True), 1e-9)
    counts = np.full(n_classes, n // n_classes)
    counts[: n - counts.sum()] += 1
    xs, ys = [], []
    for c in range(n_classes):
        xs.append(r.normal(size=(counts[c], n_features)) + centers[c])
        ys.append(np.full(counts[c], c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    p = r.permutation(n)
    return x[p], y[p]


def spirals(n: int, n_classes: int = 3, n_features: int = 2,
            turns: float = 1.25, noise: float = 0.08, seed: int = 0):
    """k interleaved 2-D spiral arms (embedded in n_features dims).

    Strongly nonlinear boundaries between EVERY pair of classes — the regime
    where a global low-rank kernel approximation fails but HSS keeps the
    near-field exact.  Labels are 0..k-1 ints.
    """
    r = np.random.default_rng(seed)
    counts = np.full(n_classes, n // n_classes)
    counts[: n - counts.sum()] += 1
    xs, ys = [], []
    for c in range(n_classes):
        t = np.sqrt(r.uniform(0.05, 1.0, size=counts[c]))
        ang = 2 * np.pi * (turns * t + c / n_classes)
        arm = np.stack([t * np.cos(ang), t * np.sin(ang)], axis=1)
        arm += noise * r.normal(size=arm.shape)
        if n_features > 2:
            extra = 0.05 * r.normal(size=(counts[c], n_features - 2))
            arm = np.concatenate([arm, extra], axis=1)
        xs.append(arm)
        ys.append(np.full(counts[c], c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    p = r.permutation(n)
    return x[p], y[p]


DATASETS = {
    "blobs": blobs,
    "circles": circles,
    "checkerboard": checkerboard,
    "susy_like": susy_like,
}

MULTICLASS_DATASETS = {
    "multiclass_blobs": multiclass_blobs,
    "spirals": spirals,
}


def train_test(name: str, n_train: int, n_test: int, seed: int = 0, **kw):
    gen = DATASETS.get(name) or MULTICLASS_DATASETS[name]
    x, y = gen(n_train + n_test, seed=seed, **kw)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]
