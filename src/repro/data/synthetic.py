"""Synthetic SVM dataset family.

Analogues of the paper's Table 1 regimes, with controllable size/geometry:
  blobs        — separable Gaussian clusters (a8a/a9a-like difficulty knob)
  circles      — concentric spheres (nonlinear boundary; small-h kernels,
                 the regime where low-rank Nyström fails and HSS wins)
  checkerboard — alternating grid (hard, many support vectors, ijcnn1-like)
  susy_like    — low-dim physics-ish mixture (8-18 features, millions of
                 rows possible — the paper's largest regime)
"""
from __future__ import annotations

import numpy as np


def blobs(n: int, n_features: int = 8, sep: float = 2.0, seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    mu = np.zeros(n_features)
    mu[0] = sep
    xa = r.normal(size=(half, n_features)) + mu
    xb = r.normal(size=(n - half, n_features)) - mu
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def circles(n: int, n_features: int = 4, gap: float = 1.0, noise: float = 0.15,
            seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    u = r.normal(size=(n, n_features))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    radii = np.concatenate([np.ones(half), np.full(n - half, 1.0 + gap)])
    x = (u * radii[:, None] + noise * r.normal(size=u.shape)).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def checkerboard(n: int, cells: int = 4, n_features: int = 2, seed: int = 0):
    r = np.random.default_rng(seed)
    x = r.uniform(0, cells, size=(n, n_features)).astype(np.float32)
    parity = np.sum(np.floor(x[:, :2]), axis=1) % 2
    y = (parity * 2 - 1).astype(np.float32)
    return x, y


def susy_like(n: int, n_features: int = 18, seed: int = 0):
    """Low-dimensional mixture with partially overlapping classes."""
    r = np.random.default_rng(seed)
    half = n // 2
    # signal: correlated features; background: broader, shifted
    cov = 0.6 * np.eye(n_features) + 0.4
    la = np.linalg.cholesky(cov)
    xa = r.normal(size=(half, n_features)) @ la.T
    xb = 1.4 * r.normal(size=(n - half, n_features)) + 0.8
    x = np.concatenate([xa, xb]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


def multiclass_blobs(n: int, n_classes: int = 4, n_features: int = 8,
                     sep: float = 3.0, seed: int = 0):
    """k Gaussian clusters on a simplex-ish layout; labels are 0..k-1 ints.

    The one-vs-rest workhorse: every class is compact, so each binary
    subproblem is blobs-vs-rest difficulty (controlled by ``sep``).
    """
    r = np.random.default_rng(seed)
    centers = r.normal(size=(n_classes, n_features))
    centers *= sep / np.maximum(
        np.linalg.norm(centers, axis=1, keepdims=True), 1e-9)
    counts = np.full(n_classes, n // n_classes)
    counts[: n - counts.sum()] += 1
    xs, ys = [], []
    for c in range(n_classes):
        xs.append(r.normal(size=(counts[c], n_features)) + centers[c])
        ys.append(np.full(counts[c], c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    p = r.permutation(n)
    return x[p], y[p]


def spirals(n: int, n_classes: int = 3, n_features: int = 2,
            turns: float = 1.25, noise: float = 0.08, seed: int = 0):
    """k interleaved 2-D spiral arms (embedded in n_features dims).

    Strongly nonlinear boundaries between EVERY pair of classes — the regime
    where a global low-rank kernel approximation fails but HSS keeps the
    near-field exact.  Labels are 0..k-1 ints.
    """
    r = np.random.default_rng(seed)
    counts = np.full(n_classes, n // n_classes)
    counts[: n - counts.sum()] += 1
    xs, ys = [], []
    for c in range(n_classes):
        t = np.sqrt(r.uniform(0.05, 1.0, size=counts[c]))
        ang = 2 * np.pi * (turns * t + c / n_classes)
        arm = np.stack([t * np.cos(ang), t * np.sin(ang)], axis=1)
        arm += noise * r.normal(size=arm.shape)
        if n_features > 2:
            extra = 0.05 * r.normal(size=(counts[c], n_features - 2))
            arm = np.concatenate([arm, extra], axis=1)
        xs.append(arm)
        ys.append(np.full(counts[c], c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    p = r.permutation(n)
    return x[p], y[p]


def noisy_sine(n: int, n_features: int = 2, freq: float = 1.5,
               noise: float = 0.1, seed: int = 0):
    """Regression targets y = sin(freq·x₀) + ½·cos(freq·x₁) + noise.

    The ε-SVR workhorse: a smooth low-dimensional response over uniformly
    scattered points — the regime where the Gaussian-kernel HSS compression
    is near-exact and the ε tube directly controls the SV count.
    """
    r = np.random.default_rng(seed)
    x = r.uniform(-np.pi, np.pi, size=(n, n_features)).astype(np.float32)
    y = np.sin(freq * x[:, 0])
    if n_features > 1:
        y = y + 0.5 * np.cos(freq * x[:, 1])
    y = (y + noise * r.normal(size=n)).astype(np.float32)
    return x, y


def noisy_step(n: int, n_features: int = 2, levels: int = 4,
               noise: float = 0.05, seed: int = 0):
    """Regression targets: a staircase of ``levels`` flat plateaus + noise.

    Discontinuous response — hard for a smooth kernel, so it exercises the
    bias fallbacks and the ε/RMSE trade-off away from the easy-sine regime.
    """
    r = np.random.default_rng(seed)
    x = r.uniform(0.0, 1.0, size=(n, n_features)).astype(np.float32)
    y = np.floor(x[:, 0] * levels) / max(levels - 1, 1)
    y = (y + noise * r.normal(size=n)).astype(np.float32)
    return x, y


def blobs_with_outliers(n: int, n_features: int = 4, outlier_frac: float = 0.1,
                        spread: float = 6.0, seed: int = 0):
    """One-class novelty-detection set: a Gaussian inlier blob (y = +1) plus
    a uniform shell of far-away outliers (y = −1, fraction ``outlier_frac``).

    Training a one-class SVM uses x only; y is the held-out ground truth for
    precision/recall scoring.
    """
    r = np.random.default_rng(seed)
    n_out = max(int(n * outlier_frac), 1)
    n_in = n - n_out
    x_in = r.normal(size=(n_in, n_features))
    u = r.normal(size=(n_out, n_features))
    u /= np.maximum(np.linalg.norm(u, axis=1, keepdims=True), 1e-9)
    radii = r.uniform(0.6 * spread, spread, size=(n_out, 1))
    x_out = u * radii + 0.3 * r.normal(size=(n_out, n_features))
    x = np.concatenate([x_in, x_out]).astype(np.float32)
    y = np.concatenate([np.ones(n_in), -np.ones(n_out)]).astype(np.float32)
    p = r.permutation(n)
    return x[p], y[p]


DATASETS = {
    "blobs": blobs,
    "circles": circles,
    "checkerboard": checkerboard,
    "susy_like": susy_like,
}

MULTICLASS_DATASETS = {
    "multiclass_blobs": multiclass_blobs,
    "spirals": spirals,
}

REGRESSION_DATASETS = {
    "noisy_sine": noisy_sine,
    "noisy_step": noisy_step,
}

ONECLASS_DATASETS = {
    "blobs_with_outliers": blobs_with_outliers,
}


def train_test(name: str, n_train: int, n_test: int, seed: int = 0, **kw):
    gen = (DATASETS.get(name) or MULTICLASS_DATASETS.get(name)
           or REGRESSION_DATASETS.get(name) or ONECLASS_DATASETS[name])
    x, y = gen(n_train + n_test, seed=seed, **kw)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]
