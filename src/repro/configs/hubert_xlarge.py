"""hubert-xlarge [audio] — encoder-only (w2v2 arch), masked cluster prediction.

[arXiv:2106.07447; unverified]
48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means cluster codes).
The conv waveform frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, T, 512), projected to d_model. No decode step
(encoder-only) — decode shapes are skipped per DESIGN.md §5.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    causal=False,
    frontend="audio_stub",
    frontend_dim=512,
)
