"""paligemma-3b [vlm] — SigLIP patch prefix + gemma text backbone.

[arXiv:2407.07726; hf]
18L d_model=2048 8H (GQA kv=1 — MQA) d_ff=16384 vocab=257216.
The SigLIP tower is a STUB: input_specs() provides 256 precomputed patch
embeddings (dim 1152), linearly projected and prepended as a fully-visible
prefix (prefix-LM mask); text is causal.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=257216,
    frontend="vision_stub",
    frontend_dim=1152,
    n_prefix_tokens=256,
)
