"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS: dict[str, str] = {
    "arctic-480b": "repro.configs.arctic_480b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "llama3-405b": "repro.configs.llama3_405b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
}


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    cfg = importlib.import_module(ARCH_IDS[arch]).CONFIG
    if overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def list_archs() -> list[str]:
    return sorted(ARCH_IDS)
