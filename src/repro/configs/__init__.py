"""Architecture configs (one file per assigned arch) + registry."""

from repro.configs.registry import ARCH_IDS, get_config, list_archs

__all__ = ["ARCH_IDS", "get_config", "list_archs"]
