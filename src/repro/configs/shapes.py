"""Assigned input-shape grid + per-arch applicability (DESIGN.md §5)."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_status(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-skipped) for an (arch x shape) cell."""
    if shape.kind == "decode":
        if cfg.family == "encoder":
            return False, "encoder-only arch has no decode step"
        if shape.name == "long_500k" and not cfg.supports_long_decode:
            return False, ("pure full-attention arch: 500k decode needs "
                           "sub-quadratic state (skip per assignment)")
    if shape.kind == "prefill" and cfg.family == "encoder":
        # interpreted as a 32k-frame encoder forward (inference analogue)
        return True, "prefill = encoder forward for encoder-only arch"
    return True, ""


def all_cells() -> list[tuple[str, str]]:
    from repro.configs.registry import list_archs

    return [(a, s) for a in list_archs() for s in SHAPES]
