"""zamba2-1.2b [hybrid] — Mamba-2 backbone + shared attention block.

[arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000, ssm_state=64.
Mamba-2 blocks throughout; ONE weight-shared attention+MLP block applied
every 6 layers (the real model's per-application LoRA adapters are
simplified to shared weights + per-application KV cache slots — DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    shared_attn_every=6,
)
