"""gemma2-9b [dense] — alternating local/global attention + logit softcaps.

[arXiv:2408.00118; hf]
42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.
Even layers: sliding window 4096; odd layers: global.  Attention logits
softcapped at 50, final logits at 30.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    window=4096,
    alt_local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
)
