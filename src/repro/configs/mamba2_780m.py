"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]
48L d_model=1536 (attn-free) vocab=50280, ssm_state=128.
expand=2 -> d_inner=3072, head_dim=64 -> 48 SSD heads, 1 B/C group.

The paper-representative architecture: SSD's token-mixing operator is a
1-semiseparable matrix evaluated with the same dense-diagonal + low-rank
off-diagonal split the paper's HSS uses (DESIGN.md §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
)
