"""repro — HSS-ADMM nonlinear SVM training framework (Cipolla & Gondzio 2021) in JAX.

Layers:
  repro.core      — the paper's contribution: HSS kernel approximation + ADMM SVM.
  repro.kernels   — Pallas TPU kernels (gaussian blocks, SSD, attention, ADMM update).
  repro.models    — LM substrate for the assigned architecture pool.
  repro.configs   — architecture configs (``--arch <id>``).
  repro.train     — optimizers, training loop, gradient compression.
  repro.ckpt      — checkpointing + elastic reshard.
  repro.dist      — sharding rules, pipeline, fault handling.
  repro.launch    — mesh, dry-run, train/serve drivers.
  repro.roofline  — roofline-term extraction from compiled artifacts.
"""

__version__ = "1.0.0"
