"""The canonical train / serve steps lowered by the launcher and dry-run."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.transformer import Model
from repro.train import optim


def make_train_step(model: Model, opt_cfg: optim.AdamWConfig | None = None,
                    num_microbatches: int = 1, grad_dtype: str | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``num_microbatches > 1`` = gradient accumulation: the global batch is
    split along dim 0 and scanned, shrinking peak activation memory by the
    same factor (the §Perf memory lever for the 100B+ dense cells).

    ``grad_dtype="bfloat16"`` — mixed-precision gradient path: grads are
    taken w.r.t. a bf16 copy of the params, so the cross-device gradient
    reduction moves bf16, not f32 (halves the dominant grad-sync collective
    of the large dense cells — §Perf change A1); the f32 master weights are
    still updated in f32 by AdamW.
    """
    opt_cfg = opt_cfg or optim.AdamWConfig()

    if grad_dtype is not None:
        gdt = jnp.dtype(grad_dtype)

        def loss_lowp(params_lowp, batch):
            return model.loss_fn(params_lowp, batch)

        base_grad = jax.value_and_grad(loss_lowp, has_aux=True)

        def grad_fn(params, batch):
            params_lowp = jax.tree.map(
                lambda p: p.astype(gdt)
                if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
            return base_grad(params_lowp, batch)
    else:
        grad_fn = jax.value_and_grad(model.loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(leaf):
                b = leaf.shape[0]
                mb = b // num_microbatches
                return leaf.reshape(num_microbatches, mb, *leaf.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb_batch):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(params, mb_batch)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss), _ = jax.lax.scan(
                acc_step, (gzero, jnp.zeros((), jnp.float32)), micro)
            scale = 1.0 / num_microbatches
            grads = jax.tree.map(lambda g: g * scale, grads)
            loss = loss * scale
            metrics = {"ce": loss, "aux": jnp.zeros(())}
        params, opt_state = optim.adamw_update(grads, opt_state, params,
                                               opt_cfg)
        metrics = dict(metrics, loss=loss,
                       grad_norm=optim.global_norm(grads))
        return params, opt_state, metrics

    return train_step


def make_serve_steps(model: Model, max_len: int):
    """Returns (prefill_step, decode_step) for serving."""

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return prefill_step, decode_step
