"""Hand-rolled optimizers (no external deps): AdamW and Adafactor.

AdamW keeps (m, v) in configurable dtypes — bf16 moments halve optimizer HBM
(a §Perf lever for the very large dense archs).  Adafactor keeps factored
second moments (row/col) — the classic memory-saver for 100B+ training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"    # "bfloat16" halves optimizer memory


class AdamWState(NamedTuple):
    step: Array
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: PyTree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(
    grads: PyTree, state: AdamWState, params: PyTree,
    cfg: AdamWConfig = AdamWConfig(),
) -> tuple[PyTree, AdamWState]:
    step = state.step + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        mhat = m32 / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * delta
        return p_new.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return params_new, AdamWState(step=step, m=m_new, v=v_new)


# ---------------------------------------------------------------------- #
# Adafactor (factored second moment) — memory-saver option               #
# ---------------------------------------------------------------------- #
class AdafactorState(NamedTuple):
    step: Array
    vr: PyTree    # row second moments (or full v for <2D params)
    vc: PyTree


def adafactor_init(params: PyTree) -> AdafactorState:
    def rows(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 else \
            jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if p.ndim >= 2 else jnp.zeros((), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(rows, params),
        vc=jax.tree.map(cols, params),
    )


def adafactor_update(
    grads: PyTree, state: AdafactorState, params: PyTree,
    lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> tuple[PyTree, AdafactorState]:
    step = state.step + 1
    beta = 1.0 - step.astype(jnp.float32) ** -decay

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if g.ndim >= 2:
            vr_new = beta * vr + (1 - beta) * g2.mean(-1)
            vc_new = beta * vc + (1 - beta) * g2.mean(-2)
            r = vr_new / jnp.maximum(
                vr_new.mean(-1, keepdims=True), eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_new)[..., None, :]
                     + eps)
        else:
            vr_new = beta * vr + (1 - beta) * g2
            vc_new = vc
            u = g / (jnp.sqrt(vr_new) + eps)
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), vr_new, vc_new

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    istuple = lambda t: isinstance(t, tuple)
    return (
        jax.tree.map(lambda t: t[0], out, is_leaf=istuple),
        AdafactorState(
            step=step,
            vr=jax.tree.map(lambda t: t[1], out, is_leaf=istuple),
            vc=jax.tree.map(lambda t: t[2], out, is_leaf=istuple),
        ),
    )
