"""int8 error-feedback gradient compression for the DP all-reduce.

The wire format of the data-parallel gradient reduction is int8 with one
f32 scale per block: a reduce-scatter expressed as all_to_all of QUANTIZED
chunks (each device receives every peer's int8 chunk, dequantizes and sums
locally), then an all_gather of the re-quantized reduced chunk — 4x less
link traffic than f32 (~2x vs bf16) at both stages.

Error feedback (Seide et al. / EF-SGD): the quantization residual is added
back into the next step's gradient, making the compression unbiased over
time — required for convergence at int8.

Composition: applies to the pure-DP / ZeRO-1 regime (params replicated over
"data").  With ZeRO-3 FSDP, XLA already emits reduce-scatter of bf16 shards;
compressing those is future work (documented in DESIGN.md).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


def _quantize(x: jax.Array, block: int = 2048):
    """Per-block int8 quantization. x flat (N,) -> (q int8, scales f32)."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    x = q.astype(jnp.float32) * scale[:, None]
    return x.reshape(-1)[:n]


def compress_roundtrip(x: jax.Array, block: int = 2048) -> jax.Array:
    q, s = _quantize(x, block)
    return _dequantize(q, s, x.shape[0])


def compressed_psum_local(g_local: jax.Array, axis_name: str,
                          n_shards: int, block: int = 2048) -> jax.Array:
    """Quantized all-reduce over ``axis_name`` (call inside shard_map).

    g_local (N,) with N divisible by n_shards.  Wire traffic per device:
    int8 all_to_all (N bytes) + int8 all_gather (N bytes) vs 8N for f32
    ring all-reduce.
    """
    n = g_local.shape[0]
    chunks = g_local.reshape(n_shards, n // n_shards)
    q, s = jax.vmap(lambda c: _quantize(c, block))(chunks)
    # every device receives peer chunk i == its index
    q_all = jax.lax.all_to_all(q[None], axis_name, split_axis=1,
                               concat_axis=0, tiled=False)[:, 0]
    s_all = jax.lax.all_to_all(s[None], axis_name, split_axis=1,
                               concat_axis=0, tiled=False)[:, 0]
    deq = jax.vmap(lambda qq, ss: _dequantize(qq, ss, n // n_shards))(
        q_all, s_all)
    reduced = jnp.sum(deq, axis=0)                      # (N/n_shards,)
    q_r, s_r = _quantize(reduced, block)
    q_full = jax.lax.all_gather(q_r, axis_name)         # (n, blocks, block)
    s_full = jax.lax.all_gather(s_r, axis_name)
    parts = jax.vmap(lambda qq, ss: _dequantize(qq, ss, n // n_shards))(
        q_full, s_full)
    return parts.reshape(-1)[:n]


def make_compressed_allreduce(mesh, axis_name: str = "data",
                              block: int = 2048):
    """Returns f(grads_stacked (n_shards, N)) -> reduced (N,) under jit.

    grads_stacked holds each data-shard's local gradient flattened; the
    shard_map performs the quantized reduction.  Used by tests and the
    ZeRO-1 training mode.
    """
    n_shards = mesh.shape[axis_name]

    def reduce_fn(g_stacked):
        from repro.dist.api import shard_map

        def local(g):
            return compressed_psum_local(g[0], axis_name, n_shards, block)

        return shard_map(
            local, mesh,
            in_specs=P(axis_name, None),
            out_specs=P(None),
        )(g_stacked)

    return jax.jit(reduce_fn)


class ErrorFeedback:
    """g_compressed = Q(g + e);  e' = (g + e) - Q(g + e)."""

    @staticmethod
    def init(params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads: PyTree, err: PyTree, block: int = 2048
              ) -> tuple[PyTree, PyTree]:
        def one(g, e):
            target = g.astype(jnp.float32) + e
            flat = target.reshape(-1)
            comp = compress_roundtrip(flat, block).reshape(g.shape)
            return comp.astype(g.dtype), target - comp

        out = jax.tree.map(one, grads, err)
        istuple = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], out, is_leaf=istuple),
                jax.tree.map(lambda t: t[1], out, is_leaf=istuple))
