"""Training substrate: optimizers, train step, gradient compression."""
