"""Pytree -> NamedSharding rules for params, optimizer state, batches, caches.

Megatron-style tensor parallelism by parameter name (wq/wk/wv/w_gate/w_up
split their output features on "model", wo/w_down their input features;
MoE expert stacks split the expert axis), optional ZeRO/FSDP sharding of a
remaining axis over the data axes, and batch-dim sharding for inputs and
decode caches.  Every rule is divisibility-guarded: a dimension that does
not divide the mesh axis size degrades to replicated, so the same rules
serve the 8-host-device CI mesh and the 512-chip production mesh.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

# parameter names whose LAST dim carries the output features -> "model"
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "head"}
# parameter names whose SECOND-TO-LAST dim carries input features -> "model"
_ROW_PARALLEL = {"wo", "w_down", "out_proj"}


def _mesh_axes(mesh: Mesh):
    present = set(mesh.axis_names)
    model = "model" if "model" in present else None
    data = tuple(a for a in ("pod", "data") if a in present) or None
    return data, model


def _size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(spec: list, shape: tuple, mesh: Mesh) -> PartitionSpec:
    """Replicate any entry whose dimension doesn't divide its mesh axes."""
    out = []
    used: set[str] = set()
    for entry, dim in zip(spec, shape):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a in used for a in axes) or dim % _size(mesh, entry) or \
                dim < _size(mesh, entry):
            out.append(None)
            continue
        used.update(axes)
        out.append(entry)
    return PartitionSpec(*out)


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, GetAttrKey):
            names.append(k.name)
        elif isinstance(k, SequenceKey):
            names.append(str(k.idx))
    return names


def param_shardings(params_shapes: Any, mesh: Mesh, fsdp: bool = False) -> Any:
    """NamedSharding per parameter leaf.

    Tensor parallelism by name (see module docstring); with ``fsdp=True``
    the largest remaining axis is additionally sharded over the data axes
    (ZeRO-3 style).  Unknown / small leaves replicate.
    """
    data, model = _mesh_axes(mesh)

    def per_leaf(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        nd = len(shape)
        spec: list = [None] * nd
        if model and nd >= 2:
            if "moe" in names and nd >= 3 and \
                    name in ("w_gate", "w_up", "w_down"):
                spec[nd - 3] = model        # expert axis of (L, E, d, ff)
            elif name in _COL_PARALLEL:
                spec[-1] = model
            elif name in _ROW_PARALLEL:
                spec[-2] = model
            elif name == "embed":
                spec[0] = model             # vocab axis
        if fsdp and data and nd >= 1:
            free = [i for i in range(nd) if spec[i] is None]
            if free:
                i = max(free, key=lambda j: shape[j])
                spec[i] = data if len(data) > 1 else data[0]
        return NamedSharding(mesh, _fit(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(per_leaf, params_shapes)


def opt_shardings(opt_shapes: Any, params_sh: Any, mesh: Mesh) -> Any:
    """Optimizer-state shardings: moment trees mirror the param shardings.

    Works for any NamedTuple optimizer state (AdamW m/v, Adafactor vr/vc):
    a field whose tree structure matches the params inherits the param
    shardings leaf-for-leaf (re-fit to the leaf's own shape — factored
    moments with reduced rank replicate where the spec no longer fits);
    everything else (step counters, scalars) replicates.
    """
    rep = NamedSharding(mesh, PartitionSpec())
    params_struct = jax.tree.structure(params_sh)

    def mirror(leaf, psh):
        spec = list(psh.spec) + [None] * leaf.ndim
        return NamedSharding(mesh, _fit(spec[:leaf.ndim], leaf.shape, mesh))

    if hasattr(opt_shapes, "_fields"):
        out = {}
        for f in opt_shapes._fields:
            sub = getattr(opt_shapes, f)
            if jax.tree.structure(sub) == params_struct:
                out[f] = jax.tree.map(mirror, sub, params_sh)
            else:
                out[f] = jax.tree.map(lambda _: rep, sub)
        return type(opt_shapes)(**out)
    return jax.tree.map(lambda _: rep, opt_shapes)


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    """Inputs shard their leading (batch) dim over the data axes."""
    data, _ = _mesh_axes(mesh)
    d_entry = None if data is None else (data if len(data) > 1 else data[0])

    def per_leaf(leaf):
        spec: list = [None] * leaf.ndim
        if d_entry is not None and leaf.ndim >= 1:
            spec[0] = d_entry
        return NamedSharding(mesh, _fit(spec, tuple(leaf.shape), mesh))

    return jax.tree.map(per_leaf, batch)


def cache_shardings(cache_shapes: Any, mesh: Mesh, *, batch: int) -> Any:
    """Decode-cache shardings: batch axis on "data", kv heads on "model".

    The batch axis is located by extent (caches stack layers in front);
    K/V leaves additionally shard their kv-head axis, SSM states their
    head axis, on "model".
    """
    data, model = _mesh_axes(mesh)
    d_entry = None if data is None else (data if len(data) > 1 else data[0])

    def per_leaf(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        spec: list = [None] * leaf.ndim
        if d_entry is not None:
            # caches stack layers in front, so the batch axis is never
            # axis 0 on >=2D leaves (guards n_layers == batch collisions)
            first = 1 if leaf.ndim >= 2 else 0
            for i in range(first, leaf.ndim):
                if shape[i] == batch:
                    spec[i] = d_entry
                    break
        if model:
            if name in ("k", "v", "shared_k", "shared_v") and leaf.ndim >= 2:
                spec[-2] = model            # kv-head axis of (..., S, KV, hd)
            elif name == "ssm_state" and leaf.ndim >= 3:
                spec[-3] = model            # head axis of (L, B, H, N, P)
        return NamedSharding(mesh, _fit(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(per_leaf, cache_shapes)
