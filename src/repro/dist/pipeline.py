"""GPipe-style pipeline parallelism over a "stage" mesh axis.

``pipeline_forward`` runs a per-stage function over microbatches with the
classic fill/steady/drain schedule: at tick t, stage s processes microbatch
t - s; activations move one stage per tick via collective_permute.  Stage
parameters are sharded on the stage axis (each device holds ONE stage's
weights), microbatches are replicated in, and outputs come back replicated —
numerically identical to applying the stages sequentially, which is exactly
what tests/test_pipeline.py asserts.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.api import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    params: Any,
    x: jax.Array,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run ``n_stages`` chained applications of ``stage_fn`` as a pipeline.

    params — pytree whose leaves lead with the stage axis (n_stages, ...);
    x      — microbatched input (n_micro, microbatch, ...);
    returns the (n_micro, microbatch, ...) output of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def local(p_local, x_all):
        stage = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], p_local)   # this device's stage

        def tick(t, carry):
            outputs, recv = carry
            mb = t - stage                          # microbatch index here
            active = (mb >= 0) & (mb < n_micro)
            mb_c = jnp.clip(mb, 0, n_micro - 1)
            # stage 0 reads fresh microbatches; later stages consume what
            # the previous stage sent last tick
            a_in = jnp.where(stage == 0, x_all[mb_c], recv)
            out = stage_fn(p, a_in)
            # the last stage commits finished microbatches
            write = active & (stage == n_stages - 1)
            committed = jnp.where(write, out, outputs[mb_c])
            outputs = outputs.at[mb_c].set(committed)
            # hand the activation to the next stage (drops off the end)
            sent = jax.lax.ppermute(out, axis, fwd)
            return outputs, sent

        outputs0 = jnp.zeros_like(x_all)
        recv0 = jnp.zeros_like(x_all[0])
        outputs, _ = jax.lax.fori_loop(0, n_ticks, tick, (outputs0, recv0))
        # only the last stage holds real outputs; psum replicates them
        outputs = jnp.where(stage == n_stages - 1, outputs, 0.0)
        return jax.lax.psum(outputs, axis)

    return shard_map(
        local, mesh,
        in_specs=(P(axis), P()), out_specs=P(),
    )(params, x)
