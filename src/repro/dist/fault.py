"""Fault tolerance for long training runs.

Three pieces, composed by launch.train:

  StepGuard     — runs each step under a wall-clock deadline (hung
                  collectives / dead hosts surface as StepTimeout instead of
                  an infinite hang) and flags straggler steps whose duration
                  exceeds ``straggler_ratio`` x the median of prior steps.
  FailureInjector — deterministic failure drills: raises InjectedFailure the
                  FIRST time each configured step is reached, so restart
                  paths are exercised in CI, not discovered in production.
  run_resilient — the restart loop: build (or restore) state, run steps under
                  the guard, checkpoint every ``ckpt_every`` steps, and on
                  any step failure restore from the latest checkpoint and
                  replay — steps are neither lost nor double-counted because
                  the checkpoint records the count of COMPLETED steps.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from typing import Any, Callable


class StepTimeout(RuntimeError):
    """A guarded step exceeded its wall-clock deadline."""


class InjectedFailure(RuntimeError):
    """Deterministic drill failure from FailureInjector."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float

    @property
    def ratio(self) -> float:
        return self.duration_s / max(self.median_s, 1e-12)


class StepGuard:
    """Deadline + straggler detection around a single step callable.

    The deadline is enforced by running the step on a daemon thread and
    abandoning it on timeout — Python offers no safe preemption, so a
    timed-out step may still be executing (e.g. blocked in a collective)
    while the caller restarts.  That matches the intended use: after a
    StepTimeout the surviving hosts are torn down / re-initialized, not
    reused concurrently with the zombie step.
    """

    def __init__(self, deadline_s: float, straggler_ratio: float | None = None):
        self.deadline_s = deadline_s
        self.straggler_ratio = straggler_ratio
        self.durations: list[float] = []
        self.stragglers: list[StragglerEvent] = []

    def run(self, step_no: int, fn: Callable[[], Any]) -> Any:
        from repro.dist import api as dist_api

        box: dict[str, Any] = {}
        errs: list[BaseException] = []
        # use_mesh state is thread-local; re-enter the caller's mesh context
        # on the worker thread so constrain()/resolve_spec() inside the step
        # still see it
        ctx = dist_api._current()

        def target():
            try:
                if ctx is not None:
                    with dist_api.use_mesh(ctx[0]):
                        box["value"] = fn()
                else:
                    box["value"] = fn()
            except BaseException as e:   # noqa: BLE001 — re-raised below
                errs.append(e)

        t0 = time.perf_counter()
        worker = threading.Thread(target=target, daemon=True)
        worker.start()
        worker.join(self.deadline_s)
        if worker.is_alive():
            raise StepTimeout(
                f"step {step_no} exceeded deadline of {self.deadline_s}s")
        if errs:
            raise errs[0]
        dur = time.perf_counter() - t0
        if self.straggler_ratio is not None and self.durations:
            med = statistics.median(self.durations)
            if med > 0 and dur > self.straggler_ratio * med:
                self.stragglers.append(StragglerEvent(step_no, dur, med))
        self.durations.append(dur)
        return box["value"]


class FailureInjector:
    """Raises InjectedFailure the first time each configured step runs."""

    def __init__(self, fail_at: tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self._fired: set[int] = set()

    def check(self, step_no: int) -> None:
        if step_no in self.fail_at and step_no not in self._fired:
            self._fired.add(step_no)
            raise InjectedFailure(f"injected failure at step {step_no}")


def run_resilient(
    n_steps: int,
    build: Callable[[], Any],
    step: Callable[[Any, int], Any],
    save: Callable[[Any, int], None],
    restore: Callable[[], tuple[Any, int] | None],
    *,
    ckpt_every: int = 0,
    max_restarts: int = 3,
    guard: StepGuard | None = None,
) -> tuple[Any, dict]:
    """Run ``n_steps`` steps with checkpoint-resume on failure.

    ``save(state, k)`` / ``restore() -> (state, k)`` use k = the number of
    COMPLETED steps, so a replay resumes at exactly step k.  On failure the
    run restores (falling back to a fresh build when no checkpoint exists)
    and replays; after ``max_restarts`` restarts the failure propagates.
    Returns (final_state, report) with restart/straggler counts.
    """
    restarts = 0

    def load() -> tuple[Any, int]:
        got = restore()
        if got is None:
            return build(), 0
        return got

    state, i = load()
    while i < n_steps:
        try:
            if guard is not None:
                state = guard.run(i, lambda: step(state, i))
            else:
                state = step(state, i)
            # the periodic save shares the restart budget: a transient
            # checkpoint-write failure restores and replays instead of
            # aborting a run with restarts to spare
            if ckpt_every and (i + 1) % ckpt_every == 0:
                save(state, i + 1)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            state, i = load()
            continue
        i += 1
    report = dict(
        restarts=restarts,
        stragglers=list(guard.stragglers) if guard is not None else [],
    )
    # Skip the final save when the periodic cadence already covered step
    # n_steps — the streamed HSS build checkpoints whole levels, and writing
    # the complete state twice back-to-back doubles the IO bill for nothing.
    if not (ckpt_every and n_steps % ckpt_every == 0):
        try:
            save(state, n_steps)
        except Exception as e:   # noqa: BLE001 — surfaced, not fatal
            # the run IS complete; a failed final checkpoint must not discard
            # the computed state, so it is reported instead of raised
            report["final_save_error"] = repr(e)
    return state, report
