"""repro.dist — the distribution layer: mesh context, sharding rules,
pipeline parallelism, and fault handling.

Modules:
  api       — ``use_mesh`` context, logical-axis resolution (``resolve_spec``),
              the ``constrain`` activation-sharding hint used throughout
              repro.models, and a version-compatible ``shard_map``.
  sharding  — pytree -> NamedSharding rules for params / optimizer state /
              batches / decode caches (consumed by launch.specs and
              launch.dryrun).
  pipeline  — GPipe-style pipeline parallelism over a "stage" mesh axis.
  fault     — StepGuard deadlines + straggler detection, failure injection
              drills, and checkpoint-resuming ``run_resilient``.

Everything degrades gracefully outside a mesh context: ``constrain`` is a
no-op, so the same model code serves single-device smoke tests and the
512-chip dry-run.
"""
from repro.dist import api, fault, pipeline, sharding  # noqa: F401
