"""Mesh context + logical-axis resolution + activation-sharding hints.

Model code names LOGICAL axes ("data", "model", "stage"); this module maps
them onto whatever mesh is active.  The "data" logical axis composes the
"pod" and "data" mesh axes when both exist (multi-pod batch/FSDP sharding —
see launch.mesh), so the same constrain() calls serve the 16x16 single-pod
and 2x16x16 multi-pod meshes unchanged.

Outside a ``use_mesh`` context every hint is a no-op — single-device smoke
tests run the exact same model code as the 512-chip dry-run.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# logical axis -> candidate mesh axes, in composition (major-to-minor) order
_LOGICAL_AXES = {
    "data": ("pod", "data"),
    "model": ("model",),
    "stage": ("stage",),
}

_state = threading.local()


def _translation(mesh: Mesh) -> dict[str, Any]:
    """Logical name -> mesh axis name (or tuple of names when composed)."""
    present = set(mesh.axis_names)
    tr: dict[str, Any] = {}
    for logical, cands in _LOGICAL_AXES.items():
        axes = tuple(a for a in cands if a in present)
        if axes:
            tr[logical] = axes[0] if len(axes) == 1 else axes
    return tr


def _current() -> tuple[Mesh, dict[str, Any]] | None:
    """The active (mesh, logical-axis translation), or None outside."""
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate ``mesh`` for constrain()/resolve_spec() in this thread.

    Composes with jax's own mesh context: ``with use_mesh(mesh), mesh:``.
    """
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, _translation(mesh))
    try:
        yield mesh
    finally:
        _state.ctx = prev


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def resolve_spec(spec: tuple, shape: tuple) -> tuple:
    """Map a logical spec onto the active mesh with divisibility fallback.

    Per dimension: the logical entry resolves to its mesh axes; axes are
    dropped (major first) until the dimension extent divides the remaining
    axes' total size, degrading to None (replicated) when nothing fits.
    An entry naming a mesh axis directly passes through the same check.
    Unknown entries and all entries outside a mesh context resolve to None.
    """
    ctx = _current()
    if ctx is None:
        return tuple(None for _ in spec)
    mesh, tr = ctx
    present = set(mesh.axis_names)
    out: list[Any] = []
    used: set[str] = set()
    for entry, dim in zip(spec, shape):
        if entry is None:
            out.append(None)
            continue
        mapped = tr.get(entry, entry if entry in present else None)
        if mapped is None:
            out.append(None)
            continue
        axes = mapped if isinstance(mapped, tuple) else (mapped,)
        axes = tuple(a for a in axes if a not in used)
        while axes and (dim % _axes_size(mesh, axes) or dim == 0):
            axes = axes[1:]                 # drop the major axis, try again
        if not axes:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    return tuple(out)


def constrain(x: jax.Array, spec: tuple) -> jax.Array:
    """Sharding hint: with_sharding_constraint under an active mesh, else id.

    ``spec`` names logical axes; entries that don't resolve (axis absent
    from the mesh, or extent not divisible) fall back to replicated for
    that dimension, so the hint never fails on small/debug meshes.
    """
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    resolved = resolve_spec(spec, x.shape)
    if all(e is None for e in resolved):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*resolved)))


def mesh_ndev(mesh: Mesh) -> int:
    """Total device count of a mesh (all axes combined)."""
    return _axes_size(mesh, tuple(mesh.axis_names))


def node_partition_spec(mesh: Mesh, ndim: int, dim0: int) -> PartitionSpec:
    """THE node-axis placement rule, shared by every layer of the HSS stack.

    Node-stacked arrays — (n_nodes, ·, ·) per-level blocks — shard their
    leading axis over ALL mesh axes when it divides the device count;
    everything else (small upper levels, the dense root LU/pivots, vectors
    handled elsewhere) replicates.  ``distributed.fac_shardings``,
    ``factorization.factorize_sharded`` and ``constrain_nodes`` all defer
    here so the rule can never drift between the build, the placement, and
    the solve's intermediate constraints.
    """
    if ndim >= 3 and dim0 % mesh_ndev(mesh) == 0 and dim0 > 1:
        return PartitionSpec(tuple(mesh.axis_names), *([None] * (ndim - 1)))
    return PartitionSpec(*([None] * ndim))


def constrain_nodes(x: jax.Array) -> jax.Array:
    """Pin the leading (node/sample) axis to the active mesh's full device set.

    The HSS per-level sweeps (``HSSMatrix.matmat``, ``hss_solve_mat``) are
    chains of pair/unpair reshapes across the node axis; left to sharding
    propagation alone, XLA's SPMD partitioner picks layouts for the small
    upper-level intermediates that (on some backends/versions) miscompile
    the interleaving reshapes.  This helper pins every per-level intermediate
    to the one layout the distributed solver is designed around: leading dim
    sharded over ALL mesh axes when it divides the device count, replicated
    otherwise — the exact rule of ``core.distributed.fac_shardings``.

    No-op outside a ``use_mesh`` context, so local single-device code paths
    are untouched.
    """
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, node_partition_spec(mesh, x.ndim, x.shape[0])))


def shard_map(f, mesh: Mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-compatible shard_map.

    jax renamed the replication-check kwarg (check_rep -> check_vma) and
    moved shard_map out of jax.experimental across releases; callers in
    repro.models go through this shim so both API generations work.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
