"""Closed-form ADMM for the SVM dual QP (paper Algorithm 2).

Problem (paper eq. (1)/(3)):

  min_x ½ xᵀ Y K Y x − eᵀx   s.t. yᵀx = 0,  x ∈ [0, C]^d

split as x − z = 0.  Per iteration (paper §2.1):

  x-step: the KKT system of the equality-constrained QP has the closed form
     x⁺ = Y K_β⁻¹ Y q − (eᵀ K_β⁻¹ Y q / eᵀ K_β⁻¹ e) · Y K_β⁻¹ e,
     q = e + μ + β z
     — exactly ONE shifted-kernel solve per iteration (the HSS factorization's
     raison d'être), plus O(d) vector work.  The vector w = K_β⁻¹ e is
     precomputed once (paper Alg. 3 lines 4–6).
  z-step: z⁺ = Π_[0,C](x⁺ − μ/β)          (component-wise box projection)
  μ-step: μ⁺ = μ − β (x⁺ − z⁺)

Note: paper Alg. 3 line 10 writes w2 = wᵀ x^k; from the derivation of eq. (5)
the projected vector is q^k = e + μ^k + β z^k (Alg. 2 line 2) — we follow the
math (Alg. 2).  The box upper bound may be a per-coordinate vector, which is
how padded (inert) points are pinned to 0 (tree.pad_dataset).

The loop is a ``lax.scan`` → a single fused trace regardless of MaxIt;
the fused z/μ elementwise update is also available as a Pallas kernel
(repro.kernels.admm_update) for the TPU target.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Solver = Callable[[Array], Array]      # b (d,)   -> K_beta^{-1} b
SolverMat = Callable[[Array], Array]   # B (d, k) -> K_beta^{-1} B


class ADMMState(NamedTuple):
    x: Array
    z: Array
    mu: Array


class ADMMTrace(NamedTuple):
    primal_res: Array   # ||x - z|| per iteration
    dual_res: Array     # beta * ||z - z_prev|| per iteration


def admm_svm(
    solver: Solver,
    y: Array,
    c_upper: Array | float,
    beta: float,
    max_it: int = 10,
    z0: Array | None = None,
    mu0: Array | None = None,
    use_fused_update: bool = False,
) -> tuple[ADMMState, ADMMTrace]:
    """Run MaxIt closed-form ADMM iterations (paper fixes MaxIt = 10).

    ``solver`` must apply (K̃ + βI)^{-1}; with the HSS factorization each call
    is O(d r).  Supports warm starts (z0, mu0) — used by the C-grid search.
    Single-problem (k = 1) view of ``admm_svm_batched``.
    """
    d = y.shape[0]
    c_vec = jnp.broadcast_to(jnp.asarray(c_upper, y.dtype), (d,))
    state, trace = admm_svm_batched(
        lambda b: solver(b[:, 0])[:, None],
        y[None, :], c_vec[None, :], beta, max_it,
        z0=None if z0 is None else z0[:, None],
        mu0=None if mu0 is None else mu0[:, None],
        use_fused_update=use_fused_update,
    )
    return (ADMMState(*(a[:, 0] for a in state)),
            ADMMTrace(*(a[:, 0] for a in trace)))


def admm_svm_batched(
    solver_mat: SolverMat,
    ys: Array,
    c_upper: Array | float,
    beta: float,
    max_it: int = 10,
    z0: Array | None = None,
    mu0: Array | None = None,
    use_fused_update: bool = False,
) -> tuple[ADMMState, ADMMTrace]:
    """Run k SVM dual ADMM problems that share one (K̃ + βI) factorization.

    ``ys`` is (k, d): one ±1 label vector per problem (the per-class label
    vectors of a one-vs-rest reduction, or per-pair vectors of one-vs-one).
    The kernel side of the x-step is label-independent, so
      * w = K_β⁻¹ e is computed ONCE and shared by every problem, and
      * the per-iteration solves of all k problems are ONE multi-RHS sweep
        ``solver_mat`` over a (d, k) block (factorization.hss_solve_mat)
    instead of k sequential single-RHS solves — the paper's factor-once
    economy extended across the class axis.

    ``c_upper`` may be a scalar, a shared (d,) vector, or a per-problem
    (k, d) matrix (one-vs-one pins non-participating points to [0, 0]).
    State arrays are (d, k); traces are (max_it, k).  Supports (d, k) warm
    starts ``z0``/``mu0`` for the C-grid × class product sweep.
    ``use_fused_update`` routes the elementwise z/μ step through the Pallas
    kernel (repro.kernels.admm_update) on the flattened (d·k,) block.
    """
    k, d = ys.shape
    dtype = ys.dtype
    y_cols = ys.T                                  # (d, k)
    e = jnp.ones((d,), dtype)
    w = solver_mat(e[:, None])[:, 0]               # K_β^{-1} e, shared by all k
    w1 = e @ w
    w_y = y_cols * w[:, None]                      # (d, k)
    c_arr = jnp.asarray(c_upper, dtype)
    if c_arr.ndim == 1:                            # shared (d,) box vector
        c_arr = c_arr[:, None]
    elif c_arr.ndim == 2:                          # per-problem (k, d)
        c_arr = c_arr.T
    c_mat = jnp.broadcast_to(c_arr, (d, k))

    z_init = jnp.zeros((d, k), dtype) if z0 is None else z0
    mu_init = jnp.zeros((d, k), dtype) if mu0 is None else mu0

    if use_fused_update:
        from repro.kernels.admm_update import ops as admm_ops

        c_flat = c_mat.reshape(-1)                 # the Pallas kernel is 1-D

        def zmu_update(x, mu):
            z_f, mu_f = admm_ops.fused_zmu_update(
                x.reshape(-1), mu.reshape(-1), c_flat, beta)
            return z_f.reshape(x.shape), mu_f.reshape(x.shape)
    else:
        def zmu_update(x, mu):
            z_new = jnp.clip(x - mu / beta, 0.0, c_mat)
            mu_new = mu - beta * (x - z_new)
            return z_new, mu_new

    def step(state: ADMMState, _):
        x, z, mu = state
        q = 1.0 + mu + beta * z                    # e broadcast over columns
        yq = y_cols * q                            # (d, k)
        u = solver_mat(yq)                         # ONE k-RHS solve
        w2 = w @ yq                                # (k,)
        x_new = y_cols * u - (w2 / w1)[None, :] * w_y
        z_new, mu_new = zmu_update(x_new, mu)
        trace = ADMMTrace(
            primal_res=jnp.linalg.norm(x_new - z_new, axis=0),
            dual_res=beta * jnp.linalg.norm(z_new - z, axis=0),
        )
        return ADMMState(x_new, z_new, mu_new), trace

    init = ADMMState(jnp.zeros((d, k), dtype), z_init, mu_init)
    final, trace = jax.lax.scan(step, init, None, length=max_it)
    return final, trace


def paper_beta(d: int) -> float:
    """The paper's β staging rule (§3.3): 1e2 / 1e3 / 1e4 by training size."""
    if d >= 1_000_000:
        return 1e4
    if d >= 100_000:
        return 1e3
    return 1e2
