"""Closed-form ADMM for the SVM dual QP (paper Algorithm 2).

Problem (paper eq. (1)/(3)):

  min_x ½ xᵀ Y K Y x − eᵀx   s.t. yᵀx = 0,  x ∈ [0, C]^d

split as x − z = 0.  Per iteration (paper §2.1):

  x-step: the KKT system of the equality-constrained QP has the closed form
     x⁺ = Y K_β⁻¹ Y q − (eᵀ K_β⁻¹ Y q / eᵀ K_β⁻¹ e) · Y K_β⁻¹ e,
     q = e + μ + β z
     — exactly ONE shifted-kernel solve per iteration (the HSS factorization's
     raison d'être), plus O(d) vector work.  The vector w = K_β⁻¹ e is
     precomputed once (paper Alg. 3 lines 4–6).
  z-step: z⁺ = Π_[0,C](x⁺ − μ/β)          (component-wise box projection)
  μ-step: μ⁺ = μ − β (x⁺ − z⁺)

Note: paper Alg. 3 line 10 writes w2 = wᵀ x^k; from the derivation of eq. (5)
the projected vector is q^k = e + μ^k + β z^k (Alg. 2 line 2) — we follow the
math (Alg. 2).  The box upper bound may be a per-coordinate vector, which is
how padded (inert) points are pinned to 0 (tree.pad_dataset).

The loop is a ``lax.scan`` → a single fused trace regardless of MaxIt;
the fused z/μ elementwise update is also available as a Pallas kernel
(repro.kernels.admm_update) for the TPU target.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Solver = Callable[[Array], Array]   # b -> K_beta^{-1} b


class ADMMState(NamedTuple):
    x: Array
    z: Array
    mu: Array


class ADMMTrace(NamedTuple):
    primal_res: Array   # ||x - z|| per iteration
    dual_res: Array     # beta * ||z - z_prev|| per iteration


def admm_svm(
    solver: Solver,
    y: Array,
    c_upper: Array | float,
    beta: float,
    max_it: int = 10,
    z0: Array | None = None,
    mu0: Array | None = None,
    use_fused_update: bool = False,
) -> tuple[ADMMState, ADMMTrace]:
    """Run MaxIt closed-form ADMM iterations (paper fixes MaxIt = 10).

    ``solver`` must apply (K̃ + βI)^{-1}; with the HSS factorization each call
    is O(d r).  Supports warm starts (z0, mu0) — used by the C-grid search.
    """
    d = y.shape[0]
    dtype = y.dtype
    e = jnp.ones((d,), dtype)
    w = solver(e)                       # K_β^{-1} e   (precomputed once)
    w1 = e @ w
    w_y = y * w
    c_vec = jnp.broadcast_to(jnp.asarray(c_upper, dtype), (d,))

    z_init = jnp.zeros((d,), dtype) if z0 is None else z0
    mu_init = jnp.zeros((d,), dtype) if mu0 is None else mu0

    if use_fused_update:
        from repro.kernels.admm_update import ops as admm_ops

        def zmu_update(x, z, mu):
            return admm_ops.fused_zmu_update(x, mu, c_vec, beta)
    else:
        def zmu_update(x, z, mu):
            z_new = jnp.clip(x - mu / beta, 0.0, c_vec)
            mu_new = mu - beta * (x - z_new)
            return z_new, mu_new

    def step(state: ADMMState, _):
        x, z, mu = state
        q = e + mu + beta * z
        yq = y * q
        u = solver(yq)
        w2 = w @ yq
        x_new = y * u - (w2 / w1) * w_y
        z_new, mu_new = zmu_update(x_new, z, mu)
        trace = ADMMTrace(
            primal_res=jnp.linalg.norm(x_new - z_new),
            dual_res=beta * jnp.linalg.norm(z_new - z),
        )
        return ADMMState(x_new, z_new, mu_new), trace

    init = ADMMState(jnp.zeros((d,), dtype), z_init, mu_init)
    final, trace = jax.lax.scan(step, init, None, length=max_it)
    return final, trace


def paper_beta(d: int) -> float:
    """The paper's β staging rule (§3.3): 1e2 / 1e3 / 1e4 by training size."""
    if d >= 1_000_000:
        return 1e4
    if d >= 100_000:
        return 1e3
    return 1e2
