"""Closed-form ADMM for box-constrained kernel QPs (paper Algorithm 2).

The paper's ADMM solves one specific instance — the binary SVM dual

  min_x ½ xᵀ Y K Y x − eᵀx   s.t. yᵀx = 0,  x ∈ [0, C]^d

— but the expensive machinery (one shifted-kernel solve K_β⁻¹ per
iteration on the shared HSS factorization) is task-agnostic.  This module
therefore solves the general *box QP family*

  min_x ½ xᵀ S K S x + pᵀx + γ‖x‖₁   s.t. aᵀx = b,  x ∈ [lo, hi]^d

specified by a :class:`BoxQPTask` (S a diagonal ±1 "sign"/label matrix, so
S(K+βI)S = SKS + βI and ONE factorization of K+βI serves every task), split
as x − z = 0.  Per iteration (paper §2.1, generalized):

  x-step: the KKT system of the equality-constrained QP has the closed form
     x⁺ = S K_β⁻¹ S q − λ · S K_β⁻¹ (S a),
     q = −p + μ + β z,      λ = (vᵀ(S q) − b) / ((Sa)ᵀ v),   v = K_β⁻¹ (S a)
     — exactly ONE shifted-kernel solve per iteration (the HSS
     factorization's raison d'être) plus O(d) vector work; v is precomputed
     once per task (paper Alg. 3 lines 4–6; for the SVM instance S a = e and
     v is the paper's w).  Without an equality constraint the λ term drops.
  z-step: z⁺ = Π_[lo,hi](soft(x⁺ − μ/β, γ/β))   (prox of γ‖·‖₁ + box; with
     γ = 0 this is the paper's component-wise box projection)
  μ-step: μ⁺ = μ − β (x⁺ − z⁺)

Instances (see also repro.core.tasks for the ε-SVR / one-class builders):
  binary/multiclass SVM  S=Y, p=−e, a=y, b=0, [0, C], γ=0   (svm_task)
  ε-SVR difference dual  S=I, p=−y, a=e, b=0, [−C, C], γ=ε  (tasks.svr_task)
  one-class (ν-) SVM     S=I, p=0,  a=e, b=1, [0, 1/(νn)]   (tasks.one_class_task)

Note: paper Alg. 3 line 10 writes w2 = wᵀ x^k; from the derivation of eq. (5)
the projected vector is q^k = e + μ^k + β z^k (Alg. 2 line 2) — we follow the
math (Alg. 2).  The box bounds may be per-coordinate vectors, which is how
padded (inert) points are pinned to [0, 0] (tree.pad_dataset).

The loop is a ``lax.scan`` → a single fused trace regardless of MaxIt; the
paper's stopping rule is honored by ``tol``: once a problem's
max(primal, dual) residual drops below it, its updates are masked (iterates
frozen) and ``ADMMTrace.iters_run`` reports the live iteration count.  The
fused z/μ elementwise update is also available as a Pallas kernel
(repro.kernels.admm_update) for the TPU target (γ=0, lo=0 tasks only).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Solver = Callable[[Array], Array]      # b (d,)   -> K_beta^{-1} b
SolverMat = Callable[[Array], Array]   # B (d, k) -> K_beta^{-1} B


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class BoxQPTask:
    """One batch of k box-QP problems sharing a single K_β factorization.

    min ½ xᵀ S K S x + pᵀx + γ‖x‖₁  s.t. aᵀx = b,  lo ≤ x ≤ hi — the sign
    diagonal S (±1) is the only way the kernel enters per-problem, so every
    task instance rides the SAME (K + βI) factorization.  All per-coordinate
    fields are (d, k) column blocks (problem axis last, matching the batched
    multi-RHS solve layout).
    """

    sign: Array             # (d, k) diagonal of S per problem (±1)
    lin: Array              # (d, k) linear term p
    lo: Array               # (d, k) box lower bounds
    hi: Array               # (d, k) box upper bounds
    # Equality constraint aᵀx = b, stored pre-multiplied by the sign
    # diagonal: eq_sa = S a — the only form the closed-form x-step needs
    # (v = K_β⁻¹(Sa) is precomputed once).  (d,) when all k problems share
    # it (every built-in task: SVM has Sa = y·y = e, SVR/one-class have
    # S = I, a = e), (d, k) for per-problem vectors, None for no constraint.
    eq_sa: Array | None = None
    eq_b: Array | None = None          # (k,) right-hand sides (None -> 0)
    l1: Array | None = None            # (k,) ℓ1 weights γ (None -> no prox)


class ADMMState(NamedTuple):
    x: Array
    z: Array
    mu: Array


class ADMMTrace(NamedTuple):
    primal_res: Array   # (max_it, k)  ||x - z|| per iteration
    dual_res: Array     # (max_it, k)  beta * ||z - z_prev|| per iteration
    iters_run: Array    # (k,) int32   iterations before the tol freeze
                        # (= max_it when tol is None / never reached)
    done: Array | None = None   # (k,) bool final freeze mask (tol runs only)
                                # — lets chunked outer loops (adaptive ρ)
                                # carry the freeze state across calls


@dataclasses.dataclass(frozen=True)
class ADMMParams:
    """Iteration-control bundle for engine-level ADMM runs.

    ``max_it``/``tol`` are the knobs the engine already exposes.  The rest
    switch on residual-balancing adaptive ρ (Boyd §3.4.1, default OFF to
    keep the committed golden pins bit-stable): the run is chunked into
    ``rho_every``-iteration pieces, and between chunks the penalty β is
    multiplied by ``rho_tau`` when the primal residual exceeds ``rho_mu``
    times the dual residual (divided when the imbalance is the other way).
    β is ALSO the factorization shift here — S(K+βI)S — so every rescale
    implies a refactorization of K̃ + βI; the caller owns that (it is cheap
    next to compression and the engine caches one factorization per visited
    β), and ``rho_max_updates`` caps how many times it can happen.
    """

    max_it: int = 10
    tol: float | None = None
    adapt_rho: bool = False
    rho_every: int = 5
    rho_mu: float = 10.0
    rho_tau: float = 2.0
    rho_max_updates: int = 4


def box_matrix(bound: Array | float, d: int, k: int, dtype) -> Array:
    """Normalize a box bound to (d, k) columns: accepts a scalar, a shared
    (d,) vector, or a per-problem (k, d) matrix (task row layout)."""
    arr = jnp.asarray(bound, dtype)
    if arr.ndim == 1:                              # shared (d,) box vector
        arr = arr[:, None]
    elif arr.ndim == 2:                            # per-problem (k, d)
        arr = arr.T
    return jnp.broadcast_to(arr, (d, k))


def svm_task(ys: Array, c_upper: Array | float) -> BoxQPTask:
    """The paper's binary SVM dual as a BoxQPTask: the (k, d) label matrix
    ``ys`` gives S = Y and a = y per problem (so S a = e, shared), p = −e,
    box [0, C].  ``c_upper`` may be a scalar, a shared (d,) vector, or a
    per-problem (k, d) matrix (one-vs-one pins non-participants to [0, 0])."""
    k, d = ys.shape
    dtype = ys.dtype
    return BoxQPTask(
        sign=ys.T,
        lin=jnp.full((d, k), -1.0, dtype),
        lo=jnp.zeros((d, k), dtype),
        hi=box_matrix(c_upper, d, k, dtype),
        eq_sa=jnp.ones((d,), dtype),
        eq_b=None,
        l1=None,
    )


def admm_boxqp(
    solver_mat: SolverMat,
    task: BoxQPTask,
    beta: float,
    max_it: int = 10,
    tol: float | None = None,
    z0: Array | None = None,
    mu0: Array | None = None,
    use_fused_update: bool = False,
    done0: Array | None = None,
) -> tuple[ADMMState, ADMMTrace]:
    """Run k box-QP ADMM problems that share one (K̃ + βI) factorization.

    ``solver_mat`` must apply (K̃ + βI)^{-1} to a (d, k) block; with the HSS
    factorization each call is ONE O(d r) multi-RHS sweep
    (factorization.hss_solve_mat) — the per-iteration solves of all k
    problems fused, the paper's factor-once economy extended across the
    problem axis.  The equality-side vector v = K_β⁻¹(Sa) is computed once
    per call and shared when ``task.eq_sa`` is a shared (d,) vector.

    State arrays are (d, k); traces are (max_it, k).  Supports (d, k) warm
    starts ``z0``/``mu0`` for knob-grid sweeps (C, ε, ν).  ``tol`` masks a
    problem's updates once both residuals pass the RELATIVE stopping test
    (Boyd §3.3.1: ‖x−z‖ < tol·(1+max(‖x‖,‖z‖)) and β‖Δz‖ < tol·(1+‖μ‖)) —
    its iterates freeze at the stopping iterate (the paper's stopping rule
    inside the fixed-length scan) and ``trace.iters_run`` reports how many
    live iterations it ran.  ``done0`` seeds the freeze mask, so a chunked
    outer loop (``adaptive_rho_outer``) can carry it across calls without
    re-running finished problems.
    ``use_fused_update`` routes the elementwise z/μ step through the Pallas
    kernel (repro.kernels.admm_update) on the flattened (d·k,) block — only
    valid for γ=0, lo=0 tasks (the SVM instance).
    """
    d, k = task.sign.shape
    dtype = task.sign.dtype
    s_cols = task.sign
    neg_lin = -task.lin
    lo_mat = jnp.broadcast_to(task.lo, (d, k))
    hi_mat = jnp.broadcast_to(task.hi, (d, k))

    has_eq = task.eq_sa is not None
    if has_eq:
        if task.eq_sa.ndim == 1:       # shared vector: ONE single-RHS solve
            v = solver_mat(task.eq_sa[:, None])[:, 0]      # K_β^{-1} (Sa)
            w1 = task.eq_sa @ v
            sv = s_cols * v[:, None]                       # (d, k)

            def eq_dot(sq):
                return v @ sq                              # (k,)
        else:                          # per-problem vectors: one k-RHS solve
            v = solver_mat(task.eq_sa)
            w1 = jnp.einsum("dk,dk->k", task.eq_sa, v,
                            preferred_element_type=jnp.float32)
            sv = s_cols * v

            def eq_dot(sq):
                return jnp.einsum("dk,dk->k", v, sq,
                                  preferred_element_type=jnp.float32)
        eq_b = jnp.zeros((k,), dtype) if task.eq_b is None else task.eq_b

    z_init = jnp.zeros((d, k), dtype) if z0 is None else z0
    mu_init = jnp.zeros((d, k), dtype) if mu0 is None else mu0

    if use_fused_update:
        if task.l1 is not None:
            raise ValueError("fused z/mu update supports only gamma=0 tasks")
        # The Pallas kernel clips to [0, c]: a nonzero lower bound would be
        # silently mis-projected.  lo is only checkable when concrete (the
        # engine builds tasks inside jit; its svm path always has lo = 0).
        if (not isinstance(task.lo, jax.core.Tracer)
                and bool(jnp.any(task.lo != 0))):
            raise ValueError("fused z/mu update supports only lo=0 tasks")
        from repro.kernels.admm_update import ops as admm_ops

        c_flat = hi_mat.reshape(-1)                # the Pallas kernel is 1-D

        def zmu_update(x, mu):
            z_f, mu_f = admm_ops.fused_zmu_update(
                x.reshape(-1), mu.reshape(-1), c_flat, beta)
            return z_f.reshape(x.shape), mu_f.reshape(x.shape)
    else:
        if task.l1 is None:
            def prox(t):
                return jnp.clip(t, lo_mat, hi_mat)
        else:
            thr = (jnp.broadcast_to(task.l1, (k,)) / beta)[None, :]

            def prox(t):               # prox of (γ‖·‖₁ + box)/β: shrink, clip
                t = jnp.sign(t) * jnp.maximum(jnp.abs(t) - thr, 0.0)
                return jnp.clip(t, lo_mat, hi_mat)

        def zmu_update(x, mu):
            z_new = prox(x - mu / beta)
            mu_new = mu - beta * (x - z_new)
            return z_new, mu_new

    def step(carry, _):
        if tol is None:
            state = carry
        else:
            state, done, iters = carry
        x, z, mu = state
        q = neg_lin + mu + beta * z
        sq = s_cols * q                            # (d, k)
        u = solver_mat(sq)                         # ONE k-RHS solve
        if has_eq:
            lam = (eq_dot(sq) - eq_b) / w1         # (k,)
            x_new = s_cols * u - lam[None, :] * sv
        else:
            x_new = s_cols * u
        z_new, mu_new = zmu_update(x_new, mu)
        if tol is not None:
            keep = done[None, :]                   # frozen problems hold
            x_new = jnp.where(keep, x, x_new)
            z_new = jnp.where(keep, z, z_new)
            mu_new = jnp.where(keep, mu, mu_new)
            iters = iters + (~done).astype(jnp.int32)
        primal = jnp.linalg.norm(x_new - z_new, axis=0)
        dual = beta * jnp.linalg.norm(z_new - z, axis=0)
        new_state = ADMMState(x_new, z_new, mu_new)
        if tol is None:
            return new_state, (primal, dual)
        # Relative stopping criteria (Boyd §3.3.1): the raw residual norms
        # scale with √d, β, and the iterate magnitudes, so tol gates the
        # residuals normalized by the natural primal/dual scales.
        p_scale = 1.0 + jnp.maximum(jnp.linalg.norm(x_new, axis=0),
                                    jnp.linalg.norm(z_new, axis=0))
        d_scale = 1.0 + jnp.linalg.norm(mu_new, axis=0)
        done = done | ((primal < tol * p_scale) & (dual < tol * d_scale))
        return (new_state, done, iters), (primal, dual)

    init_state = ADMMState(jnp.zeros((d, k), dtype), z_init, mu_init)
    if tol is None:
        final, (primal, dual) = jax.lax.scan(step, init_state, None,
                                             length=max_it)
        iters_run = jnp.full((k,), max_it, jnp.int32)
        done_out = None
    else:
        d_init = jnp.zeros((k,), bool) if done0 is None else done0
        carry = (init_state, d_init, jnp.zeros((k,), jnp.int32))
        (final, done_out, iters_run), (primal, dual) = jax.lax.scan(
            step, carry, None, length=max_it)
    return final, ADMMTrace(primal, dual, iters_run, done_out)


def adaptive_rho_outer(
    run_chunk: Callable,
    beta0: float,
    params: ADMMParams,
    z0: Array | None = None,
    mu0: Array | None = None,
) -> tuple[ADMMState, ADMMTrace, dict]:
    """Residual-balancing ρ (Boyd §3.4.1) as a host loop of scan chunks.

    ``run_chunk(beta, n_it, z0, mu0, done0) -> (ADMMState, ADMMTrace)`` runs
    ``n_it`` iterations at penalty β — the caller owns the factorization of
    K̃ + βI a rescale implies (the engine passes a jitted chunk that takes
    the factorization as a pytree argument, so chunks never recompile across
    β values).  Between chunks the last live residuals are balanced:
    primal > ρ_μ·dual ⟹ β ← τβ, dual > ρ_μ·primal ⟹ β ← β/τ, at most
    ``rho_max_updates`` times.  The UNSCALED multiplier μ is carried across
    a rescale — it is the β-invariant quantity (Boyd eq. 3.14 rescales the
    scaled u = μ/β; μ itself is unchanged) — and the freeze mask is reset
    because the relative stopping test moves with β.

    Returns (state, trace, info): ``trace.iters_run`` sums LIVE iterations
    across chunks, the residual traces are the chunks concatenated, and
    ``info`` records the final β and the rescale count.
    """
    z, mu, done = z0, mu0, None
    beta = float(beta0)
    it_left = int(params.max_it)
    rescales = 0
    iters_total = None
    state = None
    prs: list[Array] = []
    drs: list[Array] = []
    while it_left > 0:
        n_it = min(params.rho_every, it_left) if params.adapt_rho else it_left
        state, trace = run_chunk(beta, n_it, z, mu, done)
        z, mu, done = state.z, state.mu, trace.done
        iters_total = (trace.iters_run if iters_total is None
                       else iters_total + trace.iters_run)
        prs.append(trace.primal_res)
        drs.append(trace.dual_res)
        it_left -= n_it
        if done is not None and bool(jnp.all(done)):
            break
        if (params.adapt_rho and it_left > 0
                and rescales < params.rho_max_updates):
            pr, dr = trace.primal_res[-1], trace.dual_res[-1]
            if done is not None:      # balance on LIVE problems only
                pr = jnp.where(done, 0.0, pr)
                dr = jnp.where(done, 0.0, dr)
            p, d = float(jnp.max(pr)), float(jnp.max(dr))
            new_beta = beta
            if p > params.rho_mu * d:
                new_beta = beta * params.rho_tau
            elif d > params.rho_mu * p:
                new_beta = beta / params.rho_tau
            if new_beta != beta:
                beta = new_beta
                rescales += 1
                done = None
    trace = ADMMTrace(jnp.concatenate(prs), jnp.concatenate(drs),
                      iters_total, done)
    return state, trace, dict(beta=beta, rescales=rescales)


def admm_boxqp_adaptive(
    solver_for: Callable[[float], SolverMat],
    task: BoxQPTask,
    beta0: float,
    params: ADMMParams,
    z0: Array | None = None,
    mu0: Array | None = None,
) -> tuple[ADMMState, ADMMTrace, dict]:
    """:func:`admm_boxqp` under the residual-balancing outer loop.

    ``solver_for(beta)`` must return a (d, k)-block solver for (K̃ + βI) —
    with the HSS machinery that is ``factorization.factorize(hss, beta)
    .solve_mat``, and callers should cache it per visited β (the engine
    does).  With ``params.adapt_rho`` False this is a single plain
    ``admm_boxqp`` run (plus the info dict).
    """
    def run_chunk(beta, n_it, z, mu, done):
        return admm_boxqp(solver_for(beta), task, beta, max_it=n_it,
                          tol=params.tol, z0=z, mu0=mu, done0=done)

    return adaptive_rho_outer(run_chunk, beta0, params, z0=z0, mu0=mu0)


def admm_svm(
    solver: Solver,
    y: Array,
    c_upper: Array | float,
    beta: float,
    max_it: int = 10,
    z0: Array | None = None,
    mu0: Array | None = None,
    use_fused_update: bool = False,
    tol: float | None = None,
) -> tuple[ADMMState, ADMMTrace]:
    """Run MaxIt closed-form ADMM iterations (paper fixes MaxIt = 10).

    ``solver`` must apply (K̃ + βI)^{-1}; with the HSS factorization each call
    is O(d r).  Supports warm starts (z0, mu0) — used by the C-grid search.
    Single-problem (k = 1) view of ``admm_svm_batched``.
    """
    d = y.shape[0]
    c_vec = jnp.broadcast_to(jnp.asarray(c_upper, y.dtype), (d,))
    state, trace = admm_svm_batched(
        lambda b: solver(b[:, 0])[:, None],
        y[None, :], c_vec[None, :], beta, max_it,
        z0=None if z0 is None else z0[:, None],
        mu0=None if mu0 is None else mu0[:, None],
        use_fused_update=use_fused_update,
        tol=tol,
    )
    return (ADMMState(*(a[:, 0] for a in state)),
            ADMMTrace(trace.primal_res[:, 0], trace.dual_res[:, 0],
                      trace.iters_run[0],
                      None if trace.done is None else trace.done[0]))


def admm_svm_batched(
    solver_mat: SolverMat,
    ys: Array,
    c_upper: Array | float,
    beta: float,
    max_it: int = 10,
    z0: Array | None = None,
    mu0: Array | None = None,
    use_fused_update: bool = False,
    tol: float | None = None,
) -> tuple[ADMMState, ADMMTrace]:
    """Run k SVM dual ADMM problems that share one (K̃ + βI) factorization.

    ``ys`` is (k, d): one ±1 label vector per problem (the per-class label
    vectors of a one-vs-rest reduction, or per-pair vectors of one-vs-one).
    The binary-classification instance of :func:`admm_boxqp` — the kernel
    side of the x-step is label-independent, so w = K_β⁻¹ e is computed ONCE
    and shared by every problem, and the per-iteration solves of all k
    problems are ONE multi-RHS sweep over a (d, k) block.
    """
    return admm_boxqp(solver_mat, svm_task(ys, c_upper), beta, max_it=max_it,
                      tol=tol, z0=z0, mu0=mu0,
                      use_fused_update=use_fused_update)


def paper_beta(d: int) -> float:
    """The paper's β staging rule (§3.3): 1e2 / 1e3 / 1e4 by training size."""
    if d >= 1_000_000:
        return 1e4
    if d >= 100_000:
        return 1e3
    return 1e2
