"""Baselines the paper compares against (Tables 2/3) + a kernel-approx rival.

  * dense_admm  — same closed-form ADMM but with the EXACT kernel matrix and
    a dense Cholesky factorization of K + βI.  This is the "ADMM with true
    kernel" reference (the role RACQP plays in the paper: Table 3).
  * smo — a working-pair Sequential Minimal Optimization solver with
    max-violating-pair selection (the algorithmic core of LIBSVM: Table 2).
    Host/numpy implementation with an LRU kernel-row cache; intended for the
    moderate sizes used in benchmarks.
  * nystrom_admm — ADMM where K is replaced by a Nyström approximation and
    the shifted solve uses Woodbury (the "alternative kernel approximation"
    family from paper §1.1, to show where HSS wins: small-h kernels whose
    spectrum decays slowly).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np

from repro.core import admm as admm_mod
from repro.core.kernelfn import KernelSpec, kernel_block

Array = jax.Array


# ---------------------------------------------------------------------- #
# dense-kernel ADMM (RACQP-analogue)                                     #
# ---------------------------------------------------------------------- #
def dense_admm_fit(
    x: Array, y: Array, spec: KernelSpec, c_value: float, beta: float,
    max_it: int = 10,
) -> tuple[Array, Array]:
    """Returns (z, bias). O(d^3) factorization + O(d^2) per iteration."""
    k_mat = kernel_block(spec, x, x)
    d = x.shape[0]
    chol = jsl.cholesky(k_mat + beta * jnp.eye(d, dtype=x.dtype), lower=True)
    solver = lambda b: jsl.cho_solve((chol, True), b)
    state, _ = admm_mod.admm_svm(solver, y, c_value, beta, max_it)
    z = state.z
    bias = _dense_bias(k_mat, y, z, c_value)
    return z, bias


def _dense_bias(k_mat: Array, y: Array, z: Array, c_value: float,
                tol: float = 1e-6) -> Array:
    on_margin = ((z > tol) & (z < c_value - tol)).astype(z.dtype)
    kz = k_mat @ (y * z)
    n_m = jnp.sum(on_margin)
    b_margin = -(on_margin @ kz - on_margin @ y) / jnp.maximum(n_m, 1.0)
    sv = (z > tol).astype(z.dtype)
    b_all = -(sv @ kz - sv @ y) / jnp.maximum(jnp.sum(sv), 1.0)
    return jnp.where(n_m > 0, b_margin, b_all)


def dense_predict(x_train: Array, y: Array, z: Array, bias: Array,
                  spec: KernelSpec, x_test: Array) -> Array:
    scores = kernel_block(spec, x_test, x_train) @ (y * z) + bias
    return jnp.where(scores >= 0, 1, -1)


# ---------------------------------------------------------------------- #
# SMO (LIBSVM-analogue), host implementation                             #
# ---------------------------------------------------------------------- #
def smo_fit(
    x: np.ndarray, y: np.ndarray, spec: KernelSpec, c_value: float,
    tol: float = 1e-3, max_iter: int = 20000,
) -> tuple[np.ndarray, float, int]:
    """Max-violating-pair SMO on the dual. Returns (alpha, bias, iters)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    n = x.shape[0]
    sq = (x * x).sum(1)

    cache: dict[int, np.ndarray] = {}

    def krow(i: int) -> np.ndarray:
        if i not in cache:
            if len(cache) > 2048:
                cache.pop(next(iter(cache)))
            d2 = np.maximum(sq[i] + sq - 2.0 * (x @ x[i]), 0.0)
            cache[i] = np.exp(-d2 / (2.0 * spec.h * spec.h))
        return cache[i]

    alpha = np.zeros(n)
    grad = -np.ones(n)          # G = ∇(½aᵀQa − eᵀa) = Qa − e,  Q = Y K Y
    it = 0
    for it in range(max_iter):
        # LIBSVM WSS1: i = argmax_{I_up} −y G;  j = argmin_{I_low} −y G
        up = ((alpha < c_value - 1e-12) & (y > 0)) | \
             ((alpha > 1e-12) & (y < 0))
        lo = ((alpha < c_value - 1e-12) & (y < 0)) | \
             ((alpha > 1e-12) & (y > 0))
        if not up.any() or not lo.any():
            break
        myg = -y * grad
        i = int(np.argmax(np.where(up, myg, -np.inf)))
        j = int(np.argmin(np.where(lo, myg, np.inf)))
        gap = myg[i] - myg[j]
        if gap < tol:
            break
        ki, kj = krow(i), krow(j)
        # a = Q_ii + Q_jj − 2 y_i y_j K_ij
        quad = max(ki[i] + kj[j] - 2.0 * y[i] * y[j] * ki[j], 1e-12)
        t = gap / quad           # step in the (y_i α_i, −y_j α_j) direction
        # box clipping preserving yᵀα: Δα_i = +y_i t, Δα_j = −y_j t
        if y[i] > 0:
            t = min(t, c_value - alpha[i])
        else:
            t = min(t, alpha[i])
        if y[j] > 0:
            t = min(t, alpha[j])
        else:
            t = min(t, c_value - alpha[j])
        t = max(t, 0.0)
        dai = y[i] * t
        daj = -y[j] * t
        alpha[i] += dai
        alpha[j] += daj
        # G += Q[:, i] Δα_i + Q[:, j] Δα_j,  Q[:, t] = y ⊙ K[:, t] y_t
        grad += y * (ki * (y[i] * dai) + kj * (y[j] * daj))
    # bias from margin SVs
    on_m = (alpha > 1e-8) & (alpha < c_value - 1e-8)
    ya = y * alpha
    if on_m.any():
        idx = np.where(on_m)[0][:256]
        scores = np.array([krow(int(i)) @ ya for i in idx])
        b = float(np.mean(y[idx] - scores))
    else:
        b = 0.0
    return alpha, b, it + 1


# ---------------------------------------------------------------------- #
# Nyström + ADMM (Woodbury shifted solve)                                #
# ---------------------------------------------------------------------- #
def nystrom_admm_fit(
    x: Array, y: Array, spec: KernelSpec, c_value: float, beta: float,
    n_landmarks: int = 256, max_it: int = 10, seed: int = 0,
) -> tuple[Array, Array]:
    """K ≈ Z Zᵀ (Z = K(X,L) W^{-1/2}); (βI + ZZᵀ)^{-1} via Woodbury."""
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    lm = jax.random.choice(key, n, (min(n_landmarks, n),), replace=False)
    xl = jnp.take(x, lm, axis=0)
    w = kernel_block(spec, xl, xl)
    evals, evecs = jnp.linalg.eigh(w)
    inv_sqrt = jnp.where(evals > 1e-8, 1.0 / jnp.sqrt(jnp.maximum(evals, 1e-8)), 0.0)
    w_isqrt = (evecs * inv_sqrt) @ evecs.T
    z_mat = kernel_block(spec, x, xl) @ w_isqrt          # (n, k)
    k_small = z_mat.T @ z_mat
    eye_k = jnp.eye(z_mat.shape[1], dtype=x.dtype)
    chol = jsl.cholesky(beta * eye_k + k_small, lower=True)

    def solver(b: Array) -> Array:
        t = jsl.cho_solve((chol, True), z_mat.T @ b)
        return (b - z_mat @ t) / beta

    state, _ = admm_mod.admm_svm(solver, y, c_value, beta, max_it)
    z = state.z
    # bias with the approximate kernel (one matvec through the factors)
    kz = z_mat @ (z_mat.T @ (y * z))
    on_margin = ((z > 1e-6) & (z < c_value - 1e-6)).astype(z.dtype)
    n_m = jnp.sum(on_margin)
    bias = -(on_margin @ kz - on_margin @ y) / jnp.maximum(n_m, 1.0)
    return z, bias
