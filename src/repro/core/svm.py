"""SVM training/prediction via HSS + ADMM (paper Algorithm 3).

Pipeline (= paper Alg. 3):
  1. K̃   = HSScompression(K(F_train, F_train), h)          [compress once]
  2. fac  = factorize(K̃ + βI)                               [factor once]
  3. for C in grid: run MaxIt ADMM iterations                [O(d r) each]
  4. bias via eq. (7) — ONE HSS matvec instead of d kernel evaluations
  5. predict: sign(Σ_i (z_y)_i K(f_i, f_test_j) + b), streamed block kernel
     evaluations (the Pallas gaussian kernel on TPU).

Padding: datasets are padded to leaf_size * 2**levels with mutually-far
points (tree.pad_dataset).  Pads get box constraint [0, 0] so the ADMM fixed
point has x_pad = z_pad = 0 and the restriction to real points solves the
original problem; kernel rows of pads are ~0 so K̃_pad ≈ blockdiag(K̃, I),
leaving the real block's solves untouched.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core.hss import HSSMatrix, shrink_report
from repro.core.kernelfn import DEFAULT_SCORE_BLOCK, KernelSpec, kernel_block

Array = jax.Array


@dataclasses.dataclass
class SVMModel:
    """A trained classifier: support coefficients in permuted order."""

    x_perm: Array          # (N, f) padded+permuted training points
    z_y: Array             # (N,)  y_i * z_i  (pads are exactly 0)
    bias: float
    spec: KernelSpec
    c_value: float

    def decision_function(self, x_test: Array,
                          block: int = DEFAULT_SCORE_BLOCK) -> Array:
        from repro.core.kernelfn import kernel_matvec_streamed

        scores = kernel_matvec_streamed(
            self.spec, x_test, self.x_perm, self.z_y, block=block
        )
        return scores + self.bias

    def predict(self, x_test: Array,
                block: int = DEFAULT_SCORE_BLOCK) -> Array:
        return jnp.where(self.decision_function(x_test, block=block) >= 0,
                         1, -1)


@dataclasses.dataclass
class FitReport:
    """Timings mirroring the paper's Tables 4/5 columns.

    The rank fields are populated by adaptive (``CompressionParams.rtol``)
    builds: per-level stored rank caps before/after the shrink-to-fit pass,
    the corresponding Σ n_k·r_k storage sums, and the exact number of kernel
    entries the compression evaluated — the observability hooks the bench
    records so rank adaptivity shows up in the perf trajectory.
    """

    compression_s: float
    factorization_s: float
    admm_s: float
    memory_mb: float
    hss_levels: int
    beta: float
    ranks_pre: tuple | None = None
    ranks_post: tuple | None = None
    rank_sum_pre: int | None = None
    rank_sum_post: int | None = None
    kernel_evals: int | None = None
    # per-problem ADMM iterations actually run by the last train() — below
    # max_it when the residual stopping rule (``tol``) froze the iterates
    iters_run: tuple | None = None
    # streamed-build observability (compression.StreamStats): peak device
    # bytes of any one batch round-trip — the build's working set, which a
    # streamed build bounds by batch size instead of O(N·d) — plus the
    # batch count and resume/restart record
    peak_stream_bytes: int | None = None
    stream_batches: int | None = None
    stream_resumed_level: int | None = None
    stream_restarts: int | None = None
    # adaptive-ρ record of the last train(): final β and rescale count
    rho_final: float | None = None
    rho_rescales: int | None = None


@dataclasses.dataclass
class HSSSVMTrainer:
    """compress-once / factor-once / train-many driver."""

    spec: KernelSpec
    comp: compression.CompressionParams = dataclasses.field(
        default_factory=compression.CompressionParams
    )
    leaf_size: int = 128
    beta: float | None = None     # default: the paper's rule by dataset size
    max_it: int = 10
    tol: float | None = None      # ADMM residual early-stop (paper's rule)

    # populated by prepare():
    _hss: HSSMatrix | None = None
    _fac: factorization.HSSFactorization | None = None
    _y: Array | None = None
    _cmask: Array | None = None    # 1.0 for real points, 0.0 for pads
    _report: FitReport | None = None
    _jit_admm: object = None       # jitted ADMM over (fac, y, c_vec, warm)

    # ------------------------------------------------------------------ #
    def prepare(self, x: np.ndarray, y: np.ndarray) -> FitReport:
        """Pad, build tree, compress, factorize.  (Paper Alg. 3 lines 1–6.)"""
        x = np.asarray(x, np.float32)
        y = np.asarray(y, np.float32)
        d_real = x.shape[0]
        x_pad, y_pad, mask, levels = tree_mod.pad_dataset(x, y, self.leaf_size)
        t = tree_mod.build_tree(x_pad, self.leaf_size, levels)
        xp = jnp.asarray(x_pad[t.perm])
        yp = jnp.asarray(y_pad[t.perm])
        maskp = jnp.asarray(mask[t.perm].astype(np.float32))

        t0 = time.perf_counter()
        hss = compression.compress(xp, t, self.spec, self.comp)
        # Adaptive builds: slice every level to its observed max rank before
        # the factorization, so factor + every per-iteration solve run at the
        # detected ranks instead of the cap (shrink time bills to compression).
        hss, rank_info = shrink_report(hss)
        jax.block_until_ready(hss.d_leaf)
        t1 = time.perf_counter()
        beta = self.beta if self.beta is not None else admm_mod.paper_beta(d_real)
        fac = factorization.factorize(hss, beta)
        jax.block_until_ready(fac.root_lu)
        t2 = time.perf_counter()

        self._hss, self._fac, self._y, self._cmask = hss, fac, yp, maskp
        self._report = FitReport(
            compression_s=t1 - t0,
            factorization_s=t2 - t1,
            admm_s=0.0,
            memory_mb=hss.memory_bytes() / 1e6,
            hss_levels=t.levels,
            beta=beta,
            kernel_evals=compression.kernel_eval_count(t, self.comp),
            **rank_info,
        )
        return self._report

    # ------------------------------------------------------------------ #
    def train(self, c_value: float, warm: tuple[Array, Array] | None = None
              ) -> tuple[SVMModel, tuple[Array, Array]]:
        """One ADMM run for a fixed C, reusing the cached factorization."""
        assert self._fac is not None, "call prepare() first"
        fac, y, mask = self._fac, self._y, self._cmask
        c_vec = c_value * mask           # pads pinned to [0, 0]

        if self._jit_admm is None:
            max_it, tol = self.max_it, self.tol

            def _run(fac_, y_, c_vec_, z0, mu0):
                return admm_mod.admm_svm(fac_.solve, y_, c_vec_, fac_.beta,
                                         max_it, z0=z0, mu0=mu0, tol=tol)

            self._jit_admm = jax.jit(_run)

        zeros = jnp.zeros_like(y)
        t0 = time.perf_counter()
        state, trace = self._jit_admm(
            fac, y, c_vec,
            zeros if warm is None else warm[0],
            zeros if warm is None else warm[1],
        )
        z = jax.block_until_ready(state.z)
        t1 = time.perf_counter()
        if self._report is not None:
            self._report.admm_s += t1 - t0
            self._report.iters_run = (int(trace.iters_run),)

        bias = compute_bias(self._hss, y, z, c_value, mask)
        model = SVMModel(
            x_perm=self._hss.x, z_y=y * z, bias=float(bias),
            spec=self.spec, c_value=c_value,
        )
        return model, (state.z, state.mu)

    # ------------------------------------------------------------------ #
    def fit(self, x: np.ndarray, y: np.ndarray, c_value: float = 1.0) -> SVMModel:
        self.prepare(x, y)
        model, _ = self.train(c_value)
        return model

    @property
    def report(self) -> FitReport:
        assert self._report is not None
        return self._report


def compute_bias_batched(hss: HSSMatrix, ys: Array, z: Array, c_mat: Array,
                         masks: Array, margin_tol: float = 1e-6) -> Array:
    """Paper eq. (7) for P problems sharing one kernel, with ONE HSS matmat.

    b_p = (z_yᵀ K̃ ē − Σ_{j∈M_p} y_j) / |M_p| where M_p = margin support
    vectors {j : 0 < z_jp < C_jp} of problem p.  Falls back to the average
    functional margin over all bounded SVs when M_p is empty.  ``ys``/``z``/
    ``c_mat``/``masks`` are (d, P) column blocks; returns (P,).
    """
    f32 = jnp.float32
    on_margin = (
        (z > margin_tol) & (z < c_mat - margin_tol) & (masks > 0)
    ).astype(z.dtype)
    n_m = jnp.sum(on_margin, axis=0)                       # (P,)
    kz = hss.matmat(ys * z)                 # K̃ (Y z) — one O(N r) sweep
    num = (jnp.einsum("dp,dp->p", on_margin, kz, preferred_element_type=f32)
           - jnp.einsum("dp,dp->p", on_margin, ys,
                        preferred_element_type=f32))
    b_margin = -num / jnp.maximum(n_m, 1.0)
    # Fallback per problem: average functional margin over all (bounded) SVs.
    sv = ((z > margin_tol) & (masks > 0)).astype(z.dtype)
    n_sv = jnp.maximum(jnp.sum(sv, axis=0), 1.0)
    b_all = -(jnp.einsum("dp,dp->p", sv, kz, preferred_element_type=f32)
              - jnp.einsum("dp,dp->p", sv, ys,
                           preferred_element_type=f32)) / n_sv
    return jnp.where(n_m > 0, b_margin, b_all)


def compute_bias(hss: HSSMatrix, y: Array, z: Array, c_value: float,
                 mask: Array, margin_tol: float = 1e-6) -> Array:
    """Paper eq. (7) for a single binary problem (P=1 view of the batched
    computation)."""
    c_mat = jnp.full((z.shape[0], 1), c_value, z.dtype)
    return compute_bias_batched(
        hss, y[:, None], z[:, None], c_mat, mask[:, None], margin_tol)[0]


def prolong_duals(x_coarse: np.ndarray, z_coarse: np.ndarray,
                  x_fine: np.ndarray) -> np.ndarray:
    """Nearest-neighbour prolongation of per-point dual columns.

    The AML-SVM multilevel scheme (arXiv 2011.02592): a dual vector trained
    on a coarse subsample is lifted to the fine set by giving every fine
    point its nearest coarse point's dual value — support-vector regions
    stay support-vector regions, so the fine ADMM starts near its fixed
    point instead of at zero.  ``x_coarse`` (n_c, f) / ``x_fine`` (n_f, f)
    are point sets (padded, permuted — any consistent order), ``z_coarse``
    is (n_c,) or (n_c, P); returns the matching (n_f, ...) array.  Distances
    are ranked in f32 (bf16 inputs are fine); the dual VALUES are copied
    untouched.  Task-dependent mass rescaling is ``tasks.prolong_scale``.
    """
    from scipy.spatial import cKDTree

    xc = np.asarray(x_coarse, np.float32)
    xf = np.asarray(x_fine, np.float32)
    _, nn = cKDTree(xc).query(xf, k=1)
    return np.asarray(z_coarse)[nn]


def run_grid_search(
    make_trainer,
    x: np.ndarray,
    y: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    hs: Sequence[float],
    cs: Sequence[float],
    score_fn=None,
) -> tuple[object, dict]:
    """Generic (h, knob) grid driver shared by every box-QP task sweep.

    Per h: ONE trainer (= one compression + one factorization via prepare);
    the knob sweep — C for classification, ε for SVR, ν for one-class —
    reuses them (the paper's headline amortization) and warm-starts
    consecutive values.  ``make_trainer(h)`` builds the trainer; the best
    model is picked by ``score_fn(model, x_val, y_val)`` (higher is better;
    default: classification accuracy).  Returns it + a results table whose
    ``accuracy`` entries hold the score.
    """
    if score_fn is None:
        def score_fn(model, x_v, y_v):
            return float(jnp.mean(model.predict(x_v) == jnp.asarray(y_v)))
    results = {}
    best = (None, -np.inf, None, None)
    for h in hs:
        trainer = make_trainer(float(h))
        trainer.prepare(x, y)
        warm = None
        admm_seen = 0.0
        for c in cs:
            model, warm = trainer.train(float(c), warm=warm)
            acc = score_fn(model, jnp.asarray(x_val), y_val)
            # report.admm_s accumulates across the warm-started C sweep;
            # each cell records only its own run's time
            admm_total = trainer.report.admm_s
            results[(h, c)] = dict(
                accuracy=acc,
                admm_s=admm_total - admm_seen,
                compression_s=trainer.report.compression_s,
                factorization_s=trainer.report.factorization_s,
            )
            admm_seen = admm_total
            if acc > best[1]:
                best = (model, acc, h, c)
    return best[0], dict(results=results, best_h=best[2], best_c=best[3],
                         best_accuracy=best[1])


def resolve_rtol(trainer_kwargs: dict | None, rtol: float | None) -> dict:
    """Fold the paper-facing accuracy knob into a trainer kwargs dict.

    ``rtol`` mirrors STRUMPACK's rel_tol (crude ≈ 1e-2, accurate ≈ 1e-4,
    Tables 4–5); it overrides the ``comp`` entry's tolerance while keeping
    every other compression knob — ``rank`` stays the hss_max_rank cap.
    """
    kw = dict(trainer_kwargs or {})
    if rtol is not None:
        base = kw.get("comp", compression.CompressionParams())
        kw["comp"] = dataclasses.replace(base, rtol=rtol)
    return kw


def grid_search(
    x: np.ndarray,
    y: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    hs: Sequence[float],
    cs: Sequence[float],
    trainer_kwargs: dict | None = None,
    rtol: float | None = None,
) -> tuple[SVMModel, dict]:
    """(h, C) grid search (paper §3.3) for the binary trainer.

    ``rtol`` switches the sweep to the adaptive tolerance-driven HSS build
    (see ``resolve_rtol``): each h's compression detects per-node ranks,
    shrinks to fit, and the whole C sweep reuses the smaller factorization.
    """
    kw = resolve_rtol(trainer_kwargs, rtol)
    return run_grid_search(
        lambda h: HSSSVMTrainer(spec=KernelSpec(h=h), **kw),
        x, y, x_val, y_val, hs, cs)
