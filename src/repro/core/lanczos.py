"""Lanczos on the O(N r) HSS matvec: leading eigenpairs + spectral embedding.

The engine's second core asset (after the factorized solve) is the telescoping
``HSSMatrix.matmat`` — a fast symmetric operator apply.  m Lanczos steps with
full reorthogonalization give the leading eigenpairs of K̃ to working accuracy
at O(m · N r) kernel-operator cost, which turns the trained compression into a
kernel-PCA / spectral-clustering feature extractor for free.

The iteration is a ``lax.scan`` over a statically-shaped basis block, so the
whole sweep is jit-compatible (one compile per (n, num_iters) shape) and runs
under an active ``dist.api.use_mesh`` unchanged — the matvec pins its own
per-level intermediates via ``constrain_nodes``.

Padded datasets (``tree.pad_dataset``): the pad block of K̃ is ≈ I (mutually
far inert points), so pads contribute a cluster of eigenvalues ≈ 1 with
pad-supported eigenvectors.  Keep k below the number of data eigenvalues
exceeding 1 (the usual regime — leading kernel eigenvalues grow like O(n)),
or read the embedding through ``HSSSVMEngine.spectral_embed`` which drops pad
rows explicitly.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# Below this residual norm the Krylov space is exhausted (lucky breakdown):
# the next basis vector is zeroed instead of amplifying float noise.
_BREAKDOWN = 1e-30


def lanczos(matvec: Callable[[Array], Array], v0: Array, num_iters: int
            ) -> tuple[Array, Array, Array]:
    """``num_iters`` Lanczos steps with FULL reorthogonalization.

    Returns ``(alphas (m,), betas (m,), basis (m+1, n))`` with the symmetric
    tridiagonal T = diag(alphas) + offdiag(betas[:m-1]); ``betas[m-1]`` is
    the final residual norm.  All arithmetic is f32 regardless of the input
    dtype; the reorthogonalization is the classical twice-is-enough double
    Gram-Schmidt against the whole stored basis (rows not yet written are
    zero and contribute nothing), which is what keeps Ritz pairs honest at
    float32 — plain three-term recurrences lose orthogonality long before
    the leading eigenvalues converge.
    """
    f32 = jnp.float32
    n = v0.shape[0]
    v0 = v0.astype(f32)
    v0 = v0 / jnp.linalg.norm(v0)
    basis0 = jnp.zeros((num_iters + 1, n), f32).at[0].set(v0)

    def step(carry, i):
        basis, alphas, betas = carry
        v = basis[i]
        w = matvec(v).astype(f32)
        a = jnp.einsum("n,n->", v, w, preferred_element_type=f32)
        for _ in range(2):            # double Gram-Schmidt vs the full basis
            coef = jnp.einsum("kn,n->k", basis, w, preferred_element_type=f32)
            w = w - jnp.einsum("kn,k->n", basis, coef,
                               preferred_element_type=f32)
        b = jnp.linalg.norm(w)
        v_next = jnp.where(b > _BREAKDOWN, w / jnp.maximum(b, _BREAKDOWN),
                           jnp.zeros_like(w))
        return (basis.at[i + 1].set(v_next),
                alphas.at[i].set(a), betas.at[i].set(b)), None

    (basis, alphas, betas), _ = jax.lax.scan(
        step, (basis0, jnp.zeros(num_iters, f32), jnp.zeros(num_iters, f32)),
        jnp.arange(num_iters))
    return alphas, betas, basis


def tridiag_eigh(alphas: Array, offdiag: Array) -> tuple[Array, Array]:
    """eigh of the (m, m) symmetric tridiagonal — m is small, dense is fine."""
    t = (jnp.diag(alphas) + jnp.diag(offdiag, 1) + jnp.diag(offdiag, -1))
    return jnp.linalg.eigh(t)


def default_iters(n: int, k: int) -> int:
    """Default Krylov depth: comfortably past k so the leading Ritz pairs
    converge, capped by the problem size."""
    return min(n, max(2 * k + 10, 3 * k))


def top_eigenpairs(hss, k: int, num_iters: int | None = None, seed: int = 0
                   ) -> tuple[Array, Array]:
    """Leading k eigenpairs of K̃ via Lanczos on ``hss.matvec``.

    Returns ``(eigenvalues (k,) descending, vectors (n, k))`` in the
    permuted/padded row order of ``hss.x``.  Ritz residuals ‖K̃v − λv‖ are
    at the Lanczos convergence level for the leading pairs (tested against
    dense eigendecompositions in the property tier).
    """
    n = hss.n
    m = num_iters if num_iters is not None else default_iters(n, k)
    if not 0 < k <= m:
        raise ValueError(f"need 0 < k <= num_iters, got k={k}, m={m}")
    v0 = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    alphas, betas, basis = lanczos(hss.matvec, v0, m)
    evals, evecs = tridiag_eigh(alphas, betas[:-1])
    top = jnp.argsort(evals)[::-1][:k]
    ritz = jnp.einsum("mn,mk->nk", basis[:m], evecs[:, top],
                      preferred_element_type=jnp.float32)
    return evals[top], ritz


def spectral_embed(hss, k: int, num_iters: int | None = None, seed: int = 0
                   ) -> tuple[Array, Array]:
    """Kernel-PCA coordinates: eigenvectors scaled by sqrt(eigenvalue).

    Returns ``(coords (n, k), eigenvalues (k,))`` in permuted/padded row
    order; ``HSSSVMEngine.spectral_embed`` maps back to the original row
    order and drops pads.
    """
    evals, vecs = top_eigenpairs(hss, k, num_iters=num_iters, seed=seed)
    return vecs * jnp.sqrt(jnp.maximum(evals, 0.0))[None, :], evals
