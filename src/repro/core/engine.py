"""One orchestration layer for the whole HSS-ADMM SVM pipeline.

``HSSSVMEngine`` owns every stage of paper Algorithm 3 — partition (pad +
cluster tree) → HSS compression → ULV-equivalent factorization → batched
ADMM → bias → prediction — through ONE code path for both the local
single-device case and the mesh-parallel case:

  * ``mesh=None``: the stages are exactly ``compression.compress`` /
    ``factorization.factorize`` / ``admm_svm_batched`` on one device.
  * ``mesh=Mesh(...)``: the SAME stages run node/sample-sharded end-to-end
    (``compress_sharded`` / ``factorize_sharded``), so no stage ever
    materializes an unsharded O(N·m) array on a single device — the leaf
    diagonal blocks, leaf bases, E/G factors, label matrix, and ADMM
    iterates all live sharded over the full device set from the moment they
    are created.  Bias extraction and prediction scoring also run on the
    sharded representation (one ``psum`` of per-device partial scores)
    without ever gathering ``x_perm``.

Binary problems (labels ±1) and k-class problems (arbitrary labels, OVR or
OVO reduction) share the path: the engine always trains the (d, P)-block
batched ADMM with P = 1 for binary — the multiclass economy of
``core.multiclass`` with the distribution of ``core.distributed``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core import tasks as tasks_mod
from repro.core.hss import HSSMatrix, shrink_report
from repro.core.kernelfn import (
    DEFAULT_SCORE_BLOCK, KernelSpec, kernel_matvec_streamed,
)
from repro.core.multiclass import ovo_problems, ovo_vote, ovr_problems
from repro.core.svm import FitReport, compute_bias_batched
from repro.dist import api as dist_api
from repro.dist.api import mesh_ndev

Array = jax.Array


def _node_spec(mesh: Mesh) -> PartitionSpec:
    return PartitionSpec(tuple(mesh.axis_names))


@dataclasses.dataclass
class EngineModel:
    """A trained (binary or k-class) classifier, possibly mesh-resident.

    ``x_perm``/``z_y`` stay sharded over the mesh's sample axis when the
    model was trained under one; scoring then evaluates each device's
    test×local-support kernel blocks and psums the partial scores — the
    support set is never gathered to one device.
    """

    x_perm: Array          # (d, f) padded+permuted training points
    z_y: Array             # (d, P) per-problem s_i * z_i columns (pads are 0;
                           #  y_i z_i for SVM, the dual coefficients α for
                           #  SVR / one-class)
    biases: Array          # (P,)  (−ρ for one-class)
    classes: np.ndarray    # (k,) original class labels (an unused [-1, 1]
                           #  placeholder for svr / oneclass models)
    spec: KernelSpec
    c_value: float         # the task knob it was trained at (C / ε / ν)
    binary: bool
    strategy: str = "ovr"
    task: str = "svm"      # "svm" | "svr" | "oneclass" | "krr" | "gp"
    pairs: np.ndarray | None = None     # (P, 2) class indices, ovo only
    mesh: Mesh | None = None
    # β of the factorization the model was trained on — the serve-time
    # factorization-sharing cache key is (kernel, h, β, support set): two
    # models agreeing on it were trained on the SAME K̃ + βI.
    beta: float | None = None
    _score_fns: dict | None = None      # block -> cached jitted scorer

    @property
    def n_classes(self) -> int:
        return int(self.classes.shape[0])

    def _mesh_scorer(self, block: int):
        if self._score_fns is None:
            self._score_fns = {}
        fn = self._score_fns.get(block)
        if fn is None:
            spec, mesh = self.spec, self.mesh
            axes = tuple(mesh.axis_names)

            def body(xt, xp, zy):
                part = kernel_matvec_streamed(spec, xt, xp, zy, block=block)
                return jax.lax.psum(part, axes)

            fn = jax.jit(dist_api.shard_map(
                body, mesh,
                in_specs=(PartitionSpec(), _node_spec(mesh),
                          _node_spec(mesh)),
                out_specs=PartitionSpec()))
            self._score_fns[block] = fn
        return fn

    def decision_function(self, x_test: Array,
                          block: int = DEFAULT_SCORE_BLOCK) -> Array:
        """Scores (n_test, P); single-column tasks (binary SVM, SVR,
        one-class) return the flat (n_test,) column."""
        x_test = jnp.asarray(x_test)
        if self.mesh is None:
            scores = kernel_matvec_streamed(
                self.spec, x_test, self.x_perm, self.z_y, block=block)
        else:
            scores = self._mesh_scorer(block)(x_test, self.x_perm, self.z_y)
        scores = scores + self.biases[None, :]
        if self.binary or self.task in ("svr", "oneclass", "krr", "gp"):
            return scores[:, 0]
        return scores

    def predict(self, x_test: Array,
                block: int = DEFAULT_SCORE_BLOCK) -> Array:
        scores = self.decision_function(x_test, block=block)
        if self.task in ("svr", "krr", "gp"):
            return scores               # regression: scores ARE predictions
        if self.task == "oneclass":      # +1 inlier / −1 outlier
            return jnp.where(scores >= 0, 1, -1)
        if self.binary:
            return jnp.where(scores >= 0, 1, -1)
        if self.strategy == "ovr":
            idx = jnp.argmax(scores, axis=1)
        else:
            idx = ovo_vote(scores, self.pairs, self.n_classes)
        return jnp.asarray(self.classes)[idx]


@dataclasses.dataclass
class HSSSVMEngine:
    """partition → compress → factorize → ADMM → bias/predict, local or mesh.

    The paper's compress-once / factor-once / train-many economy, owned by
    one object; pass ``mesh`` to run every stage sharded (see module
    docstring).  ``store_dtype="bfloat16"`` stores the E/G factors in bf16
    (solves still accumulate in f32).

    ``task`` selects the box-QP instance trained on the shared
    factorization (repro.core.admm / repro.core.tasks):
      * ``"svm"``      — classification; ``train``'s knob is C, ``y`` holds
        labels (binary ±1 or k-class, OVR/OVO per ``strategy``);
      * ``"svr"``      — ε-SVR; the knob is ε (the C box bound is the
        ``svr_c`` field), ``y`` holds float regression targets;
      * ``"oneclass"`` — ν one-class SVM; the knob is ν, ``y`` is ignored
        (unsupervised — pass None);
      * ``"krr"`` / ``"gp"`` — kernel ridge regression / GP posterior mean
        (repro.core.krr): the knob is the ridge / noise λ, which rides the
        factorization's β shift slot, and ``train`` is ONE multi-RHS solve
        with ZERO ADMM iterations (``FitReport.iters_run == (0,)``); ``y``
        holds float regression targets.  ``"gp"`` additionally exposes
        ``log_marginal`` for (h, λ) grid scoring.

    ``tol`` enables the paper's residual stopping rule: a problem's ADMM
    updates freeze once max(primal, dual) < tol and ``FitReport.iters_run``
    records the live iteration counts (None = always run ``max_it``).

    ``stream`` switches ``prepare`` to the out-of-core streamed build
    (``compression.compress_streamed``): the dataset never has to be
    device-resident during compression, peak device bytes are bounded by
    ``stream.batch_leaves``, and with ``stream.ckpt_dir`` set an interrupted
    build resumes at its last completed level.  ``admm`` (an
    ``ADMMParams``) overrides ``max_it``/``tol`` and can switch on
    residual-balancing adaptive ρ — each β rescale refactorizes K̃ + βI
    once, cached per visited β.
    """

    spec: KernelSpec
    comp: compression.CompressionParams = dataclasses.field(
        default_factory=compression.CompressionParams
    )
    leaf_size: int = 128
    beta: float | None = None     # default: the paper's rule by dataset size
    max_it: int = 10
    mesh: Mesh | None = None
    strategy: str = "ovr"         # multiclass reduction: "ovr" | "ovo"
    store_dtype: str | None = None
    task: str = "svm"             # "svm" | "svr" | "oneclass" | "krr" | "gp"
    svr_c: float = 1.0            # SVR box bound C (ε is the train knob)
    tol: float | None = None      # ADMM residual early-stop threshold
    stream: compression.StreamParams | None = None   # out-of-core build
    admm: admm_mod.ADMMParams | None = None          # iteration control

    # populated by prepare():
    _hss: HSSMatrix | None = None
    _fac: factorization.HSSFactorization | None = None
    _ys: Array | None = None       # (P, d) per-problem ±1 labels
    _pmask: Array | None = None    # (P, d) participation masks
    _classes: np.ndarray | None = None
    _pairs: np.ndarray | None = None
    _binary: bool = False
    _report: FitReport | None = None
    _jit_admm: object = None
    _jit_bias: object = None
    # The EFFECTIVE mesh: self.mesh, or None when the tree cannot shard
    # evenly over it (non-power-of-two device count) — then every stage
    # falls back to the local path instead of crashing on placement.
    _mesh: Mesh | None = None
    # multilevel warm start inputs + adaptive-ρ machinery
    _x_raw: np.ndarray | None = None
    _y_raw: np.ndarray | None = None
    _perm_host: np.ndarray | None = None   # tree perm (host) — pad unmapping
    _xp_host: np.ndarray | None = None     # padded+permuted points (host)
    _maskp_host: np.ndarray | None = None  # (d,) real-point mask (host)
    _fac_cache: dict | None = None         # beta -> factorization
    _chunk_fns: dict | None = None         # chunk length -> jitted runner

    # ------------------------------------------------------------------ #
    @contextlib.contextmanager
    def _active(self):
        """The mesh context all jitted stages trace/run under (no-op local)."""
        if self._mesh is None:
            yield
        else:
            with dist_api.use_mesh(self._mesh), self._mesh:
                yield

    def _min_levels(self) -> int:
        """Force enough splits that the leaf axis divides the device count."""
        if self.mesh is None:
            return 0
        ndev = mesh_ndev(self.mesh)
        if ndev & (ndev - 1):
            return 0            # non-power-of-two mesh: local-build fallback
        levels = 0
        while 2 ** levels < ndev:
            levels += 1
        return levels

    # ------------------------------------------------------------------ #
    def prepare(self, x: np.ndarray, y: np.ndarray | None = None) -> FitReport:
        """Pad + tree + compress ONCE + factorize ONCE (Alg. 3 lines 1–6)."""
        if self.strategy not in ("ovr", "ovo"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.task not in ("svm", "svr", "oneclass", "krr", "gp"):
            raise ValueError(f"unknown task {self.task!r}")
        x = np.asarray(x, np.float32)
        if self.task == "svm":
            if y is None:
                raise ValueError("task='svm' needs labels")
            y = np.asarray(y)
            classes = np.unique(y)
            if classes.shape[0] < 2:
                raise ValueError("need at least 2 classes")
            try:
                vals = set(np.asarray(classes, np.float64).tolist())
            except (TypeError, ValueError):
                vals = set()
            self._binary = classes.shape[0] == 2 and vals == {-1.0, 1.0}
        else:
            if self.task in ("svr", "krr", "gp") and y is None:
                raise ValueError(
                    f"task={self.task!r} needs regression targets")
            if y is None:                # one-class is unsupervised
                y = np.zeros(x.shape[0], np.float32)
            y = np.asarray(y)
            classes = np.array([-1.0, 1.0], np.float32)
            self._binary = False
        d_real = x.shape[0]
        x_pad, y_pad, mask, levels = tree_mod.pad_dataset(
            x, y.astype(np.float32), self.leaf_size,
            min_levels=self._min_levels())
        mesh = self.mesh
        if mesh is not None and (2 ** levels) % mesh_ndev(mesh) != 0:
            mesh = None         # un-shardable leaf count: run the local path
        self._mesh = mesh
        t = tree_mod.build_tree(x_pad, self.leaf_size, levels)
        xp_host = x_pad[t.perm]
        yp = y_pad[t.perm]
        maskp = mask[t.perm]

        if self.task != "svm":
            # one problem column: SVR's ys row holds the (mask-zeroed)
            # regression targets, one-class ignores it — the participation
            # mask is what pins pads to the inert [0, 0] box in both.
            ys = (yp * maskp)[None, :].astype(np.float32)
            pmasks = maskp[None, :].astype(np.float32)
            pairs = None
        elif self._binary:
            ys = np.where(yp > 0, 1.0, -1.0)[None, :].astype(np.float32)
            pmasks = maskp[None, :].astype(np.float32)
            pairs = None
        else:
            build = ovr_problems if self.strategy == "ovr" else ovo_problems
            ys, pmasks, pairs = build(yp, classes.astype(np.float32), maskp)

        t0 = time.perf_counter()
        sstats = None
        if self.stream is not None:
            hss, sstats = compression.compress_streamed(
                xp_host, t, self.spec, self.comp, stream=self.stream,
                mesh=mesh)
        elif mesh is not None:
            hss = compression.compress_sharded(
                xp_host, t, self.spec, self.comp, mesh)
        else:
            hss = compression.compress(xp_host, t, self.spec, self.comp)
        # Adaptive builds (comp.rtol set): slice every level down to its
        # observed max rank before factorizing — the factorization and every
        # downstream solve/matmat then run at the detected ranks, mesh
        # placement preserved via the shared node_partition_spec rule.
        hss, rank_info = shrink_report(hss, mesh=mesh)
        jax.block_until_ready(hss.d_leaf)
        t1 = time.perf_counter()
        beta = self.beta if self.beta is not None else admm_mod.paper_beta(
            d_real)
        if mesh is not None:
            fac = factorization.factorize_sharded(
                hss, beta, mesh, store_dtype=self.store_dtype)
        else:
            fac = factorization.factorize(
                hss, beta, store_dtype=self.store_dtype)
        jax.block_until_ready(fac.root_lu)
        t2 = time.perf_counter()

        if mesh is not None:
            row_sh = NamedSharding(
                mesh, PartitionSpec(None, tuple(mesh.axis_names)))
            ys_d = jax.device_put(jnp.asarray(ys), row_sh)
            pm_d = jax.device_put(jnp.asarray(pmasks), row_sh)
        else:
            ys_d, pm_d = jnp.asarray(ys), jnp.asarray(pmasks)

        self._hss, self._fac = hss, fac
        self._ys, self._pmask = ys_d, pm_d
        self._classes, self._pairs = classes, pairs
        self._jit_admm = self._jit_bias = None
        self._x_raw, self._y_raw = x, (None if y is None else np.asarray(y))
        self._perm_host = t.perm
        self._xp_host = xp_host
        self._maskp_host = maskp.astype(np.float32)
        self._fac_cache = {float(beta): fac}
        self._chunk_fns = {}
        self._report = FitReport(
            compression_s=t1 - t0,
            factorization_s=t2 - t1,
            admm_s=0.0,
            memory_mb=hss.memory_bytes() / 1e6,
            hss_levels=t.levels,
            beta=beta,
            kernel_evals=compression.kernel_eval_count(t, self.comp),
            **rank_info,
        )
        if sstats is not None:
            self._report.peak_stream_bytes = sstats.peak_stream_bytes
            self._report.stream_batches = sstats.n_batches
            self._report.stream_resumed_level = sstats.resumed_level
            self._report.stream_restarts = sstats.restarts
        return self._report

    # ------------------------------------------------------------------ #
    @property
    def n_problems(self) -> int:
        assert self._ys is not None, "call prepare() first"
        return int(self._ys.shape[0])

    @property
    def problem_labels(self) -> Array:
        """(P, d) per-problem ±1 labels in tree order (mesh-placed)."""
        assert self._ys is not None, "call prepare() first"
        return self._ys

    @property
    def problem_masks(self) -> Array:
        """(P, d) participation masks (0 pins a coordinate to the [0,0] box)."""
        assert self._pmask is not None, "call prepare() first"
        return self._pmask

    @property
    def hss(self) -> HSSMatrix:
        assert self._hss is not None, "call prepare() first"
        return self._hss

    @property
    def fac(self) -> factorization.HSSFactorization:
        assert self._fac is not None, "call prepare() first"
        return self._fac

    @property
    def report(self) -> FitReport:
        assert self._report is not None
        return self._report

    # ------------------------------------------------------------------ #
    def train(self, c_value: float, warm: tuple[Array, Array] | None = None
              ) -> tuple[EngineModel, tuple[Array, Array]]:
        """ONE batched ADMM run over all P subproblems for a fixed knob.

        ``c_value`` is the task's sweep knob: C for classification, ε for
        SVR (box bound from ``self.svr_c``), ν for one-class.  It enters the
        jitted run as a traced scalar, so a warm-started knob sweep compiles
        exactly once.
        """
        assert self._fac is not None, "call prepare() first"
        if self.task in ("krr", "gp"):
            return self._train_krr(c_value)
        if self.task == "oneclass" and not 0.0 < c_value <= 1.0:
            # nu > 1 makes e'alpha = 1 infeasible (box mass < 1), nu <= 0
            # divides by zero — either silently yields a garbage model.
            raise ValueError(f"oneclass needs 0 < nu <= 1, got {c_value}")
        if self.task == "svr" and c_value < 0.0:
            raise ValueError(f"svr needs epsilon >= 0, got {c_value}")
        fac, ys, pmask = self._fac, self._ys, self._pmask
        n_prob, d = ys.shape
        ap = self.admm
        eff_max_it = self.max_it if ap is None else ap.max_it
        eff_tol = self.tol if ap is None else ap.tol
        adapt = ap is not None and ap.adapt_rho

        if self._jit_bias is None:
            if self.task == "svr":
                self._jit_bias = jax.jit(tasks_mod.compute_bias_svr_batched)
            elif self.task == "oneclass":
                self._jit_bias = jax.jit(tasks_mod.compute_rho_oneclass_batched)
            else:
                self._jit_bias = jax.jit(compute_bias_batched)
        if not adapt and self._jit_admm is None:
            max_it, tol = eff_max_it, eff_tol
            task_name, svr_c = self.task, self.svr_c

            def _run(fac_, ys_, pmask_, knob, z0, mu0):
                task = self._build_task(task_name, svr_c, ys_, pmask_, knob)
                state, trace = admm_mod.admm_boxqp(
                    fac_.solve_mat, task, fac_.beta, max_it, tol=tol,
                    z0=z0, mu0=mu0)
                # only the oneclass rho extraction needs the box bounds —
                # skip materializing the (d, P) hi block everywhere else
                hi = task.hi if task_name == "oneclass" else ()
                return (state.z, state.mu, task.sign * state.z, hi,
                        trace.iters_run)

            self._jit_admm = jax.jit(_run)

        if self._mesh is None:
            zeros = jnp.zeros((d, n_prob), jnp.float32)
        else:
            zeros = jax.device_put(
                jnp.zeros((d, n_prob), jnp.float32),
                NamedSharding(self._mesh, PartitionSpec(
                    tuple(self._mesh.axis_names), None)))
        z0, mu0 = (zeros, zeros) if warm is None else warm
        knob = jnp.asarray(c_value, jnp.float32)

        rho_info = None
        with self._active():
            t0 = time.perf_counter()
            if adapt:
                z, mu, z_y, hi_mat, iters_run, rho_info = \
                    self._train_adaptive(ap, knob, z0, mu0, n_prob)
            else:
                z, mu, z_y, hi_mat, iters_run = self._jit_admm(
                    fac, ys, pmask, knob, z0, mu0)
            jax.block_until_ready(z)
            t1 = time.perf_counter()
            if self.task == "svr":
                biases = self._jit_bias(
                    self._hss, ys.T, z, self.svr_c * pmask.T, pmask.T, knob)
            elif self.task == "oneclass":
                biases = -self._jit_bias(self._hss, z, hi_mat, pmask.T)
            else:
                biases = self._jit_bias(
                    self._hss, ys.T, z, c_value * pmask.T, pmask.T)
        if self._report is not None:
            self._report.admm_s += t1 - t0
            self._report.iters_run = tuple(
                int(i) for i in np.asarray(iters_run))
            if rho_info is not None:
                self._report.rho_final = rho_info["beta"]
                self._report.rho_rescales = rho_info["rescales"]

        model = EngineModel(
            x_perm=self._hss.x, z_y=z_y, biases=biases,
            classes=self._classes, spec=self.spec, c_value=c_value,
            binary=self._binary, strategy=self.strategy, task=self.task,
            pairs=self._pairs, mesh=self._mesh,
            beta=float(self._fac.beta),
        )
        return model, (z, mu)

    # ------------------------------------------------------------------ #
    def _train_krr(self, lam: float) -> tuple[EngineModel, tuple[Array, Array]]:
        """KRR / GP-mean train: ONE multi-RHS solve, ZERO ADMM iterations.

        The knob λ rides the factorization's β shift slot: each distinct λ
        refactorizes the shared compression once (``_fac_for`` caches per
        visited λ, exactly like the adaptive-ρ rescale path) and the train
        step is a single ``solve_mat`` on the (d, P) target block.  The
        solve is jitted with the factorization as a pytree argument; β is a
        static field, so each λ traces once — noise next to its O(N r²)
        refactorization.
        """
        from repro.core import krr as krr_mod

        if not lam > 0.0:
            raise ValueError(f"{self.task} needs lambda > 0, got {lam}")
        ys, pmask = self._ys, self._pmask
        n_prob = ys.shape[0]
        if self._jit_admm is None:
            self._jit_admm = jax.jit(krr_mod.krr_solve)
        with self._active():
            t0 = time.perf_counter()
            fac = self._fac_for(float(lam))
            jax.block_until_ready(fac.root_lu)
            t1 = time.perf_counter()
            # pads decouple exactly ((1+λ)I block, zero targets); the mask
            # only clips factorization float noise off the pad coefficients
            alpha = self._jit_admm(fac, ys.T) * pmask.T
            jax.block_until_ready(alpha)
            t2 = time.perf_counter()
        if self._report is not None:
            self._report.factorization_s += t1 - t0
            self._report.admm_s += t2 - t1
            self._report.iters_run = (0,) * n_prob
        model = EngineModel(
            x_perm=self._hss.x, z_y=alpha,
            biases=jnp.zeros((n_prob,), jnp.float32),
            classes=self._classes, spec=self.spec, c_value=lam,
            binary=False, strategy=self.strategy, task=self.task,
            pairs=None, mesh=self._mesh, beta=float(fac.beta),
        )
        return model, (alpha, alpha)

    def log_marginal(self, lam: float, n_probes: int = 4,
                     num_iters: int = 20, seed: int = 0) -> float:
        """GP log marginal likelihood estimate at noise λ (see
        ``krr.gp_log_marginal``) — the ``task="gp"`` (h, λ) grid score."""
        from repro.core import krr as krr_mod

        assert self._fac is not None, "call prepare() first"
        if self.task not in ("krr", "gp"):
            raise ValueError(f"log_marginal needs task='krr'/'gp', "
                             f"got {self.task!r}")
        fac = self._fac_for(float(lam))
        with self._active():
            return krr_mod.gp_log_marginal(
                self._hss, fac, self._ys[0], mask=self._pmask[0],
                n_probes=n_probes, num_iters=num_iters, seed=seed)

    def top_eigenpairs(self, k: int, num_iters: int | None = None,
                       seed: int = 0) -> tuple[Array, Array]:
        """Leading k eigenpairs of the compressed kernel (Lanczos on the
        O(N r) matvec), in permuted/padded row order — any prepared task."""
        from repro.core import lanczos as lanczos_mod

        assert self._hss is not None, "call prepare() first"
        with self._active():
            return lanczos_mod.top_eigenpairs(
                self._hss, k, num_iters=num_iters, seed=seed)

    def spectral_embed(self, k: int, num_iters: int | None = None,
                       seed: int = 0) -> np.ndarray:
        """Kernel-PCA coordinates (n, k) for the ORIGINAL input rows.

        Eigenvectors scaled by sqrt(eigenvalue), mapped back through the
        tree permutation with pad rows dropped.  Keep k below the count of
        kernel eigenvalues exceeding 1 — the pad block of a padded build
        contributes an eigenvalue cluster at ≈ 1 (see repro.core.lanczos).
        """
        evals, vecs = self.top_eigenpairs(k, num_iters=num_iters, seed=seed)
        emb = (np.asarray(jax.device_get(vecs))
               * np.sqrt(np.maximum(np.asarray(jax.device_get(evals)), 0.0)))
        n = self._x_raw.shape[0]
        out = np.zeros((n, k), np.float32)
        real = self._perm_host < n
        out[self._perm_host[real]] = emb[real]
        return out

    # ------------------------------------------------------------------ #
    @staticmethod
    def _build_task(task_name: str, svr_c: float, ys_, pmask_, knob):
        """The engine's knob → BoxQPTask rule (shared by both ADMM paths)."""
        if task_name == "svr":
            return tasks_mod.svr_task(ys_, svr_c * pmask_, knob)
        if task_name == "oneclass":
            return tasks_mod.one_class_task(pmask_, knob)
        return admm_mod.svm_task(ys_, knob * pmask_)

    def _fac_for(self, beta: float) -> factorization.HSSFactorization:
        """Factorization of K̃ + βI, cached per visited β.

        The adaptive-ρ rescale path: β is the factorization shift, so a
        rescale means ONE refactorization (O(N r²) — cheap next to the
        compression it reuses) the first time each β is visited.
        """
        fac = self._fac_cache.get(float(beta))
        if fac is None:
            if self._mesh is not None:
                fac = factorization.factorize_sharded(
                    self._hss, beta, self._mesh, store_dtype=self.store_dtype)
            else:
                fac = factorization.factorize(
                    self._hss, beta, store_dtype=self.store_dtype)
            self._fac_cache[float(beta)] = fac
        return fac

    def _train_adaptive(self, ap: admm_mod.ADMMParams, knob, z0, mu0,
                        n_prob: int):
        """Residual-balancing adaptive-ρ run (Boyd §3.4.1).

        The chunk runner is jitted ONCE per chunk length with the
        factorization as a pytree argument, so β rescales never recompile —
        they only swap which cached factorization is passed in.
        """
        ys, pmask = self._ys, self._pmask
        task_name, svr_c = self.task, self.svr_c

        def make_chunk(n_it: int):
            def _chunk(fac_, ys_, pmask_, knob_, z0_, mu0_, done0_):
                task = self._build_task(task_name, svr_c, ys_, pmask_, knob_)
                state, trace = admm_mod.admm_boxqp(
                    fac_.solve_mat, task, fac_.beta, n_it, tol=ap.tol,
                    z0=z0_, mu0=mu0_, done0=done0_)
                hi = task.hi if task_name == "oneclass" else ()
                return state, trace, task.sign * state.z, hi
            return jax.jit(_chunk)

        last = {}

        def run_chunk(beta, n_it, z, mu, done):
            fac_b = self._fac_for(beta)
            fn = self._chunk_fns.get(n_it)
            if fn is None:
                fn = self._chunk_fns[n_it] = make_chunk(n_it)
            done = jnp.zeros((n_prob,), bool) if done is None else done
            state, trace, z_y, hi = fn(fac_b, ys, pmask, knob, z, mu, done)
            last["z_y"], last["hi"] = z_y, hi
            return state, trace

        state, trace, info = admm_mod.adaptive_rho_outer(
            run_chunk, float(self._fac.beta), ap, z0=z0, mu0=mu0)
        return (state.z, state.mu, last["z_y"], last["hi"],
                trace.iters_run, info)

    # ------------------------------------------------------------------ #
    def train_multilevel(
        self,
        c_value: float,
        coarse_frac: float = 0.125,
        coarse_comp: compression.CompressionParams | None = None,
        coarse_leaf_size: int | None = None,
        seed: int = 0,
    ) -> tuple[EngineModel, dict]:
        """AML-SVM-style multilevel warm start (arXiv 2011.02592).

        Train the same task on a ``coarse_frac`` subsample with a CRUDE
        compression (``CompressionParams.crude`` unless overridden), prolong
        the coarse duals to the full point set by nearest-neighbour
        interpolation (``svm.prolong_duals`` over the padded/permuted host
        points), and let the warm-started early-stopping ADMM finish —
        ``FitReport.iters_run`` then measures the saved iterations against a
        cold ``train``.  The subsample is stratified per class for
        classification so the coarse problem set (OVR columns / OVO pairs)
        matches the fine one exactly.

        Returns (model, info) with the coarse size and both iteration
        records.  Requires ``prepare`` to have run (the fine factorization
        is reused untouched).
        """
        from repro.core.svm import prolong_duals

        assert self._fac is not None, "call prepare() first"
        x, y = self._x_raw, self._y_raw
        n = x.shape[0]
        leaf_c = coarse_leaf_size or min(self.leaf_size, 64)
        n_c = int(max(min(n, 2 * leaf_c), round(n * coarse_frac)))
        rng = np.random.default_rng(seed)
        if self.task == "svm":
            parts = []
            for cls in self._classes:
                rows = np.nonzero(y == cls)[0]
                want = max(1, int(round(len(rows) * n_c / n)))
                parts.append(rng.choice(rows, size=min(want, len(rows)),
                                        replace=False))
            idx = np.sort(np.concatenate(parts))
        else:
            idx = np.sort(rng.choice(n, size=min(n_c, n), replace=False))

        coarse = HSSSVMEngine(
            spec=self.spec,
            comp=coarse_comp or compression.CompressionParams.crude(),
            leaf_size=leaf_c, beta=self.beta, max_it=self.max_it,
            strategy=self.strategy, store_dtype=self.store_dtype,
            task=self.task, svr_c=self.svr_c, tol=self.tol, admm=self.admm,
        )
        y_sub = None if self.task == "oneclass" else y[idx]
        coarse.prepare(x[idx], y_sub)
        _, (z_c, mu_c) = coarse.train(c_value)

        scale = tasks_mod.prolong_scale(
            self.task,
            int(coarse._maskp_host.sum()), int(self._maskp_host.sum()))
        z0 = prolong_duals(coarse._xp_host, np.asarray(jax.device_get(z_c)),
                           self._xp_host) * scale
        mu0 = prolong_duals(coarse._xp_host, np.asarray(jax.device_get(mu_c)),
                            self._xp_host) * scale
        # Fine pads carry no dual mass regardless of what they mapped to.
        z0 = (z0 * self._maskp_host[:, None]).astype(np.float32)
        mu0 = (mu0 * self._maskp_host[:, None]).astype(np.float32)
        if self._mesh is None:
            warm = (jnp.asarray(z0), jnp.asarray(mu0))
        else:
            row_sh = NamedSharding(self._mesh, PartitionSpec(
                tuple(self._mesh.axis_names), None))
            warm = (jax.device_put(z0, row_sh), jax.device_put(mu0, row_sh))

        model, _ = self.train(c_value, warm=warm)
        info = dict(
            coarse_n=int(idx.shape[0]),
            coarse_iters_run=coarse.report.iters_run,
            iters_run=self.report.iters_run,
        )
        return model, info

    # ------------------------------------------------------------------ #
    def train_grid(self, c_values: Sequence[float], warm_start: bool = True
                   ) -> list[EngineModel]:
        """Warm-started knob sweep (C / ε / ν) reusing the one
        compression+factorization."""
        warm = None
        models = []
        for c in c_values:
            model, w = self.train(float(c), warm=warm)
            if warm_start:
                warm = w
            models.append(model)
        return models

    def fit(self, x: np.ndarray, y: np.ndarray | None = None,
            c_value: float = 1.0) -> EngineModel:
        self.prepare(x, y)
        model, _ = self.train(c_value)
        return model
