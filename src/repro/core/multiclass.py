"""Multiclass SVM training on ONE shared HSS factorization (paper Alg. 3 × k).

The shifted kernel K̃ + βI depends only on the data, the bandwidth h, and β —
never on the labels.  A one-vs-rest (or one-vs-one) reduction of a k-class
problem therefore needs exactly ONE HSS compression and ONE ULV-equivalent
factorization, shared by every binary subproblem; only the O(d) label-side
vector work differs per class.  This module exploits that three ways:

  * ``admm_svm_batched`` runs all k per-class ADMM iterations as a single
    (d, k)-block computation — each iteration is ONE multi-RHS telescoping
    solve (``factorization.hss_solve_mat``) instead of k sequential solves,
    and the label-independent w = K_β⁻¹ e is computed once for all classes;
  * the per-class biases come from ONE ``HSSMatrix.matmat`` over the (d, k)
    coefficient block (paper eq. (7), batched);
  * prediction streams each test×support kernel block against all k
    coefficient columns while the block is live (``kernel_matvec_streamed``).

One-vs-one rides on the SAME factorization: pair problem (a, b) keeps the
full padded coordinate set and pins every point outside classes {a, b} to the
box [0, 0] (exactly the mechanism that makes tree padding inert), so its ADMM
fixed point restricted to participating points solves the pair subproblem.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import admm as admm_mod
from repro.core import compression, factorization, tree as tree_mod
from repro.core.hss import HSSMatrix, shrink_report
from repro.core.kernelfn import (
    DEFAULT_SCORE_BLOCK, KernelSpec, kernel_matvec_streamed,
)
from repro.core.svm import (
    FitReport, compute_bias_batched, resolve_rtol, run_grid_search,
)

Array = jax.Array


def ovr_problems(y: np.ndarray, classes: np.ndarray, real_mask: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray | None]:
    """One-vs-rest label matrix (k, d) and participation masks (k, d)."""
    ys = np.where(y[None, :] == classes[:, None], 1.0, -1.0)
    masks = np.broadcast_to(real_mask[None, :], ys.shape)
    return ys.astype(np.float32), masks.astype(np.float32), None


def ovo_problems(y: np.ndarray, classes: np.ndarray, real_mask: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One-vs-one problems: (P, d) labels/masks + (P, 2) class-index pairs.

    Non-participating points keep label -1 but get box [0, 0] via the mask,
    so they are inert in the pair's ADMM fixed point.
    """
    k = classes.shape[0]
    pairs = np.array([(a, b) for a in range(k) for b in range(a + 1, k)],
                     dtype=np.int32).reshape(-1, 2)
    ys, masks = [], []
    for a, b in pairs:
        in_pair = (y == classes[a]) | (y == classes[b])
        ys.append(np.where(y == classes[a], 1.0, -1.0))
        masks.append((real_mask & in_pair).astype(np.float32))
    return (np.stack(ys).astype(np.float32), np.stack(masks).astype(np.float32),
            pairs)


def ovo_vote(scores: Array, pairs: np.ndarray, n_classes: int) -> Array:
    """One-vs-one decision: (n_test, P) pair scores -> (n_test,) class indices.

    Each pair votes for its winner; vote ties break toward the larger summed
    functional margin.  Shared by the multiclass trainer's model and the
    engine's (core.engine.EngineModel) so the tie-break can never drift.
    """
    pairs = jnp.asarray(pairs)
    winner = jnp.where(scores >= 0, pairs[:, 0][None, :],
                       pairs[:, 1][None, :])
    votes = jax.nn.one_hot(winner, n_classes).sum(axis=1)
    margin = jnp.zeros_like(votes)
    margin = margin.at[:, pairs[:, 0]].add(scores)
    margin = margin.at[:, pairs[:, 1]].add(-scores)
    return jnp.argmax(votes + 1e-3 * jnp.tanh(margin), axis=1)


@dataclasses.dataclass
class MulticlassSVMModel:
    """k-class classifier: per-problem support coefficients, permuted order."""

    x_perm: Array          # (d, f) padded+permuted training points
    z_y: Array             # (d, P) per-problem y_i * z_i columns (pads are 0)
    biases: Array          # (P,)
    classes: np.ndarray    # (k,) original class labels
    spec: KernelSpec
    c_value: float
    strategy: str = "ovr"          # "ovr" | "ovo"
    pairs: np.ndarray | None = None  # (P, 2) class indices, ovo only

    @property
    def n_classes(self) -> int:
        return int(self.classes.shape[0])

    def decision_function(self, x_test: Array,
                          block: int = DEFAULT_SCORE_BLOCK) -> Array:
        """(n_test, P) per-problem scores, one streamed pass over the kernel."""
        scores = kernel_matvec_streamed(
            self.spec, x_test, self.x_perm, self.z_y, block=block
        )
        return scores + self.biases[None, :]

    def predict(self, x_test: Array,
                block: int = DEFAULT_SCORE_BLOCK) -> Array:
        scores = self.decision_function(x_test, block=block)
        if self.strategy == "ovr":
            idx = jnp.argmax(scores, axis=1)
        else:
            idx = ovo_vote(scores, self.pairs, self.n_classes)
        return jnp.asarray(self.classes)[idx]


@dataclasses.dataclass
class MulticlassHSSSVMTrainer:
    """compress-once / factor-once / train-ALL-classes-at-once driver."""

    spec: KernelSpec
    comp: compression.CompressionParams = dataclasses.field(
        default_factory=compression.CompressionParams
    )
    leaf_size: int = 128
    beta: float | None = None     # default: the paper's rule by dataset size
    max_it: int = 10
    strategy: str = "ovr"         # "ovr" | "ovo"

    # populated by prepare():
    _hss: HSSMatrix | None = None
    _fac: factorization.HSSFactorization | None = None
    _ys: Array | None = None       # (P, d) per-problem labels
    _pmask: Array | None = None    # (P, d) per-problem participation masks
    _classes: np.ndarray | None = None
    _pairs: np.ndarray | None = None
    _report: FitReport | None = None
    _jit_admm: object = None

    # ------------------------------------------------------------------ #
    def prepare(self, x: np.ndarray, y: np.ndarray) -> FitReport:
        """Pad, build tree, compress ONCE, factorize ONCE for all classes."""
        if self.strategy not in ("ovr", "ovo"):
            raise ValueError(f"unknown strategy {self.strategy!r}")
        x = np.asarray(x, np.float32)
        y = np.asarray(y)
        classes = np.unique(y)
        if classes.shape[0] < 2:
            raise ValueError("need at least 2 classes")
        d_real = x.shape[0]
        x_pad, y_pad, mask, levels = tree_mod.pad_dataset(
            x, y.astype(np.float32), self.leaf_size)
        t = tree_mod.build_tree(x_pad, self.leaf_size, levels)
        xp = jnp.asarray(x_pad[t.perm])
        yp = y_pad[t.perm]
        maskp = mask[t.perm]
        # pad rows inherit pad_dataset's filler label (1.0), which MAY
        # collide with a real class — harmless: the participation mask pins
        # every pad to the [0, 0] box, so its dual weight is exactly 0
        build = ovr_problems if self.strategy == "ovr" else ovo_problems
        ys, pmasks, pairs = build(yp, classes.astype(np.float32), maskp)

        t0 = time.perf_counter()
        hss = compression.compress(xp, t, self.spec, self.comp)
        # Adaptive builds shrink to the observed ranks before factorizing:
        # ALL k class subproblems then share the smaller factors.
        hss, rank_info = shrink_report(hss)
        jax.block_until_ready(hss.d_leaf)
        t1 = time.perf_counter()
        beta = self.beta if self.beta is not None else admm_mod.paper_beta(d_real)
        fac = factorization.factorize(hss, beta)
        jax.block_until_ready(fac.root_lu)
        t2 = time.perf_counter()

        self._hss, self._fac = hss, fac
        self._ys, self._pmask = jnp.asarray(ys), jnp.asarray(pmasks)
        self._classes, self._pairs = classes, pairs
        self._jit_admm = None
        self._report = FitReport(
            compression_s=t1 - t0,
            factorization_s=t2 - t1,
            admm_s=0.0,
            memory_mb=hss.memory_bytes() / 1e6,
            hss_levels=t.levels,
            beta=beta,
            kernel_evals=compression.kernel_eval_count(t, self.comp),
            **rank_info,
        )
        return self._report

    @property
    def n_problems(self) -> int:
        assert self._ys is not None, "call prepare() first"
        return int(self._ys.shape[0])

    # ------------------------------------------------------------------ #
    def train(self, c_value: float, warm: tuple[Array, Array] | None = None
              ) -> tuple[MulticlassSVMModel, tuple[Array, Array]]:
        """ONE batched ADMM run training every class subproblem for fixed C."""
        assert self._fac is not None, "call prepare() first"
        fac, ys, pmask = self._fac, self._ys, self._pmask
        c_upper = c_value * pmask             # (P, d): outsiders pinned to [0,0]

        if self._jit_admm is None:
            max_it = self.max_it

            def _run(fac_, ys_, c_upper_, z0, mu0):
                return admm_mod.admm_svm_batched(
                    fac_.solve_mat, ys_, c_upper_, fac_.beta, max_it,
                    z0=z0, mu0=mu0)

            self._jit_admm = jax.jit(_run)

        zeros = jnp.zeros((ys.shape[1], ys.shape[0]), ys.dtype)
        t0 = time.perf_counter()
        state, _trace = self._jit_admm(
            fac, ys, c_upper,
            zeros if warm is None else warm[0],
            zeros if warm is None else warm[1],
        )
        z = jax.block_until_ready(state.z)            # (d, P)
        t1 = time.perf_counter()
        if self._report is not None:
            self._report.admm_s += t1 - t0

        y_cols = ys.T                                 # (d, P)
        biases = compute_bias_batched(
            self._hss, y_cols, z, c_value * pmask.T, pmask.T)
        model = MulticlassSVMModel(
            x_perm=self._hss.x, z_y=y_cols * z, biases=biases,
            classes=self._classes, spec=self.spec, c_value=c_value,
            strategy=self.strategy, pairs=self._pairs,
        )
        return model, (state.z, state.mu)

    # ------------------------------------------------------------------ #
    def fit(self, x: np.ndarray, y: np.ndarray, c_value: float = 1.0
            ) -> MulticlassSVMModel:
        self.prepare(x, y)
        model, _ = self.train(c_value)
        return model

    @property
    def report(self) -> FitReport:
        assert self._report is not None
        return self._report


def grid_search_multiclass(
    x: np.ndarray,
    y: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    hs: Sequence[float],
    cs: Sequence[float],
    trainer_kwargs: dict | None = None,
    rtol: float | None = None,
) -> tuple[MulticlassSVMModel, dict]:
    """(h, C) grid over the full (C × class) product (paper §3.3, batched).

    Per h: ONE compression + ONE factorization serve the whole C sweep of
    ALL k class subproblems; consecutive C values warm-start every class
    column from the previous (d, P) iterates at once.  ``rtol`` switches
    each h's build to the adaptive tolerance-driven compression (crude ≈
    1e-2, accurate ≈ 1e-4 — see ``svm.resolve_rtol``).
    """
    kw = resolve_rtol(trainer_kwargs, rtol)
    return run_grid_search(
        lambda h: MulticlassHSSSVMTrainer(spec=KernelSpec(h=h), **kw),
        x, y, x_val, y_val, hs, cs)
