"""ULV-equivalent direct factorization of the shifted HSS matrix.

The paper factorizes K̃_β = K̃ + βI once per (h, β) with STRUMPACK's ULV
(Chandrasekaran–Gu–Pals) and then solves one system per ADMM iteration and
reuses the factorization across the whole C grid.  ULV's node-sequential
orthogonal eliminations are hostile to the MXU/jit, so we compute the
mathematically equivalent telescoping inversion (Gillman–Martinsson HBS
solver), which has the identical compute pattern — O(N r^2) factor once,
O(N r) per solve — but runs as *batched dense ops per tree level*:

  A(ℓ) = D(ℓ) + U(ℓ) A(ℓ−1) U(ℓ)ᵀ          (telescoping form)
  A(ℓ)⁻¹ = G(ℓ) + E(ℓ) (A(ℓ−1) + D̂(ℓ))⁻¹ E(ℓ)ᵀ      with
  D̂ = (Uᵀ D⁻¹ U)⁻¹,   E = D⁻¹ U D̂,   G = D⁻¹ − D⁻¹ U D̂ Uᵀ D⁻¹

(the identity is verified in tests/test_factorization.py against dense
inversion).  At each level the reduced diagonal blocks are assembled from
the children D̂ and the sibling couplings B; the root system is solved dense.

Leaf diagonal blocks of K̃+βI are SPD (Gaussian kernel + positive shift), so
leaves use Cholesky; reduced levels use LU for robustness (the compression
error can perturb definiteness of the small reduced blocks).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro.core.hss import HSSMatrix

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HSSFactorization:
    """Factor-once / solve-many artifact for K̃ + beta I."""

    e_leaf: Array               # (n_leaf, m, r0)
    g_leaf: Array               # (n_leaf, m, m)
    e_lvls: tuple[Array, ...]   # per k=1..K-1: (n_k, 2 r_{k-1}, r_k)
    g_lvls: tuple[Array, ...]   # per k=1..K-1: (n_k, 2 r_{k-1}, 2 r_{k-1})
    root_lu: Array              # (2 r_{K-1}, 2 r_{K-1})
    root_piv: Array
    levels: int = dataclasses.field(metadata=dict(static=True))
    leaf_size: int = dataclasses.field(metadata=dict(static=True))
    beta: float = dataclasses.field(metadata=dict(static=True))

    def solve(self, b: Array) -> Array:
        return hss_solve(self, b)

    def solve_mat(self, b: Array) -> Array:
        """Solve for multiple RHS, b of shape (N, c) — one native block sweep."""
        return hss_solve_mat(self, b)


def _leaf_factors(d_shift: Array, u: Array, mask: Array | None = None
                  ) -> tuple[Array, Array, Array]:
    """Batched leaf EGD̂ from Cholesky of the shifted diagonal blocks.

    ``mask`` (n_leaf, r) is the adaptive build's per-node skeleton liveness
    (``HSSMatrix.rank_masks``): dead columns of U are exact zeros, so
    Ŝ = Uᵀ D⁻¹ U is structurally singular — adding 1 on each dead diagonal
    slot makes it [[Ŝ_live, 0], [0, I]] (the zero cross blocks are exact),
    whose inverse keeps the live block's exact D̂ and decouples dead slots
    as inert unit equations: E's dead columns stay exactly 0 and every live
    value matches the factorization of the sliced-down representation.
    """

    def one(d_i: Array, u_i: Array, mask_i: Array | None = None):
        m = d_i.shape[0]
        chol = jsl.cholesky(d_i, lower=True)
        dinv_u = jsl.cho_solve((chol, True), u_i)             # (m, r)
        s_hat = u_i.T @ dinv_u                                # (r, r)
        if mask_i is not None:
            s_hat = s_hat + jnp.diag(1.0 - mask_i)
        d_hat = jnp.linalg.inv(s_hat)
        e_i = dinv_u @ d_hat                                  # (m, r)
        dinv = jsl.cho_solve((chol, True), jnp.eye(m, dtype=d_i.dtype))
        g_i = dinv - e_i @ dinv_u.T
        return e_i, g_i, d_hat

    if mask is None:
        return jax.vmap(one)(d_shift, u)
    return jax.vmap(one)(d_shift, u, mask)


def _level_factors(d_blk: Array, u: Array, mask: Array | None = None
                   ) -> tuple[Array, Array, Array]:
    """Batched reduced-level EGD̂ via LU of the (2r x 2r) assembled blocks.

    ``mask`` (n_k, r_k) regularizes dead PARENT skeleton slots exactly as in
    ``_leaf_factors`` (the transfer's dead columns are exact zeros).
    """

    def one(d_i: Array, u_i: Array, mask_i: Array | None = None):
        c = d_i.shape[0]
        lu, piv = jsl.lu_factor(d_i)
        dinv_u = jsl.lu_solve((lu, piv), u_i)
        s_hat = u_i.T @ dinv_u
        if mask_i is not None:
            s_hat = s_hat + jnp.diag(1.0 - mask_i)
        d_hat = jnp.linalg.inv(s_hat)
        e_i = dinv_u @ d_hat
        dinv = jsl.lu_solve((lu, piv), jnp.eye(c, dtype=d_i.dtype))
        g_i = dinv - e_i @ dinv_u.T
        return e_i, g_i, d_hat

    if mask is None:
        return jax.vmap(one)(d_blk, u)
    return jax.vmap(one)(d_blk, u, mask)


def _assemble_next(d_hat: Array, b: Array) -> Array:
    """Pair children D̂ with their sibling coupling into parent blocks.

    d_hat (n_{k-1}, r, r), b (n_k, r, r)  ->  (n_k, 2r, 2r) blocks
    [[D̂_c1, B], [Bᵀ, D̂_c2]].
    """
    n_k, r = b.shape[0], b.shape[1]
    pair = d_hat.reshape(n_k, 2, r, r)
    top = jnp.concatenate([pair[:, 0], b], axis=-1)
    bot = jnp.concatenate([jnp.swapaxes(b, -1, -2), pair[:, 1]], axis=-1)
    return jnp.concatenate([top, bot], axis=-2)


def factorize(hss: HSSMatrix, beta: float,
              store_dtype: str | None = None) -> HSSFactorization:
    """Factor K̃ + beta*I.  Reused for every ADMM iteration and C value.

    ``store_dtype="bfloat16"`` stores the E/G factors in bf16 (the solve
    accumulates in f32) — halves the solve's HBM traffic, the dominant
    roofline term of the distributed ADMM step (§Perf change D1).  The
    root LU stays f32.
    """
    K, m = hss.levels, hss.leaf_size
    dtype = hss.d_leaf.dtype
    eye = jnp.eye(m, dtype=dtype)
    d_shift = hss.d_leaf + beta * eye

    if K == 0:
        # Degenerate single-block problem: dense Cholesky path.
        chol = jsl.cholesky(d_shift[0], lower=True)
        return HSSFactorization(
            e_leaf=jnp.zeros((1, m, 0), dtype),
            g_leaf=jnp.zeros((1, m, m), dtype),
            e_lvls=(), g_lvls=(),
            root_lu=chol, root_piv=jnp.arange(m, dtype=jnp.int32),
            levels=0, leaf_size=m, beta=beta,
        )

    masks = hss.rank_masks()
    e_leaf, g_leaf, d_hat = _leaf_factors(
        d_shift, hss.u_leaf, None if masks is None else masks[0])
    e_lvls: list[Array] = []
    g_lvls: list[Array] = []
    for k in range(1, K):
        d_blk = _assemble_next(d_hat, hss.b_mats[k - 1])
        e_k, g_k, d_hat = _level_factors(
            d_blk, hss.transfers[k - 1],
            None if masks is None else masks[1][k - 1])
        e_lvls.append(e_k)
        g_lvls.append(g_k)
    root = _assemble_next(d_hat, hss.b_mats[K - 1])[0]
    lu, piv = jsl.lu_factor(root)
    if store_dtype is not None:
        sd = jnp.dtype(store_dtype)
        e_leaf, g_leaf = e_leaf.astype(sd), g_leaf.astype(sd)
        e_lvls = [a.astype(sd) for a in e_lvls]
        g_lvls = [a.astype(sd) for a in g_lvls]
    return HSSFactorization(
        e_leaf=e_leaf, g_leaf=g_leaf,
        e_lvls=tuple(e_lvls), g_lvls=tuple(g_lvls),
        root_lu=lu, root_piv=piv,
        levels=K, leaf_size=m, beta=beta,
    )


def factorize_sharded(hss: HSSMatrix, beta: float, mesh,
                      store_dtype: str | None = None) -> HSSFactorization:
    """Mesh-parallel ``factorize``: E/G emitted already placed per level.

    The level loop is numerically identical to ``factorize`` but runs as ONE
    jitted program whose per-level arrays are pinned (via sharding
    constraints) to the ``distributed.fac_shardings`` layout: leaf and
    lower-level factors stay device-local along the node axis (zero
    communication — every EGD̂ block is an independent small dense solve),
    and ``_assemble_next``'s child-pairing reshape at the first level whose
    node count stops dividing the device count lowers to the one all-gather
    of the (tiny, O(r² n_k)) reduced blocks — the same collective schedule as
    ``hss_solve_mat``.  The result needs NO build-then-``device_put``
    round-trip: ``_run_c_grid`` detects the placement and skips it.

    Works on an ``hss`` whose arrays are themselves sharded
    (``compression.compress_sharded``) or local; parity with ``factorize``
    is tested to <=1e-5 in tests/test_engine.py.
    """
    from jax.sharding import NamedSharding

    from repro.dist.api import node_partition_spec

    K, m = hss.levels, hss.leaf_size
    if K == 0:
        return factorize(hss, beta, store_dtype=store_dtype)

    def pin(a):
        # The one shared placement rule (dist.api.node_partition_spec):
        # node-stacked arrays shard along the node axis when it divides the
        # device count; everything else (root LU, pivots) replicates.
        return jax.lax.with_sharding_constraint(
            a, NamedSharding(mesh, node_partition_spec(mesh, a.ndim,
                                                       a.shape[0])))

    sd = None if store_dtype is None else jnp.dtype(store_dtype)

    @jax.jit
    def _build(d_leaf, u_leaf, transfers, b_mats, leaf_ranks, level_ranks):
        dtype = d_leaf.dtype

        def mask(ranks, cap):
            # Adaptive skeleton-liveness masks (the shared hss.rank_mask
            # rule), built in-graph from the rank vectors so the whole
            # factorization stays ONE jitted program.
            if ranks is None:
                return None
            from repro.core.hss import rank_mask

            return rank_mask(ranks, cap, dtype)

        d_shift = pin(d_leaf) + beta * jnp.eye(m, dtype=dtype)
        e_leaf, g_leaf, d_hat = _leaf_factors(
            d_shift, pin(u_leaf), mask(leaf_ranks, u_leaf.shape[-1]))
        e_leaf, g_leaf, d_hat = pin(e_leaf), pin(g_leaf), pin(d_hat)
        e_lvls, g_lvls = [], []
        for k in range(1, K):
            d_blk = pin(_assemble_next(d_hat, pin(b_mats[k - 1])))
            e_k, g_k, d_hat = _level_factors(
                d_blk, pin(transfers[k - 1]),
                mask(None if leaf_ranks is None else level_ranks[k - 1],
                     transfers[k - 1].shape[-1]))
            e_k, g_k, d_hat = pin(e_k), pin(g_k), pin(d_hat)
            e_lvls.append(e_k)
            g_lvls.append(g_k)
        root = _assemble_next(d_hat, b_mats[K - 1])[0]
        lu, piv = jsl.lu_factor(root)
        lu, piv = pin(lu), pin(piv)
        if sd is not None:
            e_leaf, g_leaf = e_leaf.astype(sd), g_leaf.astype(sd)
            e_lvls = [pin(a.astype(sd)) for a in e_lvls]
            g_lvls = [pin(a.astype(sd)) for a in g_lvls]
        return (pin(e_leaf), pin(g_leaf), tuple(e_lvls), tuple(g_lvls),
                lu, piv)

    e_leaf, g_leaf, e_lvls, g_lvls, lu, piv = _build(
        hss.d_leaf, hss.u_leaf, hss.transfers, hss.b_mats,
        hss.leaf_ranks, hss.level_ranks)
    return HSSFactorization(
        e_leaf=e_leaf, g_leaf=g_leaf,
        e_lvls=e_lvls, g_lvls=g_lvls,
        root_lu=lu, root_piv=piv,
        levels=K, leaf_size=m, beta=beta,
    )


def hss_solve(fac: HSSFactorization, b: Array) -> Array:
    """x = (K̃ + beta I)^{-1} b in O(N r): single-RHS view of the block sweep."""
    return hss_solve_mat(fac, b[:, None])[:, 0]


def hss_solve_mat(fac: HSSFactorization, b: Array) -> Array:
    """X = (K̃ + beta I)^{-1} B for B (N, c): one upward + one downward sweep.

    The RHS block is carried as a trailing axis through every level einsum,
    so all c columns (ADMM iterates of c classes, or a warm-started C grid)
    share a single pass over the E/G factors — the multiclass analogue of
    the paper's factor-once/solve-many economy.

    Every per-level contraction pins ``preferred_element_type=float32``:
    with ``store_dtype="bfloat16"`` the E/G factors are bf16 and implicit
    promotion alone would leave the accumulator dtype to the backend's
    discretion — the f32 accumulation is what makes the bf16 storage mode
    a pure bandwidth win instead of an accuracy cliff (regression-tested in
    tests/test_factorization.py).
    """
    from repro.dist.api import constrain_nodes

    K, m = fac.levels, fac.leaf_size
    c = b.shape[1]
    if K == 0:
        return jsl.cho_solve((fac.root_lu, True), b)

    f32 = jnp.float32
    n_leaf = fac.e_leaf.shape[0]
    b0 = b.reshape(n_leaf, m, c)
    # Upward sweep: project the RHS through Eᵀ level by level.  Under an
    # active mesh every per-level block is pinned to the fac_shardings
    # layout (constrain_nodes) so the pair/unpair reshapes lower to the
    # designed per-level collective schedule.
    bs = [b0]
    bt = constrain_nodes(
        jnp.einsum("nmr,nmc->nrc", fac.e_leaf, b0, preferred_element_type=f32))
    for k in range(1, K):
        n_k = fac.e_lvls[k - 1].shape[0]
        b_k = bt.reshape(n_k, -1, c)                        # (n_k, 2 r_{k-1}, c)
        bs.append(b_k)
        bt = constrain_nodes(
            jnp.einsum("nsr,nsc->nrc", fac.e_lvls[k - 1], b_k,
                       preferred_element_type=f32))
    b_root = bt.reshape(-1, c)
    # root stays f32 regardless of the factor storage dtype
    x_root = jsl.lu_solve(
        (fac.root_lu, fac.root_piv), b_root.astype(fac.root_lu.dtype)
    ).astype(bt.dtype)

    # Downward sweep: x_k = G_k b_k + E_k xi_k.
    xi = x_root.reshape(2, -1, c)                           # level K-1 nodes
    for k in range(K - 1, 0, -1):
        b_k = bs[k]
        x_k = (
            jnp.einsum("nsd,ndc->nsc", fac.g_lvls[k - 1], b_k,
                       preferred_element_type=f32)
            + jnp.einsum("nsr,nrc->nsc", fac.e_lvls[k - 1], xi,
                         preferred_element_type=f32)
        )
        xi = constrain_nodes(
            x_k.reshape(-1, x_k.shape[1] // 2, c))          # children skeleton
    x0 = (
        jnp.einsum("nab,nbc->nac", fac.g_leaf, b0, preferred_element_type=f32)
        + jnp.einsum("nmr,nrc->nmc", fac.e_leaf, xi, preferred_element_type=f32)
    )
    return x0.reshape(-1, c)
