"""The paper's contribution: HSS kernel approximation + ADMM SVM training."""

from repro.core.admm import (
    ADMMState, BoxQPTask, admm_boxqp, admm_svm, admm_svm_batched, paper_beta,
    svm_task,
)
from repro.core.compression import (
    CompressionParams, compress, compress_sharded, compression_error,
    kernel_eval_count,
)
from repro.core.engine import EngineModel, HSSSVMEngine
from repro.core.factorization import (
    HSSFactorization, factorize, factorize_sharded, hss_solve, hss_solve_mat,
)
from repro.core.hss import HSSMatrix, shrink_to_fit
from repro.core.kernelfn import KernelSpec, kernel_block
from repro.core.krr import grid_search_gp, grid_search_krr, krr_solve
# NOTE: the raw ``lanczos`` sweep is deliberately NOT re-exported — binding
# that name here would shadow the ``repro.core.lanczos`` submodule attribute.
from repro.core.lanczos import spectral_embed, top_eigenpairs
from repro.core.multiclass import (
    MulticlassHSSSVMTrainer, MulticlassSVMModel, grid_search_multiclass,
)
from repro.core.svm import HSSSVMTrainer, SVMModel, grid_search
from repro.core.tasks import (
    grid_search_oneclass, grid_search_svr, one_class_task, svr_task,
)
from repro.core.tree import ClusterTree, build_tree, pad_dataset

__all__ = [
    "ADMMState", "BoxQPTask", "admm_boxqp", "admm_svm", "admm_svm_batched",
    "paper_beta", "svm_task",
    "grid_search_oneclass", "grid_search_svr", "one_class_task", "svr_task",
    "CompressionParams", "compress", "compress_sharded", "compression_error",
    "kernel_eval_count",
    "EngineModel", "HSSSVMEngine",
    "HSSFactorization", "factorize", "factorize_sharded",
    "hss_solve", "hss_solve_mat",
    "HSSMatrix", "shrink_to_fit", "KernelSpec", "kernel_block",
    "grid_search_gp", "grid_search_krr", "krr_solve",
    "spectral_embed", "top_eigenpairs",
    "HSSSVMTrainer", "SVMModel", "grid_search",
    "MulticlassHSSSVMTrainer", "MulticlassSVMModel", "grid_search_multiclass",
    "ClusterTree", "build_tree", "pad_dataset",
]
