"""The paper's contribution: HSS kernel approximation + ADMM SVM training."""

from repro.core.admm import (
    ADMMState, BoxQPTask, admm_boxqp, admm_svm, admm_svm_batched, paper_beta,
    svm_task,
)
from repro.core.compression import (
    CompressionParams, compress, compress_sharded, compression_error,
    kernel_eval_count,
)
from repro.core.engine import EngineModel, HSSSVMEngine
from repro.core.factorization import (
    HSSFactorization, factorize, factorize_sharded, hss_solve, hss_solve_mat,
)
from repro.core.hss import HSSMatrix, shrink_to_fit
from repro.core.kernelfn import KernelSpec, kernel_block
from repro.core.multiclass import (
    MulticlassHSSSVMTrainer, MulticlassSVMModel, grid_search_multiclass,
)
from repro.core.svm import HSSSVMTrainer, SVMModel, grid_search
from repro.core.tasks import (
    grid_search_oneclass, grid_search_svr, one_class_task, svr_task,
)
from repro.core.tree import ClusterTree, build_tree, pad_dataset

__all__ = [
    "ADMMState", "BoxQPTask", "admm_boxqp", "admm_svm", "admm_svm_batched",
    "paper_beta", "svm_task",
    "grid_search_oneclass", "grid_search_svr", "one_class_task", "svr_task",
    "CompressionParams", "compress", "compress_sharded", "compression_error",
    "kernel_eval_count",
    "EngineModel", "HSSSVMEngine",
    "HSSFactorization", "factorize", "factorize_sharded",
    "hss_solve", "hss_solve_mat",
    "HSSMatrix", "shrink_to_fit", "KernelSpec", "kernel_block",
    "HSSSVMTrainer", "SVMModel", "grid_search",
    "MulticlassHSSSVMTrainer", "MulticlassSVMModel", "grid_search_multiclass",
    "ClusterTree", "build_tree", "pad_dataset",
]
