"""KRR and GP posterior mean as ONE multi-RHS solve on the HSS factorization.

The kernel linear-algebra members of the task family: where the box-QP tasks
(repro.core.tasks) iterate ADMM against K_β⁻¹, kernel ridge regression and
the GP posterior mean ARE the solve —

  KRR:   α = (K̃ + λI)⁻¹ y,     f(x) = Σ αᵢ K(xᵢ, x)
  GP:    identical mean (λ = observation noise σ²); model selection adds the
         log marginal likelihood
           log p(y) = −½ yᵀα − ½ log det(K̃ + λI) − (n/2) log 2π
         whose logdet is estimated by Hutchinson probes with Lanczos (Gauss)
         quadrature on the O(N r) matvec — cheap enough to run inside an
         (h, λ) grid scan.

λ rides the factorization's existing β shift slot, so a λ sweep on one
compression is a sequence of O(N r²) refactorizations cached per visited λ
(``HSSSVMEngine._fac_for``), and the trained model scores through the same
``kernel_matvec_streamed`` path as every other task: zero new serving
machinery.  Padded datasets decouple exactly — pad rows of y are zero and
the pad block of K̃ + λI is ≈ (1 + λ)I, so the real-point restriction of the
padded solve is the unpadded solution (the mask still zeroes the pad
coefficients defensively against factorization float noise).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lanczos import lanczos, tridiag_eigh

Array = jax.Array


def krr_solve(fac, targets: Array) -> Array:
    """α = (K̃ + λI)⁻¹ Y for target columns Y (d, P); λ is ``fac.beta``.

    The whole train step of ``task="krr"`` / ``task="gp"``: one telescoping
    multi-RHS solve, ZERO ADMM iterations.  jit-compatible with ``fac`` as a
    pytree argument (β is a static field, so each distinct λ traces once —
    the refactorization it rides along with dominates anyway).
    """
    return fac.solve_mat(targets)


def gp_log_marginal(hss, fac, y: Array, mask: Array | None = None,
                    n_probes: int = 4, num_iters: int = 20, seed: int = 0
                    ) -> float:
    """Hutchinson + Lanczos-quadrature estimate of the GP log marginal.

    The data-fit term −½ yᵀ(K̃ + λI)⁻¹y is exact (one solve on the
    factorization); log det(K̃ + λI) = tr log(K̃ + λI) is estimated with
    ``n_probes`` Rademacher probes, each integrated by an ``num_iters``-point
    Gauss quadrature from the Lanczos tridiagonal of the shifted matvec —
    O(n_probes · num_iters · N r) total, no dense matrix ever formed.

    ``mask`` (1 real / 0 pad) removes the pad block's exact contribution
    n_pad · log(1 + λ) and counts only real points in the 2π term, so the
    estimate ranks (h, λ) on the data, not on the padding.  Deterministic
    for a fixed seed — grid scans compare like against like.
    """
    f32 = jnp.float32
    y = jnp.asarray(y, f32).reshape(-1)
    n = y.shape[0]
    lam = float(fac.beta)
    alpha = fac.solve_mat(y[:, None])[:, 0]
    fit = -0.5 * float(jnp.einsum("n,n->", y, alpha,
                                  preferred_element_type=f32))

    def matvec(v):
        return hss.matvec(v) + lam * v

    keys = jax.random.split(jax.random.PRNGKey(seed), n_probes)
    logdet = 0.0
    for key in keys:
        z = jax.random.rademacher(key, (n,), f32)
        alphas, betas, _ = lanczos(matvec, z, num_iters)
        theta, u = tridiag_eigh(alphas, betas[:-1])
        w = u[0, :] ** 2                     # Gauss weights: (e₁ᵀuᵢ)²
        quad = jnp.einsum("m,m->", w, jnp.log(jnp.maximum(theta, 1e-12)),
                          preferred_element_type=f32)
        logdet += float(n) * float(quad)     # ‖z‖² = n for Rademacher probes
    logdet /= n_probes

    n_eff = n
    if mask is not None:
        n_real = int(np.asarray(jax.device_get(mask)).sum())
        logdet -= (n - n_real) * math.log1p(lam)
        n_eff = n_real
    return fit - 0.5 * logdet - 0.5 * n_eff * math.log(2.0 * math.pi)


# --------------------------------------------------------------------- #
# validation metric + grid drivers (λ sweeps in place of C)             #
# --------------------------------------------------------------------- #
def krr_score(model, x_val: Array, y_val: Array) -> float:
    """Negated RMSE (higher is better, run_grid_search maximizes)."""
    pred = model.predict(x_val)
    return -float(jnp.sqrt(jnp.mean((pred - jnp.asarray(y_val)) ** 2)))


def grid_search_krr(
    x: np.ndarray,
    y: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    hs: Sequence[float],
    lams: Sequence[float],
    trainer_kwargs: dict | None = None,
    rtol: float | None = None,
) -> tuple[object, dict]:
    """(h, λ) grid for KRR — λ sweeps in place of C.

    Per h: ONE compression serves the whole λ sweep; each λ refactorizes the
    shared representation (cached per visited λ) and solves once.  Scores
    are negated validation RMSE.
    """
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.core.svm import resolve_rtol, run_grid_search

    kw = resolve_rtol(trainer_kwargs, rtol)
    return run_grid_search(
        lambda h: HSSSVMEngine(spec=KernelSpec(h=h), task="krr", **kw),
        x, y, x_val, y_val, hs, lams, score_fn=krr_score)


def grid_search_gp(
    x: np.ndarray,
    y: np.ndarray,
    hs: Sequence[float],
    lams: Sequence[float],
    trainer_kwargs: dict | None = None,
    rtol: float | None = None,
    n_probes: int = 4,
    num_iters: int = 20,
    seed: int = 0,
) -> tuple[object, dict]:
    """(h, λ) grid for GP regression scored by the TRAINING log marginal.

    No validation split: GP model selection maximizes log p(y | h, λ) on the
    training data itself (the marginal already charges for complexity).
    Returns (best posterior-mean model, dict with per-(h, λ) scores and the
    winning pair) in the same shape as the other grid drivers.
    """
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.core.svm import resolve_rtol

    kw = resolve_rtol(trainer_kwargs, rtol)
    results: dict = {}
    best_model, best_key, best_score = None, None, -math.inf
    for h in hs:
        engine = HSSSVMEngine(spec=KernelSpec(h=float(h)), task="gp", **kw)
        engine.prepare(x, y)
        for lam in lams:
            model, _ = engine.train(float(lam))
            score = engine.log_marginal(float(lam), n_probes=n_probes,
                                        num_iters=num_iters, seed=seed)
            results[(float(h), float(lam))] = dict(log_marginal=score)
            if score > best_score:
                best_model, best_key, best_score = model, (h, lam), score
    return best_model, dict(results=results, best_h=float(best_key[0]),
                            best_lam=float(best_key[1]),
                            best_log_marginal=best_score)
