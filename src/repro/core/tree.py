"""Cluster tree for HSS compression.

The paper relies on STRUMPACK's geometry-aware preprocessing (recursive
clustering + approximate-nearest-neighbour sampling).  TPU adaptation
(DESIGN.md §3.2): a *perfect* binary tree built by recursive
widest-dimension median bisection so that every leaf holds exactly
``leaf_size`` points — all downstream HSS arrays then have static shapes and
every per-level operation is a batched (vmapped) dense op.

The tree is built once per dataset on the host (numpy); everything after is
JAX.  Datasets whose size is not ``leaf_size * 2**levels`` are padded with
*inert* far-away points (see ``pad_dataset``): their kernel rows are ~0, the
SVM box constraint pins their dual variables to 0, so the padded problem's
solution restricted to real points equals the original one (core/svm.py).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterTree:
    """A perfect binary partition of ``n`` points.

    perm[i]   — original index of the i-th point in tree (leaf-major) order.
    levels    — number of binary splits; n_leaves == 2**levels.
    leaf_size — points per leaf; n == leaf_size * n_leaves.
    """

    perm: np.ndarray
    levels: int
    leaf_size: int

    @property
    def n(self) -> int:
        return self.perm.shape[0]

    @property
    def n_leaves(self) -> int:
        return 2 ** self.levels

    def inverse_perm(self) -> np.ndarray:
        inv = np.empty_like(self.perm)
        inv[self.perm] = np.arange(self.n)
        return inv


def _split_once(x: np.ndarray, idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split ``idx`` into two equal halves along the widest coordinate."""
    pts = x[idx]
    widths = pts.max(axis=0) - pts.min(axis=0)
    dim = int(np.argmax(widths))
    order = np.argsort(pts[:, dim], kind="stable")
    half = idx.shape[0] // 2
    return idx[order[:half]], idx[order[half:]]


def build_tree(x: np.ndarray, leaf_size: int = 256, levels: int | None = None) -> ClusterTree:
    """Recursive median-bisection tree. ``len(x)`` must be leaf_size * 2**levels."""
    n = x.shape[0]
    if levels is None:
        levels = max(int(round(math.log2(n / leaf_size))), 0)
    if n != leaf_size * 2 ** levels:
        raise ValueError(
            f"n={n} != leaf_size*2**levels={leaf_size * 2 ** levels}; pad first "
            "(see pad_dataset)"
        )
    groups = [np.arange(n)]
    for _ in range(levels):
        nxt = []
        for g in groups:
            a, b = _split_once(x, g)
            nxt.extend((a, b))
        groups = nxt
    perm = np.concatenate(groups) if groups else np.arange(n)
    return ClusterTree(perm=perm, levels=levels, leaf_size=leaf_size)


def padded_size(n: int, leaf_size: int) -> tuple[int, int]:
    """Smallest (n_padded, levels) with n_padded = leaf_size*2**levels >= n."""
    levels = max(math.ceil(math.log2(max(n, 1) / leaf_size)), 0)
    while leaf_size * 2 ** levels < n:
        levels += 1
    return leaf_size * 2 ** levels, levels


def pad_dataset(
    x: np.ndarray, y: np.ndarray, leaf_size: int, min_levels: int = 0
) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad (x, y) with mutually-far inert points to a perfect-tree size.

    Pads are placed along the first feature axis with spacing ~1e3x the data
    diameter, so every Gaussian kernel value involving a pad (including
    pad-pad for distinct pads) underflows to ~0 and the padded kernel matrix
    is blockdiag(K_real, ~I).  Returns (x_pad, y_pad, real_mask, levels).

    ``min_levels`` forces at least that many splits — the mesh-parallel
    build (core.engine) uses it to guarantee the leaf count divides the
    device count, at the cost of a few more inert leaves.
    """
    n = x.shape[0]
    n_pad_total, levels = padded_size(n, leaf_size)
    if min_levels > levels:
        levels = min_levels
        n_pad_total = leaf_size * 2 ** levels
    n_extra = n_pad_total - n
    if n_extra == 0:
        return x, y, np.ones(n, dtype=bool), levels
    lo, hi = x.min(axis=0), x.max(axis=0)
    diam = float(np.linalg.norm(hi - lo)) or 1.0
    pads = np.tile(hi[None, :], (n_extra, 1))
    pads[:, 0] = hi[0] + diam * 1e3 * (1.0 + np.arange(n_extra))
    x_out = np.concatenate([x, pads.astype(x.dtype)], axis=0)
    y_out = np.concatenate([y, np.ones(n_extra, dtype=y.dtype)], axis=0)
    mask = np.concatenate([np.ones(n, dtype=bool), np.zeros(n_extra, dtype=bool)])
    return x_out, y_out, mask, levels


def leaf_slices(tree: ClusterTree) -> list[slice]:
    m = tree.leaf_size
    return [slice(i * m, (i + 1) * m) for i in range(tree.n_leaves)]


def node_span(tree: ClusterTree, level_from_leaf: int, node: int) -> slice:
    """Half-open slice of permuted indices covered by ``node`` at a level.

    level_from_leaf = 0 — leaves; == tree.levels — the root.
    """
    width = tree.leaf_size * 2 ** level_from_leaf
    return slice(node * width, (node + 1) * width)
