"""Distributed HSS-ADMM SVM training: shardings, C-grid drivers, mesh cell.

Sample dimension d is sharded across ALL mesh devices (node-major): the
leaf-level factorization arrays (E, G — O(N r) and O(N m)) live device-local;
reduced-level arrays shard along the node axis until n_k stops dividing the
device count, where they auto-degrade to replicated (they are O(r^2 * n_k) —
tiny).  The ADMM vector iterates are fully data-parallel; the only
cross-device traffic is

  * the level-transition pairings in the solve (collective-permute /
    all-gather of skeleton vectors, O(r * n_k) per level), and
  * the scalar reductions (w2, norms) — psums.

exactly matching the communication pattern of distributed-memory HSS solvers
(STRUMPACK's design, adapted to SPMD/pjit).

Since the mesh-parallel build landed (``compression.compress_sharded`` /
``factorization.factorize_sharded`` / ``core.engine.HSSSVMEngine``) the
factorization arrives here already placed per ``fac_shardings`` — the C-grid
drivers detect that and skip the legacy build-then-``device_put`` round-trip,
so no stage of prepare→train ever materializes an unsharded O(N·m) array.
``build_svm_cell`` exposes the same ADMM step both ways: as a
ShapeDtypeStruct dry-run cell (launch/dryrun.py) and, given ``data=(x, y)``,
as a real executable cell over a live sharded factorization.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.admm import admm_svm, admm_svm_batched
from repro.core.factorization import HSSFactorization, hss_solve


def factorization_shapes(n: int, leaf: int, rank: int, dtype=jnp.float32
                         ) -> HSSFactorization:
    """ShapeDtypeStruct skeleton of a factorization for an n-point problem.

    ``dtype`` sets the E/G factor storage (bf16 = §Perf change D1); the
    root LU stays f32.
    """
    levels = int(math.log2(n // leaf))
    n_leaf = n // leaf

    def sds(*shape):
        return jax.ShapeDtypeStruct(shape, dtype)

    e_lvls, g_lvls = [], []
    for k in range(1, levels):
        n_k = n_leaf // 2 ** k
        e_lvls.append(sds(n_k, 2 * rank, rank))
        g_lvls.append(sds(n_k, 2 * rank, 2 * rank))
    return HSSFactorization(
        e_leaf=sds(n_leaf, leaf, rank),
        g_leaf=sds(n_leaf, leaf, leaf),
        e_lvls=tuple(e_lvls),
        g_lvls=tuple(g_lvls),
        root_lu=jax.ShapeDtypeStruct((2 * rank, 2 * rank), jnp.float32),
        root_piv=jax.ShapeDtypeStruct((2 * rank,), jnp.int32),
        levels=levels, leaf_size=leaf, beta=1e4,
    )


def _node_axis(mesh: Mesh):
    """All mesh axes combined — the node/sample axis uses every device."""
    return tuple(mesh.axis_names)


def fac_shardings(fac_shapes: HSSFactorization, mesh: Mesh) -> Any:
    """Node-axis sharding with replication fallback for small upper levels.

    Only the node-stacked (n_k, ·, ·) factor arrays shard; a level whose
    node count does not divide the device count degrades to replicated (it
    is O(r² n_k) — tiny).  The dense root LU/pivots are replicated outright:
    every device needs them whole for the root solve.
    """
    from repro.dist.api import node_partition_spec

    def shard_nodes(leaf):
        return NamedSharding(
            mesh, node_partition_spec(mesh, leaf.ndim, leaf.shape[0]))

    return jax.tree.map(shard_nodes, fac_shapes)


def vec_sharding(mesh: Mesh) -> NamedSharding:
    """(n,) ADMM iterate vectors: the sample axis over all mesh devices."""
    return NamedSharding(mesh, PartitionSpec(_node_axis(mesh)))


def make_distributed_admm_step(beta: float, max_it: int = 10,
                               solve_dtype=None):
    """The lowered unit: full ADMM training for one C (paper Alg. 3 7-14).

    Includes the w = K_beta^{-1} e precomputation and MaxIt closed-form
    iterations; the HSS solve inside is the level-batched telescoping solve,
    whose reshapes across the node axis generate the collective schedule.
    """

    def step(fac: HSSFactorization, y: jax.Array, c_value: jax.Array):
        if solve_dtype is not None:
            solver = lambda b: hss_solve(
                fac, b.astype(solve_dtype)).astype(b.dtype)
        else:
            solver = lambda b: hss_solve(fac, b)
        state, trace = admm_svm(solver, y, c_value, beta, max_it)
        return state.z, trace.primal_res

    return step


def admm_train_distributed(
    fac: HSSFactorization,
    y: jax.Array,
    c_values,
    mesh: Mesh,
    max_it: int = 10,
    warm_start: bool = True,
) -> list:
    """Run the ADMM C-grid data-parallel under ``mesh`` (paper Alg. 3 7-14).

    The factorization shards over the node axis (fac_shardings), the vector
    iterates (x, z, mu) shard over ALL devices (vec_sharding), and under
    SPMD the per-iteration scalar reductions — w1 = eᵀw, w2 = wᵀ(Yq), the
    residual norms — lower to cross-device all-reduces while the z/mu box
    updates stay purely device-local.  Consecutive C values warm-start from
    the previous (z, mu) exactly as core.svm.grid_search does locally.

    ``c_values`` entries may be scalars or per-coordinate (n,) vectors (the
    latter pins padded coordinates to zero, cf. tree.pad_dataset).  Returns
    one (z, primal_res_trace) per C, in grid order, with z left sharded on
    the mesh.
    """
    n = y.shape[0]
    v_sh = vec_sharding(mesh)
    y_d = jax.device_put(jnp.asarray(y, jnp.float32), v_sh)
    beta = fac.beta

    @jax.jit
    def run(fac_, y_, c, z0, mu0):
        state, trace = admm_svm(fac_.solve, y_, c, beta, max_it,
                                z0=z0, mu0=mu0)
        return state.z, state.mu, trace.primal_res

    def make_c(c):
        c_arr = jnp.asarray(c, jnp.float32)
        return jax.device_put(c_arr, v_sh) if c_arr.ndim == 1 else c_arr

    zeros = jax.device_put(jnp.zeros((n,), jnp.float32), v_sh)
    return _run_c_grid(fac, y_d, c_values, mesh, run, make_c, zeros,
                       warm_start)


def _already_placed(fac, fac_sh) -> bool:
    """True when every factor array already has its fac_shardings placement
    (the mesh-parallel build emits it that way — no device_put needed)."""
    for a, s in zip(jax.tree.leaves(fac), jax.tree.leaves(fac_sh)):
        sh = getattr(a, "sharding", None)
        if sh is None:
            return False
        try:
            if not sh.is_equivalent_to(s, a.ndim):
                return False
        except (AttributeError, TypeError):
            return False
    return True


def _run_c_grid(fac, labels_d, c_values, mesh, run, make_c, zeros,
                warm_start) -> list:
    """Shared warm-started C-grid driver for the vector and (n, k) block
    paths: shard the factorization once, then sweep C reusing it."""
    from repro.dist import api as dist_api

    fac_sh = fac_shardings(jax.eval_shape(lambda: fac), mesh)
    fac_d = fac if _already_placed(fac, fac_sh) else jax.device_put(fac, fac_sh)
    z0, mu0 = zeros, zeros
    out = []
    with dist_api.use_mesh(mesh), mesh:
        for c in c_values:
            z, mu, res = run(fac_d, labels_d, make_c(c), z0, mu0)
            out.append((z, res))
            if warm_start:
                z0, mu0 = z, mu
    return out


def mat_sharding(mesh: Mesh) -> NamedSharding:
    """(n, k) iterate blocks: samples sharded over all devices, classes local."""
    return NamedSharding(mesh, PartitionSpec(_node_axis(mesh), None))


def admm_train_multiclass_distributed(
    fac: HSSFactorization,
    ys: jax.Array,
    c_values,
    mesh: Mesh,
    max_it: int = 10,
    warm_start: bool = True,
    pmask: jax.Array | None = None,
) -> list:
    """Data-parallel batched multiclass ADMM C-grid under ``mesh``.

    ``ys`` is the (P, n) per-class (or per-pair) label matrix; the iterate
    blocks are (n, P) with the SAMPLE axis sharded over every device and the
    class axis kept device-local — per-class batching is orthogonal to the
    data-parallel layout, so the k-fold RHS widening adds ZERO cross-device
    traffic: the multi-RHS telescoping solve runs the same collective
    schedule as the single-RHS solve, just with k-column payloads, and the
    per-problem scalar reductions (w2, residual norms) psum k values instead
    of 1.  The C grid reuses the sharded factorization and warm-starts the
    whole (n, P) block, composing the paper's C-amortization with the
    class-axis batching.

    ``pmask`` (P, n) optionally pins non-participating coordinates to [0, 0]
    (one-vs-one pair problems).  Returns one (z (n, P), primal_res (max_it,
    P)) per C, with z left sharded on the mesh.
    """
    n_prob, n = ys.shape
    y_sh = NamedSharding(mesh, PartitionSpec(None, _node_axis(mesh)))
    ys_d = jax.device_put(jnp.asarray(ys, jnp.float32), y_sh)
    mask_d = (jnp.ones_like(ys_d) if pmask is None
              else jax.device_put(jnp.asarray(pmask, jnp.float32), y_sh))
    beta = fac.beta

    @jax.jit
    def run(fac_, ys_, c_upper, z0, mu0):
        state, trace = admm_svm_batched(fac_.solve_mat, ys_, c_upper, beta,
                                        max_it, z0=z0, mu0=mu0)
        return state.z, state.mu, trace.primal_res

    def make_c(c):
        return jnp.asarray(c, jnp.float32) * mask_d

    zeros = jax.device_put(jnp.zeros((n, n_prob), jnp.float32),
                           mat_sharding(mesh))
    return _run_c_grid(fac, ys_d, c_values, mesh, run, make_c, zeros,
                       warm_start)


def build_svm_cell(mesh: Mesh, n: int = 1 << 22, leaf: int = 256,
                   rank: int = 64, beta: float = 1e4, max_it: int = 10,
                   dtype=jnp.float32, solve_dtype=None, data=None,
                   spec=None, comp=None, c_value: float = 1.0):
    """(fn, args, in_shardings) for the SVM distributed training cell.

    Without ``data`` this is the dry-run cell: ``args`` are
    ShapeDtypeStructs for an n-point problem (default n = 4.2M samples — the
    susy-scale regime; paper Table 1's largest dataset is 3.5M) and the cell
    is lower/compile-only (launch/dryrun.py); the third arg is the scalar C.

    With ``data=(x, y)`` the cell runs FOR REAL: a thin wrapper over
    ``core.engine.HSSSVMEngine`` builds the sharded compression +
    factorization under ``mesh`` and ``args`` are live mesh-resident arrays
    — (factorization, permuted labels, per-coordinate C upper bound) with
    the bound equal to ``c_value`` on real points and 0 on pads — so
    ``jax.jit(fn, in_shardings=in_sh)(*args)`` trains that C end-to-end
    with every stage node-sharded.  To sweep C, rescale:
    ``fn(fac, y, new_c / c_value * args[2])``.  ``spec``/``comp`` override
    the kernel and compression accuracy knobs (engine defaults otherwise).
    """
    fn = make_distributed_admm_step(beta, max_it, solve_dtype=solve_dtype)
    if data is None:
        fac_shapes = factorization_shapes(n, leaf, rank, dtype=dtype)
        fac_sh = fac_shardings(fac_shapes, mesh)
        y_shape = jax.ShapeDtypeStruct((n,), jnp.float32)
        c_shape = jax.ShapeDtypeStruct((), jnp.float32)
        in_sh = (fac_sh, vec_sharding(mesh),
                 NamedSharding(mesh, PartitionSpec()))
        return fn, (fac_shapes, y_shape, c_shape), in_sh

    from repro.core.compression import CompressionParams
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec

    x, y = data
    eng = HSSSVMEngine(
        spec=spec if spec is not None else KernelSpec(h=1.0),
        comp=comp if comp is not None else CompressionParams(rank=rank),
        leaf_size=leaf, beta=beta, max_it=max_it, mesh=mesh,
        store_dtype=(None if jnp.dtype(dtype) == jnp.float32
                     else jnp.dtype(dtype).name),
    )
    eng.prepare(x, y)
    v_sh = vec_sharding(mesh)
    y_d = jax.device_put(eng.problem_labels[0], v_sh)
    c_vec = jax.device_put(c_value * eng.problem_masks[0], v_sh)   # pads -> 0
    fac = eng.fac
    in_sh = (fac_shardings(jax.eval_shape(lambda: fac), mesh), v_sh, v_sh)
    return fn, (fac, y_d, c_vec), in_sh
