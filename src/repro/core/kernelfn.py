"""Kernel functions evaluated block-wise.

The Gaussian kernel K(x, y) = exp(-||x-y||^2 / (2 h^2)) is the paper's choice
(Cipolla & Gondzio §3.3).  Block evaluation is the compute hot-spot of both
HSS compression (sampled blocks) and prediction (test × support blocks); the
Pallas kernel in ``repro.kernels.gaussian`` implements the tiled TPU version,
and this module provides the XLA path plus the dispatch switch.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# The ONE streaming block-size default shared by every predict/score path
# (SVMModel / MulticlassSVMModel / EngineModel / the serving tier): the row
# count of each test×support kernel block kept live during scoring.  Serving
# tunes it in one place (serve.BatchPolicy.block defaults to it).
DEFAULT_SCORE_BLOCK = 2048


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A positive-definite kernel with a single bandwidth-like parameter h."""

    name: str = "gaussian"
    h: float = 1.0
    # "xla" | "pallas" | "pallas_interpret" — which block-eval backend to use.
    impl: str = "xla"

    def with_h(self, h: float) -> "KernelSpec":
        return dataclasses.replace(self, h=h)


def _sqdist(xa: Array, xb: Array) -> Array:
    """Pairwise squared distances via the matmul expansion (MXU-friendly).

    The cross term accumulates in f32 (``preferred_element_type``): bf16
    inputs — the serving tier's compute_dtype="bfloat16" path feeds them in
    here — would otherwise accumulate the feature contraction in bf16 and
    hand systematically-off distances to ``exp``.  The f32 cross term also
    promotes the norms, so distances come out f32 regardless of input dtype.
    """
    na = jnp.sum(xa * xa, axis=-1, dtype=jnp.float32)[:, None]
    nb = jnp.sum(xb * xb, axis=-1, dtype=jnp.float32)[None, :]
    cross = jnp.matmul(xa, xb.T, preferred_element_type=jnp.float32)
    return jnp.maximum(na + nb - 2.0 * cross, 0.0)


def gaussian_block_xla(xa: Array, xb: Array, h: float) -> Array:
    """K(xa, xb) for row blocks xa (ma, r), xb (mb, r) -> (ma, mb).

    Distances and exp run in f32 (see ``_sqdist``); the block is then cast
    back to the input dtype — a bf16 build (store_dtype="bfloat16") must get
    bf16 blocks, with only the internal ACCUMULATION widened.
    """
    block = jnp.exp(_sqdist(xa, xb) * (-0.5 / (h * h)))
    return block.astype(jnp.result_type(xa.dtype, xb.dtype))


def laplacian_block_xla(xa: Array, xb: Array, h: float,
                        f_chunk: int = 16) -> Array:
    """exp(-||x-y||_1 / h); an optional PD kernel variant.

    The L1 distance has no matmul expansion, so the naive broadcast builds a
    (ma, mb, f) intermediate — at prediction block sizes that is the largest
    live array of the whole scoring path.  Instead the feature axis is
    scanned in ``f_chunk``-wide slices: live memory is O(ma * mb * f_chunk)
    regardless of the feature count (zero-padded tail chunks contribute
    |0 - 0| = 0 to the distance).
    """
    f = xa.shape[-1]
    n_chunks = -(-f // f_chunk)
    pad = n_chunks * f_chunk - f
    xa_c = jnp.moveaxis(
        jnp.pad(xa, ((0, 0), (0, pad))).reshape(xa.shape[0], n_chunks, f_chunk),
        1, 0)
    xb_c = jnp.moveaxis(
        jnp.pad(xb, ((0, 0), (0, pad))).reshape(xb.shape[0], n_chunks, f_chunk),
        1, 0)

    def body(acc, ab):
        a, b = ab
        return acc + jnp.sum(jnp.abs(a[:, None, :] - b[None, :, :]), -1), None

    d1, _ = jax.lax.scan(
        body, jnp.zeros((xa.shape[0], xb.shape[0]), xa.dtype), (xa_c, xb_c))
    return jnp.exp(-d1 / h)


_VALID_IMPLS = ("xla", "pallas", "pallas_interpret")


def kernel_block(spec: KernelSpec, xa: Array, xb: Array) -> Array:
    """Evaluate a (len(xa), len(xb)) kernel block under ``spec``.

    Both kernels have a Pallas implementation: gaussian in
    ``repro.kernels.gaussian`` (MXU matmul expansion) and laplacian in
    ``repro.kernels.compress.laplacian`` (feature-chunked L1 scan, the tiled
    twin of ``laplacian_block_xla``).  Unknown ``impl`` values raise instead
    of silently running XLA.
    """
    if spec.impl not in _VALID_IMPLS:
        raise ValueError(
            f"unknown kernel impl {spec.impl!r}; expected one of {_VALID_IMPLS}")
    if spec.name == "gaussian":
        if spec.impl in ("pallas", "pallas_interpret"):
            # Deferred import: kernels package depends on core being importable.
            from repro.kernels.gaussian import ops as gops

            return gops.gaussian_block(
                xa, xb, spec.h, interpret=(spec.impl == "pallas_interpret")
            )
        return gaussian_block_xla(xa, xb, spec.h)
    if spec.name == "laplacian":
        if spec.impl in ("pallas", "pallas_interpret"):
            from repro.kernels.compress import laplacian as lops

            return lops.laplacian_block(
                xa, xb, spec.h, interpret=(spec.impl == "pallas_interpret")
            )
        return laplacian_block_xla(xa, xb, spec.h)
    raise ValueError(f"unknown kernel {spec.name!r}")


def kernel_matvec_streamed(
    spec: KernelSpec, x_rows: Array, x_cols: Array, v: Array,
    block: int = DEFAULT_SCORE_BLOCK,
) -> Array:
    """(K(x_rows, x_cols) @ v) without materializing the full block.

    Streams over row blocks with ``lax.map`` — O(block * n_cols) live memory.
    Used by prediction when the support set is large.  ``v`` may be a single
    (n_cols,) vector or a (n_cols, k) block — multiclass prediction scores
    all k per-class coefficient columns against each kernel block while it
    is live, so k classes cost one pass over the kernel, not k.
    """
    n = x_rows.shape[0]
    pad = (-n) % block
    xr = jnp.pad(x_rows, ((0, pad), (0, 0)))
    xr = xr.reshape(-1, block, x_rows.shape[1])

    def body(xblk):
        # f32 accumulation over the (potentially huge) support axis — a bf16
        # coefficient vector must not drag the reduction down to bf16.
        return jnp.matmul(kernel_block(spec, xblk, x_cols), v,
                          preferred_element_type=jnp.float32)

    out = jax.lax.map(body, xr)
    out = out.reshape(-1) if v.ndim == 1 else out.reshape(-1, v.shape[1])
    return out[:n]
