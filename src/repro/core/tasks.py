"""ε-SVR and one-class SVM as thin BoxQPTask specs on the shared K_β⁻¹.

The shifted kernel K̃ + βI depends only on the data, the bandwidth h, and β —
never on the task.  Related work (semismooth-Newton and augmented-Lagrangian
kernel-machine solvers) treats kernel SVC, ε-SVR, and one-class/novelty
detection as instances of one box-QP family; this module supplies the two
non-classification members on the exact same HSS compression + factorization
the SVM path uses (see repro.core.admm for the generic solver and
repro.core.engine for the orchestration):

  ε-SVR (difference-form dual, variables α = α⁺ − α⁻ ∈ R^d):
      min ½ αᵀKα − yᵀα + ε‖α‖₁   s.t. eᵀα = 0,  α ∈ [−C, C]^d
    The ℓ1 term — which makes the 2d-variable form a QP — is handled
    exactly by the ADMM z-step's soft-threshold prox, so the d-dimensional
    difference form rides K_β⁻¹ directly: ONE multi-RHS solve per
    iteration, same as classification.  Prediction is f(x) = Σ αᵢ K(xᵢ, x)
    + b with b recovered from the margin support vectors (|αᵢ| strictly
    inside (0, C): y_i − f(x_i) = ε·sign(αᵢ)).

  one-class SVM (Schölkopf ν-parameterization):
      min ½ αᵀKα   s.t. eᵀα = 1,  α ∈ [0, 1/(νn)]^d
    ν bounds the outlier fraction; the offset ρ = (Kα)ᵢ on the margin
    support vectors (0 < αᵢ < 1/(νn)), and f(x) = Σ αᵢ K(xᵢ, x) − ρ is
    ≥ 0 on the estimated support of the data.

Both bias extractions cost ONE HSS matmat (paper eq. (7)'s trick applied to
the new tasks) and are batched over problem columns like compute_bias_batched.
Padded points (tree.pad_dataset) are pinned to the [0, 0] box through the
participation mask exactly as in classification, so the restriction of the
ADMM fixed point to real points solves the original problem.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admm import BoxQPTask, box_matrix
from repro.core.hss import HSSMatrix

Array = jax.Array


# --------------------------------------------------------------------- #
# task builders                                                         #
# --------------------------------------------------------------------- #
def svr_task(targets: Array, c_box: Array | float, epsilon: Array | float
             ) -> BoxQPTask:
    """ε-SVR difference-form dual for k regression problems.

    ``targets`` is (k, d) (or (d,)) response vectors; ``c_box`` a scalar or
    (k, d) per-coordinate bound — pass C·mask so padded points get the
    inert [0, 0] box; ``epsilon`` the tube half-width (scalar or (k,)).
    """
    t = jnp.atleast_2d(jnp.asarray(targets))            # (k, d)
    k, d = t.shape
    dtype = t.dtype
    c_mat = box_matrix(c_box, d, k, dtype)
    return BoxQPTask(
        sign=jnp.ones((d, k), dtype),
        lin=-t.T,
        lo=-c_mat,
        hi=c_mat,
        eq_sa=jnp.ones((d,), dtype),
        eq_b=None,
        l1=jnp.broadcast_to(jnp.asarray(epsilon, dtype), (k,)),
    )


def one_class_task(mask: Array, nu: Array | float) -> BoxQPTask:
    """Schölkopf ν one-class SVM for k problems.

    ``mask`` is (k, d) (or (d,)) participation masks (1 real, 0 pad): the
    box upper bound is mask/(ν·n_real) so pads are pinned to [0, 0] and the
    feasible mass eᵀα = 1 lives on real points (1/ν ≥ 1 of box headroom).
    """
    m = jnp.atleast_2d(jnp.asarray(mask))               # (k, d)
    k, d = m.shape
    dtype = m.dtype
    n_real = jnp.sum(m, axis=1)                         # (k,)
    nu_arr = jnp.broadcast_to(jnp.asarray(nu, dtype), (k,))
    hi = m.T / (nu_arr * n_real)[None, :]               # (d, k); pads -> 0
    return BoxQPTask(
        sign=jnp.ones((d, k), dtype),
        lin=jnp.zeros((d, k), dtype),
        lo=jnp.zeros((d, k), dtype),
        hi=hi,
        eq_sa=jnp.ones((d,), dtype),
        eq_b=jnp.ones((k,), dtype),
        l1=None,
    )


# --------------------------------------------------------------------- #
# bias / offset extraction (one HSS matmat each, batched over columns)  #
# --------------------------------------------------------------------- #
def compute_bias_svr_batched(hss: HSSMatrix, targets: Array, alpha: Array,
                             c_mat: Array, masks: Array,
                             epsilon: Array | float,
                             margin_rel: float = 1e-4) -> Array:
    """SVR bias from the margin SVs, with ONE HSS matmat for all P problems.

    For margin support vectors (0 < |αᵢ| < C strictly) the KKT conditions
    give yᵢ − (Kα)ᵢ − b = ε·sign(αᵢ), so b averages yᵢ − (Kα)ᵢ − ε·sign(αᵢ)
    over them.  Falls back to all support vectors, then to all real points
    (ε term dropped — the unbiased residual mean).  All column blocks are
    (d, P); returns (P,).
    """
    k_alpha = hss.matmat(alpha)                         # K̃ α, one O(N r) sweep
    absa = jnp.abs(alpha)
    tol = margin_rel * c_mat
    resid = targets - k_alpha - epsilon * jnp.sign(alpha)
    on_margin = ((absa > tol) & (absa < c_mat - tol)
                 & (masks > 0)).astype(alpha.dtype)
    n_m = jnp.sum(on_margin, axis=0)
    f32 = jnp.float32
    b_margin = (jnp.einsum("dp,dp->p", on_margin, resid,
                           preferred_element_type=f32)
                / jnp.maximum(n_m, 1.0))
    sv = ((absa > tol) & (masks > 0)).astype(alpha.dtype)
    n_sv = jnp.sum(sv, axis=0)
    b_sv = (jnp.einsum("dp,dp->p", sv, resid, preferred_element_type=f32)
            / jnp.maximum(n_sv, 1.0))
    b_all = (jnp.einsum("dp,dp->p", masks, targets - k_alpha,
                        preferred_element_type=f32)
             / jnp.maximum(jnp.sum(masks, axis=0), 1.0))
    return jnp.where(n_m > 0, b_margin, jnp.where(n_sv > 0, b_sv, b_all))


def compute_rho_oneclass_batched(hss: HSSMatrix, alpha: Array, hi_mat: Array,
                                 masks: Array, margin_rel: float = 1e-3
                                 ) -> Array:
    """One-class offset ρ = (K̃α)ᵢ averaged over margin SVs (0 < αᵢ < 1/(νn)).

    Falls back to all support vectors when every SV sits at the bound.  The
    decision function is f(x) = Σ αᵢ K(xᵢ, x) − ρ (≥ 0 inside the estimated
    support), i.e. the model bias is −ρ.  Blocks are (d, P); returns (P,).
    """
    k_alpha = hss.matmat(alpha)
    tol = margin_rel * hi_mat
    on_margin = ((alpha > tol) & (alpha < hi_mat - tol)
                 & (masks > 0)).astype(alpha.dtype)
    n_m = jnp.sum(on_margin, axis=0)
    f32 = jnp.float32
    rho_margin = (jnp.einsum("dp,dp->p", on_margin, k_alpha,
                             preferred_element_type=f32)
                  / jnp.maximum(n_m, 1.0))
    sv = ((alpha > tol) & (masks > 0)).astype(alpha.dtype)
    n_sv = jnp.maximum(jnp.sum(sv, axis=0), 1.0)
    rho_sv = (jnp.einsum("dp,dp->p", sv, k_alpha, preferred_element_type=f32)
              / n_sv)
    return jnp.where(n_m > 0, rho_margin, rho_sv)


def prolong_scale(task: str, n_coarse_real: int, n_fine_real: int) -> float:
    """Dual rescale factor n_c/n_f for coarse→fine prolongation.

    The decision function f(x) = Σᵢ αᵢ K(xᵢ, x) sums one kernel term per
    training point, so at a comparable margin the individual duals shrink
    like 1/n as the training set grows: nearest-neighbour prolongation
    copies each coarse dual ≈ n_f/n_c times, and without the n_c/n_f
    rescale the warm start overshoots the fine-level magnitudes by that
    factor (measurably worse than a cold start for SVC).  For one-class
    the same factor additionally restores unit mass eᵀα = 1 and maps the
    coarse box bound 1/(ν·n_c) onto the fine one 1/(ν·n_f).
    """
    del task  # the 1/n magnitude argument applies to every box-QP family
    return float(n_coarse_real) / float(max(n_fine_real, 1))


# --------------------------------------------------------------------- #
# validation metrics + grid drivers (ε / ν sweeps in place of C)        #
# --------------------------------------------------------------------- #
def svr_score(model, x_val: Array, y_val: Array) -> float:
    """Negated RMSE (higher is better, run_grid_search maximizes)."""
    pred = model.predict(x_val)
    return -float(jnp.sqrt(jnp.mean((pred - jnp.asarray(y_val)) ** 2)))


def oneclass_metrics(pred, y_true) -> dict:
    """Outlier-detection metrics from ±1 predictions vs ±1 ground truth:
    precision/recall of the outlier (−1) class and balanced accuracy.
    The ONE home of the flagged/precision/recall arithmetic — bench, serve
    and the examples all report from here so the numbers cannot diverge."""
    pred = np.asarray(pred)
    y_true = np.asarray(y_true)
    flagged = pred < 0
    out = y_true < 0
    precision = float((flagged & out).sum() / max(flagged.sum(), 1))
    recall = float((flagged & out).sum() / max(out.sum(), 1))
    r_in = float((~flagged & ~out).sum() / max((~out).sum(), 1))
    return dict(precision=precision, recall=recall,
                balanced_accuracy=0.5 * (recall + r_in))


def oneclass_score(model, x_val: Array, y_val: Array) -> float:
    """Balanced accuracy of inlier(+1)/outlier(−1) detection."""
    return oneclass_metrics(model.predict(x_val), y_val)["balanced_accuracy"]


def grid_search_svr(
    x: np.ndarray,
    y: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    hs: Sequence[float],
    epsilons: Sequence[float],
    c_value: float = 1.0,
    trainer_kwargs: dict | None = None,
    rtol: float | None = None,
) -> tuple[object, dict]:
    """(h, ε) grid for ε-SVR — ε sweeps in place of C (paper §3.3 pattern).

    Per h: ONE compression + ONE factorization serve the whole warm-started
    ε sweep (the task's linear term and prox threshold change, the kernel
    side never does).  Scores are negated validation RMSE.
    """
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.core.svm import resolve_rtol, run_grid_search

    kw = resolve_rtol(trainer_kwargs, rtol)
    return run_grid_search(
        lambda h: HSSSVMEngine(spec=KernelSpec(h=h), task="svr",
                               svr_c=c_value, **kw),
        x, y, x_val, y_val, hs, epsilons, score_fn=svr_score)


def grid_search_oneclass(
    x: np.ndarray,
    x_val: np.ndarray,
    y_val: np.ndarray,
    hs: Sequence[float],
    nus: Sequence[float],
    trainer_kwargs: dict | None = None,
    rtol: float | None = None,
) -> tuple[object, dict]:
    """(h, ν) grid for one-class SVM — ν sweeps in place of C.

    Training is unsupervised (no y); ``y_val`` holds ±1 inlier/outlier
    labels scored by balanced accuracy.  Per h: one compression + one
    factorization for the whole warm-started ν sweep.
    """
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.core.svm import resolve_rtol, run_grid_search

    kw = resolve_rtol(trainer_kwargs, rtol)
    return run_grid_search(
        lambda h: HSSSVMEngine(spec=KernelSpec(h=h), task="oneclass", **kw),
        x, None, x_val, y_val, hs, nus, score_fn=oneclass_score)
