"""HSS-ANN-style compression of a kernel matrix, partially matrix-free.

Paper §3.1 / Chávez et al. IPDPS'20: instead of random sketching, use the
data geometry to pick the kernel entries that matter.  TPU adaptation
(DESIGN.md §3.2):

  * proxy columns per node = NEAR points (the sibling cluster — the ANN
    surrogate: boundary neighbours dominate the off-diagonal block's range)
    + FAR points (uniform sample of the complement) — index sets built once
    on the host;
  * skeleton selection per node = interpolative decomposition via pivoted QR
    on the sampled block (repro.core.idqr), vmapped over all nodes of a
    level;
  * total kernel evaluations O(N * n_proxy) — never the full matrix.

Construction cost O(r^2 N) and storage O(r N), matching the paper's claims
for HSS-ANN (§1.2).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idqr
from repro.core.hss import HSSMatrix, rank_mask
from repro.core.kernelfn import KernelSpec, kernel_block
from repro.core.tree import ClusterTree

Array = jax.Array

# Counting-kernel instrumentation state (see ``counting_kernel_evals``).
_EVAL_STATE: dict | None = None


@contextlib.contextmanager
def counting_kernel_evals():
    """Count the kernel entries a ``compress`` call actually evaluates.

    Every kernel evaluation inside the build flows through the two seams
    below (``_batched_kernel_block`` / ``_batched_row_id``), which add the
    logical block sizes to this counter whenever their operands are concrete
    — i.e. for the eager host-orchestrated ``compress``.  Inside traced
    contexts (``compress_sharded``'s shard_map bodies) the operands are
    tracers and nothing is counted: per-device shapes would double-count.

    Yields a dict whose ``"count"`` entry is the running total; the property
    test pins it against the hand-derived ``kernel_eval_count`` formula.
    """
    global _EVAL_STATE
    prev = _EVAL_STATE
    _EVAL_STATE = {"count": 0}
    try:
        yield _EVAL_STATE
    finally:
        _EVAL_STATE = prev


def _note_evals(xa: Array, xb: Array, count: int) -> None:
    if _EVAL_STATE is not None and not (
            isinstance(xa, jax.core.Tracer) or isinstance(xb, jax.core.Tracer)):
        _EVAL_STATE["count"] += count


def _batched_kernel_block(spec: KernelSpec, xa: Array, xb: Array) -> Array:
    """vmapped ``kernel_block`` over (B, ·, f) stacks — the eval-count seam."""
    _note_evals(xa, xb, xa.shape[0] * xa.shape[1] * xb.shape[1])
    return jax.vmap(lambda a, b: kernel_block(spec, a, b))(xa, xb)


def _batched_row_id(
    spec: KernelSpec,
    xc: Array,
    xp: Array,
    k: int,
    rtol: float | None,
    adaptive: bool,
    cmask: Array | None = None,
) -> tuple[Array, Array, Array]:
    """All row IDs of one tree level behind ``KernelSpec.impl``.

    xc (B, m, f) candidate points, xp (B, s, f) proxy points.  Returns
    (piv (B, k) int32, p_mat (B, m, k), ranks (B,) int32).  The Pallas impls
    dispatch to the fused assemble+ID kernel (``repro.kernels.compress``):
    the sampled blocks K(xc_i, xp_i) are evaluated in VMEM and consumed by
    the pivoted-QR deflation loop in place, one launch for the whole level.
    ``impl="xla"`` keeps the reference per-node assemble-then-ID closures.
    Both paths count the SAME logical kernel evaluations at this seam, so
    ``kernel_eval_count`` is impl-independent.
    """
    _note_evals(xc, xp, xc.shape[0] * xc.shape[1] * xp.shape[1])
    eff_rtol = 1e-5 if rtol is None else rtol
    if spec.impl in ("pallas", "pallas_interpret"):
        from repro.kernels.compress import ops as cops

        return cops.batched_assemble_id(
            xc, xp, k, kernel_name=spec.name, h=spec.h, rtol=eff_rtol,
            adaptive=adaptive, cmask=cmask,
            interpret=(spec.impl == "pallas_interpret"))

    def one(xc_i: Array, xp_i: Array, cm_i: Array | None):
        a = kernel_block(spec, xc_i, xp_i)
        if cm_i is not None:
            # Zero dead candidate rows: skeleton propagation only ever
            # forwards LIVE child skeleton points (dead rows get zero
            # interpolation weights and sort behind every live pivot).
            a = a * cm_i[:, None]
        if adaptive:
            piv, p_mat, rk = idqr.row_interp_decomp_ranked(a, k, eff_rtol)
        else:
            piv, p_mat = idqr.row_interp_decomp(a, k)
            rk = jnp.int32(k)
        return piv.astype(jnp.int32), p_mat, rk

    if cmask is None:
        return jax.vmap(lambda c, p: one(c, p, None))(xc, xp)
    return jax.vmap(one)(xc, xp, cmask)


@dataclasses.dataclass(frozen=True)
class CompressionParams:
    """Accuracy knobs, analogous to the paper's STRUMPACK parameters.

    rtol      ~ rel_tol        (Table 4 "crude": 1e-2, Table 5 "accurate":
                1e-4) — the paper-facing accuracy knob.  None = legacy
                fixed-rank mode: every node stores the full ``rank`` columns.
                A float switches on the ADAPTIVE build: each node's numerical
                rank is detected from the pivoted-QR diagonal decay against
                rtol, truncated columns are exact zeros, and
                ``hss.shrink_to_fit`` can slice each level to its observed
                max rank.
    rank      ~ hss_max_rank   (Table 4: 200, Table 5: 2000 — here per
                level).  With rtol set this is only the CAP on the detected
                rank (STRUMPACK semantics); without it, the rank itself.
    n_near    ~ hss_approximate_neighbors (Table 4: 64, Table 5: 512)
    n_far     — far-field proxy sample size
    """

    rank: int = 32
    n_near: int = 32
    n_far: int = 32
    seed: int = 0
    rtol: float | None = None

    @property
    def n_proxy(self) -> int:
        return self.n_near + self.n_far

    @classmethod
    def crude(cls, **kw) -> "CompressionParams":
        """Paper Table 4 regime: loose tolerance, small cap/neighbourhoods."""
        return cls(**{**dict(rank=32, n_near=32, n_far=32, rtol=1e-2), **kw})

    @classmethod
    def accurate(cls, **kw) -> "CompressionParams":
        """Paper Table 5 regime: tight tolerance, larger cap/neighbourhoods."""
        return cls(**{**dict(rank=64, n_near=64, n_far=128, rtol=1e-4), **kw})


def kernel_eval_count(tree: ClusterTree, params: CompressionParams) -> int:
    """Exact number of kernel entries ``compress`` evaluates for this tree.

    The partially matrix-free build touches O(N · n_proxy) entries instead of
    N² — this counts them exactly (leaf diagonal blocks + leaf sampled
    blocks + per-level candidate×proxy blocks + B couplings), for the bench's
    perf trajectory.  Static per (tree, params): the adaptive build masks
    entries but the sampled block SHAPES are the rank cap, so adaptivity
    shows up in stored ranks and factor/solve cost, not here.
    """
    m, K = tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    r0 = min(params.rank, m)
    total = n_leaf * (m * m + m * params.n_proxy)
    r_prev = r0
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        total += n_k * r_prev * r_prev                  # sibling couplings B
        if k == K:
            break
        total += n_k * (2 * r_prev) * (2 * r_prev + params.n_far)
        r_prev = min(params.rank, 2 * r_prev)
    return total


def _cand_mask(ranks: Array, rp: int, dtype) -> Array:
    """(2·n,) child rank vector -> (n, 2·rp) candidate-slot liveness.

    One row per parent: the two children's ``hss.rank_mask`` rows side by
    side — shared by the local and sharded builds so the masking rule cannot
    drift between them.
    """
    return rank_mask(ranks, rp, dtype).reshape(-1, 2 * rp)


def _mask_b(b: Array, cm: Array, rp: int) -> Array:
    """Zero B rows/columns of dead child skeletons (exact structural zeros)."""
    return b * cm[:, :rp, None] * cm[:, rp:][:, None, :]


def _complement_sample(
    rng: np.random.Generator, n: int, span_start: int, span_width: int, count: int
) -> np.ndarray:
    """Uniform sample of indices in [0, n) \\ [span_start, span_start+width)."""
    u = rng.integers(0, n - span_width, size=count)
    return np.where(u < span_start, u, u + span_width).astype(np.int32)


def _host_proxy_indices(
    tree: ClusterTree, params: CompressionParams
) -> list[np.ndarray]:
    """Per-level FAR proxy index arrays: far[k] has shape (n_k, n_far)."""
    rng = np.random.default_rng(params.seed)
    n, m, K = tree.n, tree.leaf_size, tree.levels
    out = []
    for k in range(K):  # levels 0..K-1 need bases/skeletons
        n_k = 2 ** (K - k)
        width = m * 2 ** k
        rows = [
            _complement_sample(rng, n, node * width, width, params.n_far)
            for node in range(n_k)
        ]
        out.append(np.stack(rows, axis=0))
    return out


def _host_leaf_near(
    tree: ClusterTree, params: CompressionParams, x_perm: np.ndarray | None = None
) -> np.ndarray:
    """(n_leaf, n_near) NEAR-proxy indices per leaf.

    The paper's HSS-ANN strategy: the dominant entries of a leaf's
    off-diagonal block row correspond to its points' nearest neighbours in
    *other* clusters.  With data available we find them with a KD-tree
    (scipy) — the exact analogue of STRUMPACK's ANN preprocessing; without
    data we fall back to sampling the sibling leaf (tree-adjacent ≈ near).
    """
    rng = np.random.default_rng(params.seed + 1)
    m, K = tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    out = np.empty((n_leaf, params.n_near), dtype=np.int32)
    if x_perm is not None and n_leaf > 1:
        from scipy.spatial import cKDTree

        # f32 is plenty for neighbour RANKING and keeps scipy happy with
        # dtypes it cannot handle (bf16); the kernel evaluations themselves
        # stay in the caller's dtype.
        x_f32 = np.asarray(x_perm, np.float32)
        kdt = cKDTree(x_f32)
        k_query = min(max(2 * params.n_near // m + 4, 4), tree.n)
        _, nbr = kdt.query(x_f32, k=k_query)   # (n, k) incl. self
        leaf_of = np.arange(tree.n) // m
        # Vectorized over ALL leaves at once (the per-leaf Python loop was
        # the host-preprocessing serial bottleneck at large n_leaf): each
        # leaf's candidate pool is its points' neighbour lists, flattened.
        cand = nbr.reshape(n_leaf, m * k_query).astype(np.int64)
        own = leaf_of[cand] == np.arange(n_leaf)[:, None]   # in-leaf -> drop
        # Duplicate suppression without per-row np.unique: sort ids per row,
        # mark repeats, scatter the mask back to original positions.
        order = np.argsort(cand, axis=1, kind="stable")
        sorted_ids = np.take_along_axis(cand, order, axis=1)
        dup_sorted = np.zeros_like(own)
        dup_sorted[:, 1:] = sorted_ids[:, 1:] == sorted_ids[:, :-1]
        dup = np.zeros_like(own)
        np.put_along_axis(dup, order, dup_sorted, axis=1)
        invalid = own | dup
        # Rank candidates by distance to the leaf centroid; invalid -> +inf.
        centroid = x_f32.reshape(n_leaf, m, -1).mean(axis=1)
        dist = np.linalg.norm(
            x_f32[cand] - centroid[:, None, :], axis=2)
        dist[invalid] = np.inf
        pick = np.argsort(dist, axis=1, kind="stable")[:, : params.n_near]
        out[:] = np.take_along_axis(cand, pick, axis=1)
        # Deficit rows (candidate pool smaller than n_near — tiny problems
        # only): top up from the sibling leaf, EXCLUDING candidates already
        # placed (a duplicate NEAR proxy is a duplicate sampled-block column:
        # it wastes ID sample budget and skews the pivot order).  Repeats are
        # only permitted once the whole sibling leaf is exhausted.
        counts = (~invalid).sum(axis=1)
        for i in np.nonzero(counts < params.n_near)[0]:
            c = int(counts[i])
            short = params.n_near - c
            sib = int(i) ^ 1
            pool = np.setdiff1d(
                np.arange(m, dtype=np.int64) + sib * m, out[i, :c])
            if len(pool) >= short:
                fill = rng.choice(pool, size=short, replace=False)
            else:
                extra = rng.choice(m, size=short - len(pool)) + sib * m
                fill = np.concatenate([pool, extra])
            out[i, c:] = fill
        return out
    for i in range(n_leaf):
        sib = i ^ 1
        out[i] = rng.choice(m, size=params.n_near, replace=params.n_near > m) + sib * m
    return out


def compress(
    x_perm: Array,
    tree: ClusterTree,
    spec: KernelSpec,
    params: CompressionParams = CompressionParams(),
) -> HSSMatrix:
    """Build the HSS approximation of K(x_perm, x_perm).

    ``x_perm`` must already be in tree (leaf-major) order:
    ``x_perm = x[tree.perm]``.  A host numpy array is accepted directly —
    the host copy the proxy preprocessing needs anyway — so callers that
    already hold the data on the host (``compress_sharded``'s fallback, the
    engine) never pay a device round-trip for it.
    """
    n, m, K = tree.n, tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    if x_perm.shape[0] != n:
        raise ValueError(f"x has {x_perm.shape[0]} rows, tree expects {n}")
    r0 = min(params.rank, m)
    adaptive, rtol = params.rtol is not None, params.rtol

    far_idx = [jnp.asarray(a) for a in _host_proxy_indices(tree, params)]
    if isinstance(x_perm, np.ndarray):
        # Already on the host: use it as-is for the KD-tree preprocessing.
        # (Wrapping it in jnp.asarray first and gathering it back — the old
        # fallback behaviour — kept TWO full copies of the dataset alive.)
        x_host = x_perm
        x_perm = jnp.asarray(x_host)
    else:
        x_host = np.asarray(jax.device_get(x_perm))
    leaf_near = jnp.asarray(_host_leaf_near(tree, params, x_host))

    x_leaves = x_perm.reshape(n_leaf, m, -1)

    # ---------------- leaves ---------------- #
    d_leaf = _batched_kernel_block(spec, x_leaves, x_leaves)

    prox0 = jnp.concatenate([leaf_near, far_idx[0]], axis=1)
    x_prox0 = jnp.take(x_perm, prox0, axis=0)      # (n_leaf, n_proxy, f)
    piv0, u_leaf, leaf_ranks = _batched_row_id(
        spec, x_leaves, x_prox0, r0, rtol, adaptive)
    leaf_starts = jnp.arange(n_leaf, dtype=jnp.int32) * m
    skel_leaf = leaf_starts[:, None] + piv0

    # ---------------- internal levels ---------------- #
    transfers: list[Array] = []
    skels: list[Array] = []
    b_mats: list[Array] = []
    level_ranks: list[Array] = []
    skel_prev = skel_leaf                     # (n_{k-1}, r_{k-1})
    rank_prev = leaf_ranks                    # (n_{k-1},) numerical ranks
    r_prev = r0
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        cand = skel_prev.reshape(n_k, 2 * r_prev)      # children skeleton ids
        # Liveness of each candidate slot under the children's detected ranks
        # (all-ones in fixed-rank mode).
        cmask = _cand_mask(rank_prev, r_prev, x_perm.dtype)
        # B couplings: K(skel_c1, skel_c2) — pure kernel evals.  Dead
        # skeleton rows/columns are masked to exact zeros so the truncation
        # is structural (factorization decouples them; shrink slices them).
        xa = jnp.take(x_perm, cand[:, :r_prev], axis=0)
        xb = jnp.take(x_perm, cand[:, r_prev:], axis=0)
        b_k = _batched_kernel_block(spec, xa, xb)
        if adaptive:
            b_k = _mask_b(b_k, cmask, r_prev)
        b_mats.append(b_k)
        if k == K:
            break
        r_k = min(params.rank, 2 * r_prev)
        # NEAR proxies: the sibling node's candidate skeletons (dynamic).
        sib = cand.reshape(n_k // 2, 2, 2 * r_prev)[:, ::-1, :].reshape(n_k, 2 * r_prev)
        prox = jnp.concatenate([sib, far_idx[k]], axis=1)
        xc = jnp.take(x_perm, cand, axis=0)            # (n_k, 2 r_prev, f)
        xp = jnp.take(x_perm, prox, axis=0)
        piv_k, t_k, rank_k = _batched_row_id(
            spec, xc, xp, r_k, rtol, adaptive,
            cmask=cmask if adaptive else None)
        skel_k = jnp.take_along_axis(cand, piv_k, axis=1)
        transfers.append(t_k)
        skels.append(skel_k)
        level_ranks.append(rank_k)
        skel_prev, rank_prev, r_prev = skel_k, rank_k, r_k

    return HSSMatrix(
        x=x_perm,
        d_leaf=d_leaf,
        u_leaf=u_leaf,
        skel_leaf=skel_leaf,
        transfers=tuple(transfers),
        skels=tuple(skels),
        b_mats=tuple(b_mats),
        levels=K,
        leaf_size=m,
        leaf_ranks=leaf_ranks if adaptive else None,
        level_ranks=tuple(level_ranks) if adaptive else (),
    )


def _mesh_nodes(mesh) -> tuple[tuple[str, ...], int]:
    """All mesh axes combined into one logical node axis, + device count."""
    nodes = tuple(mesh.axis_names)
    ndev = 1
    for a in nodes:
        ndev *= mesh.shape[a]
    return nodes, ndev


def compress_sharded(
    x_perm,
    tree: ClusterTree,
    spec: KernelSpec,
    params: CompressionParams = CompressionParams(),
    mesh=None,
) -> HSSMatrix:
    """Mesh-parallel HSS build: every stage node-sharded from the start.

    The single-device ``compress`` materializes every per-level array on one
    device — the O(N m) leaf blocks alone exceed a single device's HBM at the
    paper's Table-1 scales.  Here the leaf axis is sharded over ALL mesh
    devices end-to-end:

      * host preprocessing gathers each leaf's proxy *points* (near + far,
        O(n_leaf * n_proxy * f)) so no device-side global gather over the
        full dataset is ever needed;
      * the leaf stage (diagonal blocks, ID-QR bases, skeleton selection)
        runs under ``shard_map`` with n_leaf/ndev leaves per device;
      * each level transition carries only the skeleton POINTS
        (n_k, r_k, f) and their global ids upward — O(r n_k) per level, the
        distributed-memory HSS-ANN communication pattern (STRUMPACK §3.1);
      * a level degrades to replicated (one all-gather of the skeleton
        points, after which every device redundantly computes the tiny
        upper-tree arrays) exactly when its node count stops being evenly
        pair-shardable — the same fallback rule as
        ``distributed.fac_shardings``.

    ``x_perm`` may be a host numpy array (preferred — it is needed on the
    host for KD-tree preprocessing anyway) or a jax array.  Requires
    ``tree.n_leaves % n_devices == 0``; otherwise falls back to the local
    build (the result is then unsharded).  Numerically this computes the
    same interpolative decompositions on the same sampled blocks as
    ``compress`` (parity-tested to <=1e-5 in tests/test_engine.py).
    """
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.dist.api import shard_map

    n, m, K = tree.n, tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    # Preserve the caller's dtype: the local build does, and downcasting here
    # (the old behaviour) made the two builds disagree for f64/bf16 inputs.
    # Host preprocessing that needs f32 (the KD-tree) casts internally.
    x_host = np.asarray(jax.device_get(x_perm))
    if x_host.shape[0] != n:
        raise ValueError(f"x has {x_host.shape[0]} rows, tree expects {n}")
    nodes, ndev = _mesh_nodes(mesh)
    if K == 0 or n_leaf % ndev != 0:
        # compress() takes host arrays directly — re-wrapping x_host in a
        # device array here would pay the host->device copy a second time.
        return compress(x_host, tree, spec, params)

    r0 = min(params.rank, m)
    adaptive, rtol = params.rtol is not None, params.rtol
    p_nodes = PartitionSpec(nodes)
    sh_nodes = NamedSharding(mesh, p_nodes)
    sh_repl = NamedSharding(mesh, PartitionSpec())

    far_idx = _host_proxy_indices(tree, params)
    leaf_near = _host_leaf_near(tree, params, x_host)
    prox0 = np.concatenate([leaf_near, far_idx[0]], axis=1)

    x_leaves = jax.device_put(x_host.reshape(n_leaf, m, -1), sh_nodes)
    x_prox0 = jax.device_put(x_host[prox0], sh_nodes)   # (n_leaf, n_proxy, f)
    leaf_starts = jax.device_put(
        np.arange(n_leaf, dtype=np.int32) * m, sh_nodes)

    # ---------------- leaves (shard_map over the node axis) ------------- #
    def _leaf_stage(xl, xp, starts):
        d = _batched_kernel_block(spec, xl, xl)
        piv, u, rks = _batched_row_id(spec, xl, xp, r0, rtol, adaptive)
        skel = starts[:, None] + piv
        spts = jax.vmap(lambda xa, p: jnp.take(xa, p, axis=0))(xl, piv)
        return d, u, skel, spts, rks

    leaf_fn = jax.jit(shard_map(
        _leaf_stage, mesh,
        in_specs=(p_nodes, p_nodes, p_nodes),
        out_specs=(p_nodes,) * 5))
    d_leaf, u_leaf, skel_leaf, spts, leaf_ranks = leaf_fn(
        x_leaves, x_prox0, leaf_starts)
    sids, sranks = skel_leaf, leaf_ranks

    # ---------------- internal levels ---------------- #
    transfers: list[Array] = []
    skels: list[Array] = []
    b_mats: list[Array] = []
    level_ranks: list[Array] = []
    r_prev = r0
    sharded = True
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        # Pair-shardable: parents divide the devices AND each device holds
        # an even number of parents so the sibling-NEAR exchange is local.
        want = (sharded and n_k % ndev == 0
                and (k == K or (n_k // ndev) % 2 == 0))
        if sharded and not want:
            # Degradation point: one all-gather of the skeleton points/ids/
            # ranks (O(r * n_k) — the only cross-device traffic of the
            # upper tree).
            spts = jax.device_put(spts, sh_repl)
            sids = jax.device_put(sids, sh_repl)
            sranks = jax.device_put(sranks, sh_repl)
            sharded = False
        r_k = min(params.rank, 2 * r_prev)

        if sharded:
            loc = n_k // ndev
            rp, rk = r_prev, r_k
            if k == K:
                def _b_only(sp, sr):
                    cp = sp.reshape(loc, 2 * rp, sp.shape[-1])
                    b = _batched_kernel_block(spec, cp[:, :rp], cp[:, rp:])
                    if adaptive:
                        b = _mask_b(b, _cand_mask(sr, rp, b.dtype), rp)
                    return b

                b_fn = jax.jit(shard_map(
                    _b_only, mesh, in_specs=(p_nodes, p_nodes),
                    out_specs=p_nodes))
                b_mats.append(b_fn(spts, sranks))
                break

            far_pts = jax.device_put(x_host[far_idx[k]], sh_nodes)

            def _level(sp, si, sr, fp):
                f = sp.shape[-1]
                cp = sp.reshape(loc, 2 * rp, f)
                ci = si.reshape(loc, 2 * rp)
                cm = _cand_mask(sr, rp, sp.dtype)
                b = _batched_kernel_block(spec, cp[:, :rp], cp[:, rp:])
                if adaptive:
                    b = _mask_b(b, cm, rp)
                sib = cp.reshape(loc // 2, 2, 2 * rp, f)[:, ::-1]
                sib = sib.reshape(loc, 2 * rp, f)
                xp_ = jnp.concatenate([sib, fp], axis=1)
                piv, t, rks = _batched_row_id(
                    spec, cp, xp_, rk, rtol, adaptive,
                    cmask=cm if adaptive else None)
                ids = jnp.take_along_axis(ci, piv, axis=1)
                pts = jax.vmap(lambda c, p: jnp.take(c, p, axis=0))(cp, piv)
                return b, t, ids, pts, rks

            lvl_fn = jax.jit(shard_map(
                _level, mesh,
                in_specs=(p_nodes,) * 4,
                out_specs=(p_nodes,) * 5))
            b_k, t_k, sids, spts, sranks = lvl_fn(spts, sids, sranks, far_pts)
            b_mats.append(b_k)
            transfers.append(t_k)
            skels.append(sids)
            level_ranks.append(sranks)
        else:
            # Replicated upper tree: same math, every device computes it.
            f = spts.shape[-1]
            cand_pts = spts.reshape(n_k, 2 * r_prev, f)
            cand_ids = sids.reshape(n_k, 2 * r_prev)
            cmask = _cand_mask(sranks, r_prev, spts.dtype)
            b_k = _batched_kernel_block(
                spec, cand_pts[:, :r_prev], cand_pts[:, r_prev:])
            if adaptive:
                b_k = _mask_b(b_k, cmask, r_prev)
            b_mats.append(b_k)
            if k == K:
                break
            sib = cand_pts.reshape(n_k // 2, 2, 2 * r_prev, f)[:, ::-1]
            sib = sib.reshape(n_k, 2 * r_prev, f)
            far_pts = jax.device_put(x_host[far_idx[k]], sh_repl)
            xp_ = jnp.concatenate([sib, far_pts], axis=1)
            piv_k, t_k, sranks = _batched_row_id(
                spec, cand_pts, xp_, r_k, rtol, adaptive,
                cmask=cmask if adaptive else None)
            sids = jnp.take_along_axis(cand_ids, piv_k, axis=1)
            spts = jax.vmap(lambda c, p: jnp.take(c, p, axis=0))(
                cand_pts, piv_k)
            transfers.append(t_k)
            skels.append(sids)
            level_ranks.append(sranks)
        r_prev = r_k

    return HSSMatrix(
        x=jax.device_put(x_host, sh_nodes),
        d_leaf=d_leaf,
        u_leaf=u_leaf,
        skel_leaf=skel_leaf,
        transfers=tuple(transfers),
        skels=tuple(skels),
        b_mats=tuple(b_mats),
        levels=K,
        leaf_size=m,
        leaf_ranks=leaf_ranks if adaptive else None,
        level_ranks=tuple(level_ranks) if adaptive else (),
    )


# --------------------------------------------------------------------- #
# streamed (out-of-core) build                                          #
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class StreamParams:
    """Knobs of the out-of-core streamed build (``compress_streamed``).

    batch_leaves      — nodes processed per device round-trip.  The build's
                        peak device working set is O(batch·m·(m + n_proxy))
                        plus the current batch's outputs — independent of N.
                        Internal levels reuse the same node-batch size
                        (rounded down to even so the sibling-NEAR exchange
                        stays batch-local).
    ckpt_dir          — directory for per-level checkpoints through
                        ``repro.ckpt``; None disables checkpointing (the
                        build is then streamed but not restartable).
    ckpt_every_levels — checkpoint cadence in completed levels (the leaf
                        stage counts as one level).
    max_restarts      — in-process restart budget handed to
                        ``dist.fault.run_resilient``.
    assemble          — "device" materializes the finished HSS as jax
                        arrays (mesh-placed when ``mesh`` is given);
                        "host" leaves the leaves as numpy for callers that
                        checkpoint or inspect without a device footprint.
    """

    batch_leaves: int = 64
    ckpt_dir: str | None = None
    ckpt_every_levels: int = 1
    max_restarts: int = 3
    assemble: str = "device"


@dataclasses.dataclass
class StreamStats:
    """Observability record of one streamed build (bench/CI artifact)."""

    peak_stream_bytes: int = 0      # max over batches of in+out device bytes
    n_batches: int = 0
    resumed_level: int | None = None    # completed levels found on disk
    restarts: int = 0                   # in-process run_resilient restarts
    checkpointed_levels: int = 0


def _stream_leaf_batch(spec, xl, xp, r0, rtol, adaptive):
    """One node batch of the streamed leaf stage (pure and traceable —
    repro.analysis traces it to prove the hot loop is callback-free).

    Identical math to the leaf stage of ``compress``: diagonal blocks +
    proxy-sampled row ID, through the same two eval-counting seams."""
    d = _batched_kernel_block(spec, xl, xl)
    piv, u, rks = _batched_row_id(spec, xl, xp, r0, rtol, adaptive)
    return d, u, piv, rks


def _stream_level_batch(spec, cp, xp, cm, rk, rtol, adaptive):
    """One node batch of a streamed internal level: sibling couplings B +
    the candidate->proxy row ID.  ``cp`` (b, 2·r_prev, f) candidate points,
    ``xp`` (b, 2·r_prev + n_far, f) proxy points, ``cm`` candidate liveness
    (None in fixed-rank mode)."""
    rp = cp.shape[1] // 2
    b = _batched_kernel_block(spec, cp[:, :rp], cp[:, rp:])
    if adaptive:
        b = _mask_b(b, cm, rp)
    piv, t, rks = _batched_row_id(
        spec, cp, xp, rk, rtol, adaptive, cmask=cm if adaptive else None)
    return b, piv, t, rks


def _stream_root_batch(spec, cp, cm, adaptive):
    """The root level stores only the sibling coupling B."""
    rp = cp.shape[1] // 2
    b = _batched_kernel_block(spec, cp[:, :rp], cp[:, rp:])
    if adaptive:
        b = _mask_b(b, cm, rp)
    return b


def _device_bytes(*arrays) -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in arrays)


def _stream_fingerprint(n, m, K, spec, params, dtype) -> dict:
    """Identity of a streamed build — a checkpoint from ANY other problem
    (different data size, tree, kernel, accuracy knobs, dtype) must never be
    resumed into this one.  Stored in the checkpoint manifest's ``extra``
    and compared after a JSON round-trip, so values are plain scalars."""
    return dict(
        kind="hss_streamed_build", n=int(n), leaf_size=int(m), levels=int(K),
        rank=int(params.rank), n_near=int(params.n_near),
        n_far=int(params.n_far), seed=int(params.seed),
        rtol=None if params.rtol is None else float(params.rtol),
        kernel=spec.name, h=float(spec.h), impl=spec.impl,
        dtype=str(np.dtype(dtype)))


def compress_streamed(
    x_perm,
    tree: ClusterTree,
    spec: KernelSpec,
    params: CompressionParams = CompressionParams(),
    stream: StreamParams = StreamParams(),
    mesh=None,
    on_level=None,
) -> tuple[HSSMatrix, StreamStats]:
    """Out-of-core HSS build: the dataset stays on the HOST, the device only
    ever sees one node batch at a time.

    ``compress`` materializes the full (N, f) dataset plus every per-level
    array on the device — O(N·f + N·m) resident bytes, the wall at the
    paper's 10⁵–10⁷ scales.  Here the leaf level is walked in
    ``stream.batch_leaves``-node batches: per batch, gather the batch's
    points and proxy points from host numpy, run the SAME fused per-node
    kernels (``_batched_kernel_block`` / ``_batched_row_id`` — Pallas or
    XLA per ``spec.impl``), and copy the results back into preallocated
    host accumulators.  Level transitions carry skeleton POINTS only
    (gathered per batch from the host by skeleton id), so peak device bytes
    during the build are O(batch·m·(m + n_proxy)) — independent of N
    (``StreamStats.peak_stream_bytes`` records the measured max).

    Restartability: with ``stream.ckpt_dir`` set, each completed level's
    host state is checkpointed through ``repro.ckpt`` and the level loop
    runs under ``dist.fault.run_resilient`` — an interrupted build (same
    process via the restart budget, or a fresh call pointed at the same
    directory) resumes at the last completed level and produces
    BIT-IDENTICAL output: the state is saved as raw bytes and every level
    is a deterministic function of it.  A checkpoint whose fingerprint
    (data size, tree shape, kernel, accuracy knobs, dtype) does not match
    is ignored, not trusted.

    Numerics: identical sampled blocks and IDs to ``compress`` — the same
    points reach the same seams in the same order, only the batch axis is
    tiled — so skeletons match exactly and ``counting_kernel_evals`` counts
    the same total (batching-independence is property-tested).

    ``x_perm`` should be host numpy in tree order (a jax array is gathered
    once).  Returns ``(HSSMatrix, StreamStats)``; with ``mesh`` the
    finished arrays are placed node-sharded so ``factorize_sharded``
    consumes them directly.
    """
    from repro import ckpt
    from repro.dist.fault import run_resilient

    n, m, K = tree.n, tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    if K == 0:
        raise ValueError("streamed build needs at least one tree level")
    x_host = (x_perm if isinstance(x_perm, np.ndarray)
              else np.asarray(jax.device_get(x_perm)))
    if x_host.shape[0] != n:
        raise ValueError(f"x has {x_host.shape[0]} rows, tree expects {n}")
    r0 = min(params.rank, m)
    adaptive, rtol = params.rtol is not None, params.rtol
    if stream.assemble not in ("device", "host"):
        raise ValueError(f"unknown assemble mode {stream.assemble!r}")

    far_idx = _host_proxy_indices(tree, params)          # host, per level
    leaf_near = _host_leaf_near(tree, params, x_host)
    prox0 = np.concatenate([leaf_near, far_idx[0]], axis=1)
    x_leaves = x_host.reshape(n_leaf, m, -1)
    stats = StreamStats()
    fp = _stream_fingerprint(n, m, K, spec, params, x_host.dtype)

    def _run_leaves(state: dict) -> dict:
        bsz = max(1, stream.batch_leaves)
        d_out = np.empty((n_leaf, m, m), x_host.dtype)
        u_out = np.empty((n_leaf, m, r0), x_host.dtype)
        skel_out = np.empty((n_leaf, r0), np.int32)
        rank_out = np.empty((n_leaf,), np.int32)
        for s in range(0, n_leaf, bsz):
            e = min(s + bsz, n_leaf)
            xl = jnp.asarray(x_leaves[s:e])
            xp = jnp.asarray(x_host[prox0[s:e]])
            d, u, piv, rks = _stream_leaf_batch(spec, xl, xp, r0, rtol,
                                                adaptive)
            stats.peak_stream_bytes = max(
                stats.peak_stream_bytes,
                _device_bytes(xl, xp, d, u, piv, rks))
            stats.n_batches += 1
            d_out[s:e] = jax.device_get(d)
            u_out[s:e] = jax.device_get(u)
            skel_out[s:e] = (np.asarray(jax.device_get(piv))
                             + np.arange(s, e, dtype=np.int32)[:, None] * m)
            rank_out[s:e] = jax.device_get(rks)
        state = dict(state)
        state.update(d_leaf=d_out, u_leaf=u_out, skel_leaf=skel_out,
                     ranks_leaf=rank_out)
        return state

    def _run_level(state: dict, k: int) -> dict:
        skel_prev = state["skel_leaf"] if k == 1 else state[f"skel_{k - 1}"]
        rank_prev = state["ranks_leaf"] if k == 1 else state[f"ranks_{k - 1}"]
        r_prev = skel_prev.shape[1]
        n_k = 2 ** (K - k)
        cand = skel_prev.reshape(n_k, 2 * r_prev)
        # Host-side candidate liveness, same rule as hss.rank_mask.
        cm_all = ((np.arange(r_prev)[None, :] < rank_prev[:, None])
                  .reshape(n_k, 2 * r_prev).astype(x_host.dtype))
        bsz = max(2, stream.batch_leaves - stream.batch_leaves % 2)
        state = dict(state)
        if k == K:                                       # root: B only
            cp = jnp.asarray(x_host[cand])
            cm = jnp.asarray(cm_all) if adaptive else None
            b = _stream_root_batch(spec, cp, cm, adaptive)
            stats.peak_stream_bytes = max(stats.peak_stream_bytes,
                                          _device_bytes(cp, b))
            stats.n_batches += 1
            state[f"b_{k}"] = np.asarray(jax.device_get(b))
            return state
        r_k = min(params.rank, 2 * r_prev)
        b_out = np.empty((n_k, r_prev, r_prev), x_host.dtype)
        t_out = np.empty((n_k, 2 * r_prev, r_k), x_host.dtype)
        skel_out = np.empty((n_k, r_k), np.int32)
        rank_out = np.empty((n_k,), np.int32)
        for s in range(0, n_k, bsz):
            e = min(s + bsz, n_k)                # n_k, bsz even -> e-s even
            cand_b = cand[s:e]
            # NEAR proxies: the sibling's candidates, exchanged batch-locally
            # (batches are even-aligned so both siblings are present).
            sib = cand_b.reshape(-1, 2, 2 * r_prev)[:, ::-1].reshape(
                e - s, 2 * r_prev)
            cp = jnp.asarray(x_host[cand_b])
            xp = jnp.asarray(np.concatenate(
                [x_host[sib], x_host[far_idx[k][s:e]]], axis=1))
            cm = jnp.asarray(cm_all[s:e]) if adaptive else None
            b, piv, t, rks = _stream_level_batch(spec, cp, xp, cm, r_k,
                                                 rtol, adaptive)
            stats.peak_stream_bytes = max(
                stats.peak_stream_bytes,
                _device_bytes(cp, xp, b, piv, t, rks))
            stats.n_batches += 1
            b_out[s:e] = jax.device_get(b)
            t_out[s:e] = jax.device_get(t)
            skel_out[s:e] = np.take_along_axis(
                cand_b, np.asarray(jax.device_get(piv)), axis=1)
            rank_out[s:e] = jax.device_get(rks)
        state.update({f"b_{k}": b_out, f"t_{k}": t_out,
                      f"skel_{k}": skel_out, f"ranks_{k}": rank_out})
        return state

    def _step(state: dict, i: int) -> dict:
        if on_level is not None:
            on_level(i)
        return _run_leaves(state) if i == 0 else _run_level(state, i)

    def _save(state: dict, completed: int) -> None:
        if stream.ckpt_dir is None:
            return
        ckpt.save_checkpoint(stream.ckpt_dir, state, completed, extra=fp)
        stats.checkpointed_levels = completed

    def _restore():
        if stream.ckpt_dir is None:
            return None
        step = ckpt.latest_step(stream.ckpt_dir)
        if step is None:
            return None
        arrays, got, extra = ckpt.load_checkpoint_arrays(
            stream.ckpt_dir, step)
        if {key: extra.get(key) for key in fp} != fp:
            return None                      # someone else's checkpoint
        stats.resumed_level = got
        return arrays, got

    state, report = run_resilient(
        K + 1, dict, _step, _save, _restore,
        ckpt_every=stream.ckpt_every_levels if stream.ckpt_dir else 0,
        max_restarts=stream.max_restarts)
    stats.restarts = report["restarts"]

    # ---------------- assembly ---------------- #
    if stream.assemble == "host" and mesh is None:
        def put(a):
            return a
        x_out = x_host
    elif mesh is None:
        put = jnp.asarray
        x_out = jnp.asarray(x_host)
    else:
        from jax.sharding import NamedSharding, PartitionSpec

        nodes, ndev = _mesh_nodes(mesh)

        def put(a):
            # compress_sharded-compatible placement: node-stacked arrays are
            # sharded along the node axis when it divides the device count,
            # tiny upper-tree arrays replicate; factorize_sharded re-pins
            # everything itself, so this only has to be a sane start.
            if a.ndim >= 1 and a.shape[0] > 1 and a.shape[0] % ndev == 0:
                p = PartitionSpec(nodes, *([None] * (a.ndim - 1)))
            else:
                p = PartitionSpec()
            return jax.device_put(a, NamedSharding(mesh, p))

        x_out = put(x_host)

    hss = HSSMatrix(
        x=x_out,
        d_leaf=put(state["d_leaf"]),
        u_leaf=put(state["u_leaf"]),
        skel_leaf=put(state["skel_leaf"]),
        transfers=tuple(put(state[f"t_{k}"]) for k in range(1, K)),
        skels=tuple(put(state[f"skel_{k}"]) for k in range(1, K)),
        b_mats=tuple(put(state[f"b_{k}"]) for k in range(1, K + 1)),
        levels=K,
        leaf_size=m,
        leaf_ranks=put(state["ranks_leaf"]) if adaptive else None,
        level_ranks=tuple(put(state[f"ranks_{k}"])
                          for k in range(1, K)) if adaptive else (),
    )
    return hss, stats


def compression_error(hss: HSSMatrix, spec: KernelSpec, n_probe: int = 8,
                      seed: int = 0) -> Array:
    """Stochastic relative Frobenius error ||K̃ - K||_F / ||K||_F via probes.

    Uses Hutchinson-style probing with the *streamed* exact kernel matvec, so
    it never materializes K — usable at large N as a compression diagnostic
    (paper eq. (9) ties this to the objective gap).
    """
    from repro.core.kernelfn import kernel_matvec_streamed

    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (hss.n, n_probe), hss.x.dtype)
    kv = kernel_matvec_streamed(spec, hss.x, hss.x, v)
    kv_hss = hss.matmat(v)
    return jnp.linalg.norm(kv_hss - kv) / jnp.maximum(jnp.linalg.norm(kv), 1e-30)
