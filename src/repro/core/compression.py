"""HSS-ANN-style compression of a kernel matrix, partially matrix-free.

Paper §3.1 / Chávez et al. IPDPS'20: instead of random sketching, use the
data geometry to pick the kernel entries that matter.  TPU adaptation
(DESIGN.md §3.2):

  * proxy columns per node = NEAR points (the sibling cluster — the ANN
    surrogate: boundary neighbours dominate the off-diagonal block's range)
    + FAR points (uniform sample of the complement) — index sets built once
    on the host;
  * skeleton selection per node = interpolative decomposition via pivoted QR
    on the sampled block (repro.core.idqr), vmapped over all nodes of a
    level;
  * total kernel evaluations O(N * n_proxy) — never the full matrix.

Construction cost O(r^2 N) and storage O(r N), matching the paper's claims
for HSS-ANN (§1.2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idqr
from repro.core.hss import HSSMatrix, rank_mask
from repro.core.kernelfn import KernelSpec, kernel_block
from repro.core.tree import ClusterTree

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionParams:
    """Accuracy knobs, analogous to the paper's STRUMPACK parameters.

    rtol      ~ rel_tol        (Table 4 "crude": 1e-2, Table 5 "accurate":
                1e-4) — the paper-facing accuracy knob.  None = legacy
                fixed-rank mode: every node stores the full ``rank`` columns.
                A float switches on the ADAPTIVE build: each node's numerical
                rank is detected from the pivoted-QR diagonal decay against
                rtol, truncated columns are exact zeros, and
                ``hss.shrink_to_fit`` can slice each level to its observed
                max rank.
    rank      ~ hss_max_rank   (Table 4: 200, Table 5: 2000 — here per
                level).  With rtol set this is only the CAP on the detected
                rank (STRUMPACK semantics); without it, the rank itself.
    n_near    ~ hss_approximate_neighbors (Table 4: 64, Table 5: 512)
    n_far     — far-field proxy sample size
    """

    rank: int = 32
    n_near: int = 32
    n_far: int = 32
    seed: int = 0
    rtol: float | None = None

    @property
    def n_proxy(self) -> int:
        return self.n_near + self.n_far

    @classmethod
    def crude(cls, **kw) -> "CompressionParams":
        """Paper Table 4 regime: loose tolerance, small cap/neighbourhoods."""
        return cls(**{**dict(rank=32, n_near=32, n_far=32, rtol=1e-2), **kw})

    @classmethod
    def accurate(cls, **kw) -> "CompressionParams":
        """Paper Table 5 regime: tight tolerance, larger cap/neighbourhoods."""
        return cls(**{**dict(rank=64, n_near=64, n_far=128, rtol=1e-4), **kw})


def kernel_eval_count(tree: ClusterTree, params: CompressionParams) -> int:
    """Exact number of kernel entries ``compress`` evaluates for this tree.

    The partially matrix-free build touches O(N · n_proxy) entries instead of
    N² — this counts them exactly (leaf diagonal blocks + leaf sampled
    blocks + per-level candidate×proxy blocks + B couplings), for the bench's
    perf trajectory.  Static per (tree, params): the adaptive build masks
    entries but the sampled block SHAPES are the rank cap, so adaptivity
    shows up in stored ranks and factor/solve cost, not here.
    """
    m, K = tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    r0 = min(params.rank, m)
    total = n_leaf * (m * m + m * params.n_proxy)
    r_prev = r0
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        total += n_k * r_prev * r_prev                  # sibling couplings B
        if k == K:
            break
        total += n_k * (2 * r_prev) * (2 * r_prev + params.n_far)
        r_prev = min(params.rank, 2 * r_prev)
    return total


def _cand_mask(ranks: Array, rp: int, dtype) -> Array:
    """(2·n,) child rank vector -> (n, 2·rp) candidate-slot liveness.

    One row per parent: the two children's ``hss.rank_mask`` rows side by
    side — shared by the local and sharded builds so the masking rule cannot
    drift between them.
    """
    return rank_mask(ranks, rp, dtype).reshape(-1, 2 * rp)


def _mask_b(b: Array, cm: Array, rp: int) -> Array:
    """Zero B rows/columns of dead child skeletons (exact structural zeros)."""
    return b * cm[:, :rp, None] * cm[:, rp:][:, None, :]


def _complement_sample(
    rng: np.random.Generator, n: int, span_start: int, span_width: int, count: int
) -> np.ndarray:
    """Uniform sample of indices in [0, n) \\ [span_start, span_start+width)."""
    u = rng.integers(0, n - span_width, size=count)
    return np.where(u < span_start, u, u + span_width).astype(np.int32)


def _host_proxy_indices(
    tree: ClusterTree, params: CompressionParams
) -> list[np.ndarray]:
    """Per-level FAR proxy index arrays: far[k] has shape (n_k, n_far)."""
    rng = np.random.default_rng(params.seed)
    n, m, K = tree.n, tree.leaf_size, tree.levels
    out = []
    for k in range(K):  # levels 0..K-1 need bases/skeletons
        n_k = 2 ** (K - k)
        width = m * 2 ** k
        rows = [
            _complement_sample(rng, n, node * width, width, params.n_far)
            for node in range(n_k)
        ]
        out.append(np.stack(rows, axis=0))
    return out


def _host_leaf_near(
    tree: ClusterTree, params: CompressionParams, x_perm: np.ndarray | None = None
) -> np.ndarray:
    """(n_leaf, n_near) NEAR-proxy indices per leaf.

    The paper's HSS-ANN strategy: the dominant entries of a leaf's
    off-diagonal block row correspond to its points' nearest neighbours in
    *other* clusters.  With data available we find them with a KD-tree
    (scipy) — the exact analogue of STRUMPACK's ANN preprocessing; without
    data we fall back to sampling the sibling leaf (tree-adjacent ≈ near).
    """
    rng = np.random.default_rng(params.seed + 1)
    m, K = tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    out = np.empty((n_leaf, params.n_near), dtype=np.int32)
    if x_perm is not None and n_leaf > 1:
        from scipy.spatial import cKDTree

        kdt = cKDTree(x_perm)
        k_query = min(max(2 * params.n_near // m + 4, 4), tree.n)
        _, nbr = kdt.query(x_perm, k=k_query)   # (n, k) incl. self
        leaf_of = np.arange(tree.n) // m
        # Vectorized over ALL leaves at once (the per-leaf Python loop was
        # the host-preprocessing serial bottleneck at large n_leaf): each
        # leaf's candidate pool is its points' neighbour lists, flattened.
        cand = nbr.reshape(n_leaf, m * k_query).astype(np.int64)
        own = leaf_of[cand] == np.arange(n_leaf)[:, None]   # in-leaf -> drop
        # Duplicate suppression without per-row np.unique: sort ids per row,
        # mark repeats, scatter the mask back to original positions.
        order = np.argsort(cand, axis=1, kind="stable")
        sorted_ids = np.take_along_axis(cand, order, axis=1)
        dup_sorted = np.zeros_like(own)
        dup_sorted[:, 1:] = sorted_ids[:, 1:] == sorted_ids[:, :-1]
        dup = np.zeros_like(own)
        np.put_along_axis(dup, order, dup_sorted, axis=1)
        invalid = own | dup
        # Rank candidates by distance to the leaf centroid; invalid -> +inf.
        centroid = x_perm.reshape(n_leaf, m, -1).mean(axis=1)
        dist = np.linalg.norm(
            x_perm[cand] - centroid[:, None, :], axis=2)
        dist[invalid] = np.inf
        pick = np.argsort(dist, axis=1, kind="stable")[:, : params.n_near]
        out[:] = np.take_along_axis(cand, pick, axis=1)
        # Deficit rows (candidate pool smaller than n_near — tiny problems
        # only): top up from the sibling leaf, as in the data-free fallback.
        counts = (~invalid).sum(axis=1)
        for i in np.nonzero(counts < params.n_near)[0]:
            short = params.n_near - int(counts[i])
            sib = int(i) ^ 1
            fill = rng.choice(m, size=short, replace=short > m) + sib * m
            out[i, int(counts[i]):] = fill
        return out
    for i in range(n_leaf):
        sib = i ^ 1
        out[i] = rng.choice(m, size=params.n_near, replace=params.n_near > m) + sib * m
    return out


def compress(
    x_perm: Array,
    tree: ClusterTree,
    spec: KernelSpec,
    params: CompressionParams = CompressionParams(),
) -> HSSMatrix:
    """Build the HSS approximation of K(x_perm, x_perm).

    ``x_perm`` must already be in tree (leaf-major) order:
    ``x_perm = x[tree.perm]``.
    """
    n, m, K = tree.n, tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    if x_perm.shape[0] != n:
        raise ValueError(f"x has {x_perm.shape[0]} rows, tree expects {n}")
    r0 = min(params.rank, m)
    adaptive, rtol = params.rtol is not None, params.rtol

    far_idx = [jnp.asarray(a) for a in _host_proxy_indices(tree, params)]
    x_host = np.asarray(jax.device_get(x_perm))
    leaf_near = jnp.asarray(_host_leaf_near(tree, params, x_host))

    x_leaves = x_perm.reshape(n_leaf, m, -1)

    # ---------------- leaves ---------------- #
    d_leaf = jax.vmap(lambda xa: kernel_block(spec, xa, xa))(x_leaves)

    def leaf_basis(xa: Array, prox_idx: Array, leaf_start: Array):
        xp = jnp.take(x_perm, prox_idx, axis=0)
        a = kernel_block(spec, xa, xp)            # (m, n_proxy)
        if adaptive:
            piv, p_mat, rk = idqr.row_interp_decomp_ranked(a, r0, rtol)
        else:
            piv, p_mat = idqr.row_interp_decomp(a, r0)
            rk = jnp.int32(r0)
        return p_mat, leaf_start + piv.astype(jnp.int32), rk

    leaf_starts = jnp.arange(n_leaf, dtype=jnp.int32) * m
    prox0 = jnp.concatenate([leaf_near, far_idx[0]], axis=1)
    u_leaf, skel_leaf, leaf_ranks = jax.vmap(leaf_basis)(
        x_leaves, prox0, leaf_starts)

    # ---------------- internal levels ---------------- #
    transfers: list[Array] = []
    skels: list[Array] = []
    b_mats: list[Array] = []
    level_ranks: list[Array] = []
    skel_prev = skel_leaf                     # (n_{k-1}, r_{k-1})
    rank_prev = leaf_ranks                    # (n_{k-1},) numerical ranks
    r_prev = r0
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        cand = skel_prev.reshape(n_k, 2 * r_prev)      # children skeleton ids
        # Liveness of each candidate slot under the children's detected ranks
        # (all-ones in fixed-rank mode).
        cmask = _cand_mask(rank_prev, r_prev, x_perm.dtype)
        # B couplings: K(skel_c1, skel_c2) — pure kernel evals.  Dead
        # skeleton rows/columns are masked to exact zeros so the truncation
        # is structural (factorization decouples them; shrink slices them).
        xa = jnp.take(x_perm, cand[:, :r_prev], axis=0)
        xb = jnp.take(x_perm, cand[:, r_prev:], axis=0)
        b_k = jax.vmap(lambda a, b: kernel_block(spec, a, b))(xa, xb)
        if adaptive:
            b_k = _mask_b(b_k, cmask, r_prev)
        b_mats.append(b_k)
        if k == K:
            break
        r_k = min(params.rank, 2 * r_prev)
        # NEAR proxies: the sibling node's candidate skeletons (dynamic).
        sib = cand.reshape(n_k // 2, 2, 2 * r_prev)[:, ::-1, :].reshape(n_k, 2 * r_prev)
        prox = jnp.concatenate([sib, far_idx[k]], axis=1)

        def node_basis(cand_i: Array, prox_i: Array, cmask_i: Array):
            xc = jnp.take(x_perm, cand_i, axis=0)
            xp = jnp.take(x_perm, prox_i, axis=0)
            a = kernel_block(spec, xc, xp)             # (2 r_prev, n_prox)
            if adaptive:
                # Zero dead candidate rows: skeleton propagation only ever
                # forwards LIVE child skeleton points (dead rows get zero
                # interpolation weights and sort behind every live pivot).
                a = a * cmask_i[:, None]
                piv, p_mat, rk = idqr.row_interp_decomp_ranked(a, r_k, rtol)
            else:
                piv, p_mat = idqr.row_interp_decomp(a, r_k)
                rk = jnp.int32(r_k)
            return p_mat, jnp.take(cand_i, piv), rk

        t_k, skel_k, rank_k = jax.vmap(node_basis)(cand, prox, cmask)
        transfers.append(t_k)
        skels.append(skel_k)
        level_ranks.append(rank_k)
        skel_prev, rank_prev, r_prev = skel_k, rank_k, r_k

    return HSSMatrix(
        x=x_perm,
        d_leaf=d_leaf,
        u_leaf=u_leaf,
        skel_leaf=skel_leaf,
        transfers=tuple(transfers),
        skels=tuple(skels),
        b_mats=tuple(b_mats),
        levels=K,
        leaf_size=m,
        leaf_ranks=leaf_ranks if adaptive else None,
        level_ranks=tuple(level_ranks) if adaptive else (),
    )


def _mesh_nodes(mesh) -> tuple[tuple[str, ...], int]:
    """All mesh axes combined into one logical node axis, + device count."""
    nodes = tuple(mesh.axis_names)
    ndev = 1
    for a in nodes:
        ndev *= mesh.shape[a]
    return nodes, ndev


def compress_sharded(
    x_perm,
    tree: ClusterTree,
    spec: KernelSpec,
    params: CompressionParams = CompressionParams(),
    mesh=None,
) -> HSSMatrix:
    """Mesh-parallel HSS build: every stage node-sharded from the start.

    The single-device ``compress`` materializes every per-level array on one
    device — the O(N m) leaf blocks alone exceed a single device's HBM at the
    paper's Table-1 scales.  Here the leaf axis is sharded over ALL mesh
    devices end-to-end:

      * host preprocessing gathers each leaf's proxy *points* (near + far,
        O(n_leaf * n_proxy * f)) so no device-side global gather over the
        full dataset is ever needed;
      * the leaf stage (diagonal blocks, ID-QR bases, skeleton selection)
        runs under ``shard_map`` with n_leaf/ndev leaves per device;
      * each level transition carries only the skeleton POINTS
        (n_k, r_k, f) and their global ids upward — O(r n_k) per level, the
        distributed-memory HSS-ANN communication pattern (STRUMPACK §3.1);
      * a level degrades to replicated (one all-gather of the skeleton
        points, after which every device redundantly computes the tiny
        upper-tree arrays) exactly when its node count stops being evenly
        pair-shardable — the same fallback rule as
        ``distributed.fac_shardings``.

    ``x_perm`` may be a host numpy array (preferred — it is needed on the
    host for KD-tree preprocessing anyway) or a jax array.  Requires
    ``tree.n_leaves % n_devices == 0``; otherwise falls back to the local
    build (the result is then unsharded).  Numerically this computes the
    same interpolative decompositions on the same sampled blocks as
    ``compress`` (parity-tested to <=1e-5 in tests/test_engine.py).
    """
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.dist.api import shard_map

    n, m, K = tree.n, tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    x_host = np.asarray(jax.device_get(x_perm), np.float32)
    if x_host.shape[0] != n:
        raise ValueError(f"x has {x_host.shape[0]} rows, tree expects {n}")
    nodes, ndev = _mesh_nodes(mesh)
    if K == 0 or n_leaf % ndev != 0:
        return compress(jnp.asarray(x_host), tree, spec, params)

    r0 = min(params.rank, m)
    adaptive, rtol = params.rtol is not None, params.rtol
    p_nodes = PartitionSpec(nodes)
    sh_nodes = NamedSharding(mesh, p_nodes)
    sh_repl = NamedSharding(mesh, PartitionSpec())

    far_idx = _host_proxy_indices(tree, params)
    leaf_near = _host_leaf_near(tree, params, x_host)
    prox0 = np.concatenate([leaf_near, far_idx[0]], axis=1)

    x_leaves = jax.device_put(x_host.reshape(n_leaf, m, -1), sh_nodes)
    x_prox0 = jax.device_put(x_host[prox0], sh_nodes)   # (n_leaf, n_proxy, f)
    leaf_starts = jax.device_put(
        np.arange(n_leaf, dtype=np.int32) * m, sh_nodes)

    # ---------------- leaves (shard_map over the node axis) ------------- #
    def _leaf_stage(xl, xp, starts):
        d = jax.vmap(lambda xa: kernel_block(spec, xa, xa))(xl)

        def one(xa, xpi, s):
            a = kernel_block(spec, xa, xpi)            # (m, n_proxy)
            if adaptive:
                piv, p_mat, rk = idqr.row_interp_decomp_ranked(a, r0, rtol)
            else:
                piv, p_mat = idqr.row_interp_decomp(a, r0)
                rk = jnp.int32(r0)
            piv = piv.astype(jnp.int32)
            return p_mat, s + piv, jnp.take(xa, piv, axis=0), rk

        u, skel, spts, rks = jax.vmap(one)(xl, xp, starts)
        return d, u, skel, spts, rks

    leaf_fn = jax.jit(shard_map(
        _leaf_stage, mesh,
        in_specs=(p_nodes, p_nodes, p_nodes),
        out_specs=(p_nodes,) * 5))
    d_leaf, u_leaf, skel_leaf, spts, leaf_ranks = leaf_fn(
        x_leaves, x_prox0, leaf_starts)
    sids, sranks = skel_leaf, leaf_ranks

    # ---------------- internal levels ---------------- #
    transfers: list[Array] = []
    skels: list[Array] = []
    b_mats: list[Array] = []
    level_ranks: list[Array] = []
    r_prev = r0
    sharded = True
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        # Pair-shardable: parents divide the devices AND each device holds
        # an even number of parents so the sibling-NEAR exchange is local.
        want = (sharded and n_k % ndev == 0
                and (k == K or (n_k // ndev) % 2 == 0))
        if sharded and not want:
            # Degradation point: one all-gather of the skeleton points/ids/
            # ranks (O(r * n_k) — the only cross-device traffic of the
            # upper tree).
            spts = jax.device_put(spts, sh_repl)
            sids = jax.device_put(sids, sh_repl)
            sranks = jax.device_put(sranks, sh_repl)
            sharded = False
        r_k = min(params.rank, 2 * r_prev)

        if sharded:
            loc = n_k // ndev
            rp, rk = r_prev, r_k
            if k == K:
                def _b_only(sp, sr):
                    cp = sp.reshape(loc, 2 * rp, sp.shape[-1])
                    b = jax.vmap(
                        lambda c: kernel_block(spec, c[:rp], c[rp:]))(cp)
                    if adaptive:
                        b = _mask_b(b, _cand_mask(sr, rp, b.dtype), rp)
                    return b

                b_fn = jax.jit(shard_map(
                    _b_only, mesh, in_specs=(p_nodes, p_nodes),
                    out_specs=p_nodes))
                b_mats.append(b_fn(spts, sranks))
                break

            far_pts = jax.device_put(x_host[far_idx[k]], sh_nodes)

            def _level(sp, si, sr, fp):
                f = sp.shape[-1]
                cp = sp.reshape(loc, 2 * rp, f)
                ci = si.reshape(loc, 2 * rp)
                cm = _cand_mask(sr, rp, sp.dtype)
                b = jax.vmap(
                    lambda c: kernel_block(spec, c[:rp], c[rp:]))(cp)
                if adaptive:
                    b = _mask_b(b, cm, rp)
                sib = cp.reshape(loc // 2, 2, 2 * rp, f)[:, ::-1]
                sib = sib.reshape(loc, 2 * rp, f)

                def node_basis(cp_i, ci_i, cm_i, sp_i, fp_i):
                    xp_ = jnp.concatenate([sp_i, fp_i], axis=0)
                    a = kernel_block(spec, cp_i, xp_)
                    if adaptive:
                        a = a * cm_i[:, None]
                        piv, p_mat, rk_i = idqr.row_interp_decomp_ranked(
                            a, rk, rtol)
                    else:
                        piv, p_mat = idqr.row_interp_decomp(a, rk)
                        rk_i = jnp.int32(rk)
                    return (p_mat, jnp.take(ci_i, piv),
                            jnp.take(cp_i, piv, axis=0), rk_i)

                t, ids, pts, rks = jax.vmap(node_basis)(cp, ci, cm, sib, fp)
                return b, t, ids, pts, rks

            lvl_fn = jax.jit(shard_map(
                _level, mesh,
                in_specs=(p_nodes,) * 4,
                out_specs=(p_nodes,) * 5))
            b_k, t_k, sids, spts, sranks = lvl_fn(spts, sids, sranks, far_pts)
            b_mats.append(b_k)
            transfers.append(t_k)
            skels.append(sids)
            level_ranks.append(sranks)
        else:
            # Replicated upper tree: same math, every device computes it.
            f = spts.shape[-1]
            cand_pts = spts.reshape(n_k, 2 * r_prev, f)
            cand_ids = sids.reshape(n_k, 2 * r_prev)
            cmask = _cand_mask(sranks, r_prev, spts.dtype)
            b_k = jax.vmap(
                lambda c: kernel_block(spec, c[:r_prev], c[r_prev:])
            )(cand_pts)
            if adaptive:
                b_k = _mask_b(b_k, cmask, r_prev)
            b_mats.append(b_k)
            if k == K:
                break
            sib = cand_pts.reshape(n_k // 2, 2, 2 * r_prev, f)[:, ::-1]
            sib = sib.reshape(n_k, 2 * r_prev, f)
            far_pts = jax.device_put(x_host[far_idx[k]], sh_repl)

            def node_basis(cp_i, ci_i, cm_i, sp_i, fp_i):
                xp_ = jnp.concatenate([sp_i, fp_i], axis=0)
                a = kernel_block(spec, cp_i, xp_)
                if adaptive:
                    a = a * cm_i[:, None]
                    piv, p_mat, rk_i = idqr.row_interp_decomp_ranked(
                        a, r_k, rtol)
                else:
                    piv, p_mat = idqr.row_interp_decomp(a, r_k)
                    rk_i = jnp.int32(r_k)
                return (p_mat, jnp.take(ci_i, piv),
                        jnp.take(cp_i, piv, axis=0), rk_i)

            t_k, sids, spts, sranks = jax.vmap(node_basis)(
                cand_pts, cand_ids, cmask, sib, far_pts)
            transfers.append(t_k)
            skels.append(sids)
            level_ranks.append(sranks)
        r_prev = r_k

    return HSSMatrix(
        x=jax.device_put(x_host, sh_nodes),
        d_leaf=d_leaf,
        u_leaf=u_leaf,
        skel_leaf=skel_leaf,
        transfers=tuple(transfers),
        skels=tuple(skels),
        b_mats=tuple(b_mats),
        levels=K,
        leaf_size=m,
        leaf_ranks=leaf_ranks if adaptive else None,
        level_ranks=tuple(level_ranks) if adaptive else (),
    )


def compression_error(hss: HSSMatrix, spec: KernelSpec, n_probe: int = 8,
                      seed: int = 0) -> Array:
    """Stochastic relative Frobenius error ||K̃ - K||_F / ||K||_F via probes.

    Uses Hutchinson-style probing with the *streamed* exact kernel matvec, so
    it never materializes K — usable at large N as a compression diagnostic
    (paper eq. (9) ties this to the objective gap).
    """
    from repro.core.kernelfn import kernel_matvec_streamed

    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (hss.n, n_probe), hss.x.dtype)
    kv = kernel_matvec_streamed(spec, hss.x, hss.x, v)
    kv_hss = hss.matmat(v)
    return jnp.linalg.norm(kv_hss - kv) / jnp.maximum(jnp.linalg.norm(kv), 1e-30)
