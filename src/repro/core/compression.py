"""HSS-ANN-style compression of a kernel matrix, partially matrix-free.

Paper §3.1 / Chávez et al. IPDPS'20: instead of random sketching, use the
data geometry to pick the kernel entries that matter.  TPU adaptation
(DESIGN.md §3.2):

  * proxy columns per node = NEAR points (the sibling cluster — the ANN
    surrogate: boundary neighbours dominate the off-diagonal block's range)
    + FAR points (uniform sample of the complement) — index sets built once
    on the host;
  * skeleton selection per node = interpolative decomposition via pivoted QR
    on the sampled block (repro.core.idqr), vmapped over all nodes of a
    level;
  * total kernel evaluations O(N * n_proxy) — never the full matrix.

Construction cost O(r^2 N) and storage O(r N), matching the paper's claims
for HSS-ANN (§1.2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import idqr
from repro.core.hss import HSSMatrix
from repro.core.kernelfn import KernelSpec, kernel_block
from repro.core.tree import ClusterTree

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CompressionParams:
    """Accuracy knobs, analogous to the paper's STRUMPACK parameters.

    rank      ~ hss_max_rank  (Table 4: 200, Table 5: 2000 — here per level)
    n_near    ~ hss_approximate_neighbors (Table 4: 64, Table 5: 512)
    n_far     — far-field proxy sample size
    """

    rank: int = 32
    n_near: int = 32
    n_far: int = 32
    seed: int = 0

    @property
    def n_proxy(self) -> int:
        return self.n_near + self.n_far


def _complement_sample(
    rng: np.random.Generator, n: int, span_start: int, span_width: int, count: int
) -> np.ndarray:
    """Uniform sample of indices in [0, n) \\ [span_start, span_start+width)."""
    u = rng.integers(0, n - span_width, size=count)
    return np.where(u < span_start, u, u + span_width).astype(np.int32)


def _host_proxy_indices(
    tree: ClusterTree, params: CompressionParams
) -> list[np.ndarray]:
    """Per-level FAR proxy index arrays: far[k] has shape (n_k, n_far)."""
    rng = np.random.default_rng(params.seed)
    n, m, K = tree.n, tree.leaf_size, tree.levels
    out = []
    for k in range(K):  # levels 0..K-1 need bases/skeletons
        n_k = 2 ** (K - k)
        width = m * 2 ** k
        rows = [
            _complement_sample(rng, n, node * width, width, params.n_far)
            for node in range(n_k)
        ]
        out.append(np.stack(rows, axis=0))
    return out


def _host_leaf_near(
    tree: ClusterTree, params: CompressionParams, x_perm: np.ndarray | None = None
) -> np.ndarray:
    """(n_leaf, n_near) NEAR-proxy indices per leaf.

    The paper's HSS-ANN strategy: the dominant entries of a leaf's
    off-diagonal block row correspond to its points' nearest neighbours in
    *other* clusters.  With data available we find them with a KD-tree
    (scipy) — the exact analogue of STRUMPACK's ANN preprocessing; without
    data we fall back to sampling the sibling leaf (tree-adjacent ≈ near).
    """
    rng = np.random.default_rng(params.seed + 1)
    m, K = tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    out = np.empty((n_leaf, params.n_near), dtype=np.int32)
    if x_perm is not None and n_leaf > 1:
        from scipy.spatial import cKDTree

        kdt = cKDTree(x_perm)
        k_query = min(max(2 * params.n_near // m + 4, 4), tree.n)
        _, nbr = kdt.query(x_perm, k=k_query)   # (n, k) incl. self
        leaf_of = np.arange(tree.n) // m
        for i in range(n_leaf):
            cand = nbr[i * m:(i + 1) * m].reshape(-1)
            cand = np.unique(cand[leaf_of[cand] != i])
            if len(cand) >= params.n_near:
                # keep the closest ones to the leaf (by distance to leaf points)
                d = np.linalg.norm(
                    x_perm[cand] - x_perm[i * m:(i + 1) * m].mean(0), axis=1
                )
                cand = cand[np.argsort(d)[: params.n_near]]
                out[i] = cand
            else:
                sib = i ^ 1
                fill = rng.choice(m, size=params.n_near - len(cand),
                                  replace=(params.n_near - len(cand)) > m) + sib * m
                out[i] = np.concatenate([cand, fill]).astype(np.int32)
        return out
    for i in range(n_leaf):
        sib = i ^ 1
        out[i] = rng.choice(m, size=params.n_near, replace=params.n_near > m) + sib * m
    return out


def compress(
    x_perm: Array,
    tree: ClusterTree,
    spec: KernelSpec,
    params: CompressionParams = CompressionParams(),
) -> HSSMatrix:
    """Build the HSS approximation of K(x_perm, x_perm).

    ``x_perm`` must already be in tree (leaf-major) order:
    ``x_perm = x[tree.perm]``.
    """
    n, m, K = tree.n, tree.leaf_size, tree.levels
    n_leaf = 2 ** K
    if x_perm.shape[0] != n:
        raise ValueError(f"x has {x_perm.shape[0]} rows, tree expects {n}")
    r0 = min(params.rank, m)

    far_idx = [jnp.asarray(a) for a in _host_proxy_indices(tree, params)]
    x_host = np.asarray(jax.device_get(x_perm))
    leaf_near = jnp.asarray(_host_leaf_near(tree, params, x_host))

    x_leaves = x_perm.reshape(n_leaf, m, -1)

    # ---------------- leaves ---------------- #
    d_leaf = jax.vmap(lambda xa: kernel_block(spec, xa, xa))(x_leaves)

    def leaf_basis(xa: Array, prox_idx: Array, leaf_start: Array):
        xp = jnp.take(x_perm, prox_idx, axis=0)
        a = kernel_block(spec, xa, xp)            # (m, n_proxy)
        piv, p_mat = idqr.row_interp_decomp(a, r0)
        return p_mat, leaf_start + piv.astype(jnp.int32)

    leaf_starts = jnp.arange(n_leaf, dtype=jnp.int32) * m
    prox0 = jnp.concatenate([leaf_near, far_idx[0]], axis=1)
    u_leaf, skel_leaf = jax.vmap(leaf_basis)(x_leaves, prox0, leaf_starts)

    # ---------------- internal levels ---------------- #
    transfers: list[Array] = []
    skels: list[Array] = []
    b_mats: list[Array] = []
    skel_prev = skel_leaf                     # (n_{k-1}, r_{k-1})
    r_prev = r0
    for k in range(1, K + 1):
        n_k = 2 ** (K - k)
        cand = skel_prev.reshape(n_k, 2 * r_prev)      # children skeleton ids
        # B couplings: K(skel_c1, skel_c2) — pure kernel evals.
        xa = jnp.take(x_perm, cand[:, :r_prev], axis=0)
        xb = jnp.take(x_perm, cand[:, r_prev:], axis=0)
        b_mats.append(jax.vmap(lambda a, b: kernel_block(spec, a, b))(xa, xb))
        if k == K:
            break
        r_k = min(params.rank, 2 * r_prev)
        # NEAR proxies: the sibling node's candidate skeletons (dynamic).
        sib = cand.reshape(n_k // 2, 2, 2 * r_prev)[:, ::-1, :].reshape(n_k, 2 * r_prev)
        prox = jnp.concatenate([sib, far_idx[k]], axis=1)

        def node_basis(cand_i: Array, prox_i: Array):
            xc = jnp.take(x_perm, cand_i, axis=0)
            xp = jnp.take(x_perm, prox_i, axis=0)
            a = kernel_block(spec, xc, xp)             # (2 r_prev, n_prox)
            piv, p_mat = idqr.row_interp_decomp(a, r_k)
            return p_mat, jnp.take(cand_i, piv)

        t_k, skel_k = jax.vmap(node_basis)(cand, prox)
        transfers.append(t_k)
        skels.append(skel_k)
        skel_prev, r_prev = skel_k, r_k

    return HSSMatrix(
        x=x_perm,
        d_leaf=d_leaf,
        u_leaf=u_leaf,
        skel_leaf=skel_leaf,
        transfers=tuple(transfers),
        skels=tuple(skels),
        b_mats=tuple(b_mats),
        levels=K,
        leaf_size=m,
    )


def compression_error(hss: HSSMatrix, spec: KernelSpec, n_probe: int = 8,
                      seed: int = 0) -> Array:
    """Stochastic relative Frobenius error ||K̃ - K||_F / ||K||_F via probes.

    Uses Hutchinson-style probing with the *streamed* exact kernel matvec, so
    it never materializes K — usable at large N as a compression diagnostic
    (paper eq. (9) ties this to the objective gap).
    """
    from repro.core.kernelfn import kernel_matvec_streamed

    key = jax.random.PRNGKey(seed)
    v = jax.random.normal(key, (hss.n, n_probe), hss.x.dtype)
    kv = kernel_matvec_streamed(spec, hss.x, hss.x, v)
    kv_hss = hss.matmat(v)
    return jnp.linalg.norm(kv_hss - kv) / jnp.maximum(jnp.linalg.norm(kv), 1e-30)
