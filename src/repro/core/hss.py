"""Hierarchically Semi-Separable matrix container + telescoping apply.

Skeleton (interpolative) form, symmetric kernel case (paper §3.1, following
Chávez et al. "HSS-ANN"):

  - leaf diagonal blocks D_i = K(X_i, X_i)                       (dense, exact)
  - leaf bases U_i (m, r0): interpolation onto r0 skeleton points per leaf,
    U_i[skel rows] = I
  - per internal level k: transfer matrices P (2 r_{k-1}, r_k) stacking the
    children transfers [R_c1; R_c2], and skeleton indices (global point ids)
  - sibling couplings B at level k: B_p = K(X[skel_c1], X[skel_c2])
    — *pure kernel evaluations between skeleton points*, which is what makes
    the construction partially matrix-free (no dense off-diagonal block is
    ever formed at any level).

Level indexing: k = 0 are the leaves, k = K = tree.levels is the root.
Level k has n_k = 2**(K-k) nodes. Arrays are stacked over nodes per level so
every HSS operation is a batch of small dense ops (vmapped → MXU-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


def rank_mask(ranks: Array, cap: int, dtype=jnp.float32) -> Array:
    """(n,) per-node rank vector -> (n, cap) skeleton-liveness mask.

    1.0 on live slots (j < rank), 0.0 on truncated ones.  THE one definition
    of liveness: the build (compression), the representation
    (``HSSMatrix.rank_masks``) and the factorization all defer here so the
    structural-zero invariant can never drift between layers.  Works inside
    jit/shard_map (pure jnp ops on the traced rank vector).
    """
    return (jnp.arange(cap)[None, :] < ranks[:, None]).astype(dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HSSMatrix:
    """Symmetric HSS approximation of a kernel matrix over permuted points."""

    x: Array           # (N, f)  permuted data points (needed for predict/bias)
    d_leaf: Array      # (n_leaf, m, m)
    u_leaf: Array      # (n_leaf, m, r0)
    skel_leaf: Array   # (n_leaf, r0) int32 — global permuted-space indices
    # tuple over k = 1..K-1 (empty when K <= 1):
    transfers: tuple[Array, ...]   # (n_k, 2*r_{k-1}, r_k)
    skels: tuple[Array, ...]       # (n_k, r_k) int32
    # tuple over k = 1..K: sibling couplings, (n_k, r_{k-1}, r_{k-1})
    b_mats: tuple[Array, ...]
    levels: int = dataclasses.field(metadata=dict(static=True))
    leaf_size: int = dataclasses.field(metadata=dict(static=True))
    # Adaptive-rank (tolerance-driven) builds only; None/() = fixed-rank.
    # Per-node NUMERICAL ranks detected by the pivoted-QR tolerance: columns
    # ≥ rank of that node's u_leaf/transfer block are exactly zero, as are
    # the b_mats rows/columns of its dead skeletons — the per-level array
    # shapes stay static at the rank cap, the masks carry the adaptivity.
    leaf_ranks: Array | None = None          # (n_leaf,) int32
    level_ranks: tuple[Array, ...] = ()      # per k=1..K-1: (n_k,) int32

    @property
    def n(self) -> int:
        return self.d_leaf.shape[0] * self.leaf_size

    @property
    def n_leaves(self) -> int:
        return self.d_leaf.shape[0]

    @property
    def ranks(self) -> list[int]:
        """Per-level STORED rank caps (array column counts), k = 0..K-1."""
        r = [self.u_leaf.shape[-1]]
        for t in self.transfers:
            r.append(t.shape[-1])
        return r

    @property
    def adaptive(self) -> bool:
        return self.leaf_ranks is not None

    def observed_ranks(self) -> list[int]:
        """Per-level max NUMERICAL rank over the level's nodes.

        Equals ``ranks`` for fixed-rank builds; for adaptive builds this is
        what ``shrink_to_fit`` slices each level down to.  Host sync.
        """
        if not self.adaptive:
            return self.ranks
        import numpy as np

        # ONE batched host transfer for all K rank vectors: this runs on
        # every shrink_report (i.e. every train), and per-level device_get
        # calls would serialize K+1 blocking round-trips.
        host = jax.device_get((self.leaf_ranks, *self.level_ranks))
        return [int(np.max(np.asarray(r))) for r in host]

    def stored_rank_sum(self) -> int:
        """Σ_levels n_k · (stored rank cap): the paper's O(N r) storage knob
        in units of skeleton slots — decreases under shrink_to_fit."""
        return sum(r * (self.n_leaves >> k) for k, r in enumerate(self.ranks))

    def shifted(self, beta: float) -> "HSSMatrix":
        """K̃ + beta I (shift lives on the leaf diagonal blocks only)."""
        m = self.leaf_size
        eye = jnp.eye(m, dtype=self.d_leaf.dtype)
        return dataclasses.replace(self, d_leaf=self.d_leaf + beta * eye)

    # ------------------------------------------------------------------ #
    # telescoping matvec / matmat                                        #
    # ------------------------------------------------------------------ #
    def matvec(self, v: Array) -> Array:
        """K̃ @ v in O(N r) — single-RHS view of the native matmat sweep."""
        return self.matmat(v[:, None])[:, 0]

    def matmat(self, v: Array) -> Array:
        """K̃ @ V for V (N, c) — ONE telescoping sweep over the RHS block.

        The RHS columns ride along as a trailing axis of every per-level
        einsum (no ``jax.vmap`` over single-RHS sweeps), so the k per-class
        vectors of a multiclass problem cost one pass over the HSS factors
        instead of k.

        All contractions accumulate in f32 (``preferred_element_type``) so a
        bf16-stored representation still produces f32-quality sweeps.

        Under an active ``repro.dist.api.use_mesh`` every per-level
        intermediate is pinned to the node-sharded/replicated layout of
        ``distributed.fac_shardings`` (``constrain_nodes``) — the pair/unpair
        reshapes then lower to the same per-level collective schedule as the
        distributed solve, and the sweep stays correct under SPMD
        partitioning.
        """
        from repro.dist.api import constrain_nodes

        K = self.levels
        n_leaf, m = self.n_leaves, self.leaf_size
        c = v.shape[1]
        f32 = jnp.float32
        vl = v.reshape(n_leaf, m, c)
        diag = jnp.einsum("nab,nbc->nac", self.d_leaf, vl,
                          preferred_element_type=f32)
        if K == 0:
            return diag.reshape(-1, c)

        # Upward: project into skeleton coordinates at every level.
        vt = [constrain_nodes(
            jnp.einsum("nmr,nmc->nrc", self.u_leaf, vl,
                       preferred_element_type=f32))]        # (n_leaf, r0, c)
        for k in range(1, K):
            t = self.transfers[k - 1]                       # (n_k, 2 r_{k-1}, r_k)
            prev = vt[-1].reshape(t.shape[0], t.shape[1], c)  # pair children
            vt.append(constrain_nodes(
                jnp.einsum("nsr,nsc->nrc", t, prev,
                           preferred_element_type=f32)))

        # Downward: accumulate incoming far-field per node, top level first.
        w = None
        for k in range(K, 0, -1):
            b = self.b_mats[k - 1]                          # (n_k, r_{k-1}, r_{k-1})
            pair = vt[k - 1].reshape(b.shape[0], 2, b.shape[1], c)
            coup = jnp.stack(
                [
                    jnp.einsum("nij,njc->nic", b, pair[:, 1],
                               preferred_element_type=f32),
                    jnp.einsum("nji,njc->nic", b, pair[:, 0],
                               preferred_element_type=f32),
                ],
                axis=1,
            )                                               # (n_k, 2, r_{k-1}, c)
            if w is not None:
                t = self.transfers[k - 1]
                down = jnp.einsum("nsr,nrc->nsc", t, w,
                                  preferred_element_type=f32)
                coup = coup + down.reshape(coup.shape)      # (n_k, 2 r_{k-1}, c)
            w = constrain_nodes(
                coup.reshape(-1, coup.shape[-2], c))        # (n_{k-1}, r_{k-1}, c)

        out = diag + jnp.einsum("nmr,nrc->nmc", self.u_leaf, w,
                                preferred_element_type=f32)
        return out.reshape(-1, c)

    # ------------------------------------------------------------------ #
    # dense reconstruction (tests / small problems only)                 #
    # ------------------------------------------------------------------ #
    def todense(self) -> Array:
        K = self.levels
        n_leaf, m = self.n_leaves, self.leaf_size
        n = self.n
        out = jnp.zeros((n, n), self.d_leaf.dtype)
        for i in range(n_leaf):
            out = out.at[i * m:(i + 1) * m, i * m:(i + 1) * m].set(self.d_leaf[i])
        # Expanded bases per level: Ubig[k] maps skeleton coords -> full span.
        ubig = [self.u_leaf[i] for i in range(n_leaf)]
        for k in range(1, K + 1):
            b = self.b_mats[k - 1]
            n_k = b.shape[0]
            width = m * 2 ** (k - 1)
            for p in range(n_k):
                ua, ub_ = ubig[2 * p], ubig[2 * p + 1]
                blk = ua @ b[p] @ ub_.T
                r0 = 2 * p * width
                c0 = (2 * p + 1) * width
                out = out.at[r0:r0 + width, c0:c0 + width].set(blk)
                out = out.at[c0:c0 + width, r0:r0 + width].set(blk.T)
            if k < K:
                t = self.transfers[k - 1]
                nxt = []
                for p in range(n_k):
                    rc = t.shape[1] // 2
                    top = ubig[2 * p] @ t[p, :rc, :]
                    bot = ubig[2 * p + 1] @ t[p, rc:, :]
                    nxt.append(jnp.concatenate([top, bot], axis=0))
                ubig = nxt
        return out

    def rank_masks(self) -> tuple[Array, tuple[Array, ...]] | None:
        """Per-level skeleton-liveness masks from the stored rank vectors.

        Returns (leaf_mask (n_leaf, r0), level_masks[k-1] (n_k, r_k)) with
        1.0 on live skeleton slots and 0.0 on truncated ones, or None for
        fixed-rank builds.  Consumed by the factorization to regularize the
        (structurally singular) reduced Schur blocks of masked bases.
        """
        if not self.adaptive:
            return None
        dtype = self.u_leaf.dtype
        leaf = rank_mask(self.leaf_ranks, self.u_leaf.shape[-1], dtype)
        lvls = tuple(
            rank_mask(r, t.shape[-1], dtype)
            for r, t in zip(self.level_ranks, self.transfers))
        return leaf, lvls

    def memory_bytes(self) -> int:
        """Storage of the representation (the paper's 'Memory [MB]' column)."""
        leaves = [self.d_leaf, self.u_leaf, self.skel_leaf]
        total = sum(int(a.size) * a.dtype.itemsize for a in leaves)
        for t in (*self.transfers, *self.skels, *self.b_mats):
            total += int(t.size) * t.dtype.itemsize
        if self.adaptive:
            for t in (self.leaf_ranks, *self.level_ranks):
                total += int(t.size) * t.dtype.itemsize
        return total


def shrink_to_fit(hss: HSSMatrix, mesh=None, multiple: int = 1) -> HSSMatrix:
    """Slice every level's stacked arrays down to the level's max observed rank.

    The adaptive build keeps shapes static at the rank cap and zeroes the
    truncated columns; this host-side pass is where the representation — and
    everything downstream: factorization, per-iteration solves, matmats —
    actually gets smaller.  Exact, not approximate: every sliced-away slot is
    structurally zero (dead u/transfer columns, dead b_mats rows/columns), so
    matmat/solve parity with the unshrunk matrix is float-noise only.

    ``multiple`` rounds each level's new cap up (e.g. 8 for TPU lane
    friendliness); ``mesh`` re-pins node-stacked outputs to the shared
    ``dist.api.node_partition_spec`` placement so a mesh-resident build stays
    sharded through the shrink.  Fixed-rank builds are returned unchanged.
    """
    if not hss.adaptive:
        return hss
    K = hss.levels
    caps = hss.ranks
    new_caps = [
        min(cap, max(1, -(-obs // multiple) * multiple))
        for cap, obs in zip(caps, hss.observed_ranks())
    ]
    if new_caps == caps:
        return hss

    def put(a: Array) -> Array:
        if mesh is None:
            return a
        from jax.sharding import NamedSharding

        from repro.dist.api import node_partition_spec

        return jax.device_put(
            a, NamedSharding(mesh, node_partition_spec(mesh, a.ndim,
                                                       a.shape[0])))

    r0 = new_caps[0]
    u_leaf = put(hss.u_leaf[:, :, :r0])
    skel_leaf = put(hss.skel_leaf[:, :r0])
    transfers, skels, b_mats = [], [], []
    for k in range(1, K + 1):
        rc = new_caps[k - 1]                     # child-level cap
        b_mats.append(put(hss.b_mats[k - 1][:, :rc, :rc]))
        if k == K:
            break
        rk = new_caps[k]
        t = hss.transfers[k - 1]
        n_k, two_rc_old = t.shape[0], t.shape[1]
        t = t.reshape(n_k, 2, two_rc_old // 2, t.shape[2])
        t = t[:, :, :rc, :rk].reshape(n_k, 2 * rc, rk)
        transfers.append(put(t))
        skels.append(put(hss.skels[k - 1][:, :rk]))
    return dataclasses.replace(
        hss,
        u_leaf=u_leaf,
        skel_leaf=skel_leaf,
        transfers=tuple(transfers),
        skels=tuple(skels),
        b_mats=tuple(b_mats),
    )


def shrink_report(hss: HSSMatrix, mesh=None) -> tuple[HSSMatrix, dict]:
    """``shrink_to_fit`` plus the rank-trajectory fields of ``FitReport``.

    Returns the (possibly) shrunk matrix and a dict of ranks_pre/ranks_post/
    rank_sum_pre/rank_sum_post; fixed-rank builds pass through unchanged
    with pre == post.  Shared by the engine and both trainers.
    """
    info = dict(ranks_pre=tuple(hss.ranks), rank_sum_pre=hss.stored_rank_sum())
    hss = shrink_to_fit(hss, mesh=mesh)
    info.update(ranks_post=tuple(hss.ranks),
                rank_sum_post=hss.stored_rank_sum())
    return hss, info
