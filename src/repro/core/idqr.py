"""Interpolative decomposition via greedy column-pivoted QR, in JAX.

Selects ``k`` skeleton columns J of M (s, n) and an interpolation matrix
T (k, n) with  M ≈ M[:, J] @ T  and  T[:, J] = I.

This is the TPU-native stand-in for STRUMPACK's ANN-guided pivot selection:
the *sampling* (which rows/columns of K we look at) already encodes the data
geometry (see compression.py); the pivoted QR then extracts the dominant
skeleton within the sampled block.  The loop is k sequential rank-1 updates
(k is the HSS rank, small) and is vmapped across all nodes of a tree level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("k",))
def cpqr_select(m_mat: Array, k: int) -> tuple[Array, Array]:
    """Greedy CPQR pivot selection.

    Returns (piv (k,) int32 column indices, qmat (s, k) orthonormal basis of
    the selected columns' span).  Modified-Gram-Schmidt with explicit
    re-orthogonalization against previously selected directions.
    """
    s, n = m_mat.shape
    dtype = m_mat.dtype

    def body(i, carry):
        resid, piv, qs, avail = carry
        norms = jnp.where(avail, jnp.sum(resid * resid, axis=0), -1.0)
        p = jnp.argmax(norms).astype(jnp.int32)
        col = resid[:, p]
        nrm = jnp.sqrt(jnp.maximum(norms[p], 1e-30))
        q = col / nrm
        # "Twice is enough": re-orthogonalize against prior directions.
        q = q - qs @ (qs.T @ q)
        q = q / jnp.sqrt(jnp.maximum(q @ q, 1e-30))
        # Deflate every remaining column.
        resid = resid - q[:, None] * (q @ resid)[None, :]
        # Numerical safety: zero the chosen column exactly.
        resid = resid.at[:, p].set(0.0)
        piv = piv.at[i].set(p)
        qs = qs.at[:, i].set(q)
        avail = avail.at[p].set(False)   # pivots stay distinct even for rank-
        return resid, piv, qs, avail     # deficient (e.g. all-zero) blocks

    piv0 = jnp.zeros((k,), jnp.int32)
    qs0 = jnp.zeros((s, k), dtype)
    avail0 = jnp.ones((n,), bool)
    _, piv, qs, _ = jax.lax.fori_loop(0, k, body, (m_mat, piv0, qs0, avail0))
    return piv, qs


@functools.partial(jax.jit, static_argnames=("k",))
def interp_decomp(m_mat: Array, k: int, rtol: float = 1e-5) -> tuple[Array, Array]:
    """Column ID:  M ≈ M[:, J] @ T  with  T[:, J] = I_k.

    T comes from the triangular factor of the pivoted QR: with Q from
    cpqr_select, R = QᵀM and R_J = Qᵀ M[:, J] is (numerically) upper
    triangular in pivot order, so T = R_J⁻¹ R.  When the numerical rank of M
    is below k — which happens by design, the HSS rank is a static cap (cf.
    hss_max_rank in the paper), and for leaves made of inert padding points —
    the trailing R_J diagonal entries underflow and a raw solve yields
    NaN/garbage.  Rows whose diagonal falls below ``rtol * max|diag|`` are
    truncated: their basis directions carry no signal, so dropping them gives
    the best-available rank-r interpolation instead of amplified noise.
    """
    piv, qs = cpqr_select(m_mat, k)
    r_full = qs.T @ m_mat                                   # (k, n)
    r_skel = jnp.triu(jnp.take(r_full, piv, axis=1))        # (k, k) upper-tri
    diag = jnp.diagonal(r_skel)
    tol = rtol * jnp.maximum(jnp.max(jnp.abs(diag)), 1e-30)
    keep = jnp.abs(diag) > tol
    # Truncate rank-deficient directions: unit diagonal + zeroed row makes
    # the triangular solve exact and finite for the dropped rows.
    r_safe = jnp.where(keep[:, None], r_skel, 0.0) + jnp.diag(
        jnp.where(keep, 0.0, 1.0).astype(m_mat.dtype))
    rhs = jnp.where(keep[:, None], r_full, 0.0)
    t_full = jax.scipy.linalg.solve_triangular(r_safe, rhs, lower=False)
    # Enforce exact identity on skeleton columns.
    t_full = t_full.at[:, piv].set(jnp.eye(k, dtype=m_mat.dtype))
    return piv, t_full


def row_interp_decomp(m_mat: Array, k: int) -> tuple[Array, Array]:
    """Row ID:  M ≈ P @ M[J, :]  with P (rows, k), P[J, :] = I_k."""
    piv, t = interp_decomp(m_mat.T, k)
    return piv, t.T
