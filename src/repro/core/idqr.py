"""Interpolative decomposition via greedy column-pivoted QR, in JAX.

Selects ``k`` skeleton columns J of M (s, n) and an interpolation matrix
T (k, n) with  M ≈ M[:, J] @ T  and  T[:, J] = I.

This is the TPU-native stand-in for STRUMPACK's ANN-guided pivot selection:
the *sampling* (which rows/columns of K we look at) already encodes the data
geometry (see compression.py); the pivoted QR then extracts the dominant
skeleton within the sampled block.  The loop is k sequential rank-1 updates
(k is the HSS rank, small) and is vmapped across all nodes of a tree level.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("k",))
def cpqr_select(m_mat: Array, k: int) -> tuple[Array, Array]:
    """Greedy CPQR pivot selection.

    Returns (piv (k,) int32 column indices, qmat (s, k) orthonormal basis of
    the selected columns' span).  Modified-Gram-Schmidt with explicit
    re-orthogonalization against previously selected directions.
    """
    s, n = m_mat.shape
    dtype = m_mat.dtype

    def body(i, carry):
        resid, piv, qs, avail = carry
        norms = jnp.where(avail, jnp.sum(resid * resid, axis=0), -1.0)
        p = jnp.argmax(norms).astype(jnp.int32)
        col = resid[:, p]
        nrm = jnp.sqrt(jnp.maximum(norms[p], 1e-30))
        q = col / nrm
        # "Twice is enough": re-orthogonalize against prior directions.
        q = q - qs @ (qs.T @ q)
        q = q / jnp.sqrt(jnp.maximum(q @ q, 1e-30))
        # Deflate every remaining column.
        resid = resid - q[:, None] * (q @ resid)[None, :]
        # Numerical safety: zero the chosen column exactly.
        resid = resid.at[:, p].set(0.0)
        piv = piv.at[i].set(p)
        qs = qs.at[:, i].set(q)
        avail = avail.at[p].set(False)   # pivots stay distinct even for rank-
        return resid, piv, qs, avail     # deficient (e.g. all-zero) blocks

    piv0 = jnp.zeros((k,), jnp.int32)
    qs0 = jnp.zeros((s, k), dtype)
    avail0 = jnp.ones((n,), bool)
    _, piv, qs, _ = jax.lax.fori_loop(0, k, body, (m_mat, piv0, qs0, avail0))
    return piv, qs


def finish_interp(piv: Array, r_full: Array, rtol: float,
                  keep_identity: bool) -> tuple[Array, Array]:
    """Truncation + triangular solve from (piv, R = QᵀM): returns (T, rank).

    The back half of the ID, shared by the XLA path (``_interp_core``) and
    the fused Pallas assemble+ID stage (``repro.kernels.compress``), which
    computes ``piv`` and ``R`` on-chip and hands only those small arrays
    here.  With Q from cpqr_select, R = QᵀM and R_J = Qᵀ M[:, J] is
    (numerically) upper triangular in pivot order, so T = R_J⁻¹ R.  The
    greedy pivoting makes |R_J[i, i]| (the residual norm at step i)
    non-increasing, so its decay against ``rtol * |R_J[0, 0]|`` reveals the
    numerical rank: ``rank`` is the longest prefix of directions above the
    tolerance (STRUMPACK's rel_tol semantics — the static ``k`` is only the
    hss_max_rank cap).  Truncated directions get a unit diagonal + zeroed
    row, which makes the triangular solve exact and finite instead of
    amplifying noise through an underflowed diagonal.

    ``keep_identity=True`` (legacy fixed-rank mode) re-enforces T[:, J] = I_k
    on ALL k skeleton columns, so even truncated skeletons interpolate
    themselves exactly — shapes and downstream factorizations see a full-rank
    basis.  ``keep_identity=False`` (adaptive mode) instead zeroes every
    truncated row of T: columns ≥ rank of the resulting interpolation basis
    are exactly 0, which is what lets callers mask and later slice them away
    without changing any live value.
    """
    k = piv.shape[0]
    m_dtype = r_full.dtype
    r_skel = jnp.triu(jnp.take(r_full, piv, axis=1))        # (k, k) upper-tri
    diag = jnp.diagonal(r_skel)
    tol = rtol * jnp.maximum(jnp.max(jnp.abs(diag)), 1e-30)
    above = jnp.abs(diag) > tol
    if keep_identity:
        # Legacy fixed-rank mode keeps its historical elementwise truncation
        # (NaN-safety only — a below-tol direction sandwiched between kept
        # ones stays dropped individually, exactly as before adaptivity).
        keep = above
    else:
        # Prefix rank: float noise can make |diag| non-monotone near the
        # tolerance; everything after the first below-tol direction is dead
        # so the live directions are a contiguous leading block
        # (maskable/sliceable by column index).
        keep = jnp.cumsum(jnp.logical_not(above)) == 0
    rank = jnp.sum(keep).astype(jnp.int32)
    r_safe = jnp.where(keep[:, None], r_skel, 0.0) + jnp.diag(
        jnp.where(keep, 0.0, 1.0).astype(m_dtype))
    rhs = jnp.where(keep[:, None], r_full, 0.0)
    t_full = jax.scipy.linalg.solve_triangular(r_safe, rhs, lower=False)
    if keep_identity:
        # Exact identity on all skeleton columns (legacy fixed-rank mode).
        t_full = t_full.at[:, piv].set(jnp.eye(k, dtype=m_dtype))
    else:
        # Exact identity on LIVE skeleton columns only.  A truncated pivot
        # is not a skeleton: its column keeps the solved interpolation
        # weights over the live skeletons (zeroing it would drop that
        # column's full contribution, not its below-tolerance residual).
        keep_f = keep.astype(m_dtype)
        at_piv = jnp.take(t_full, piv, axis=1)               # (k, k)
        t_full = t_full.at[:, piv].set(jnp.where(
            keep[None, :], jnp.eye(k, dtype=m_dtype), at_piv))
        t_full = t_full * keep_f[:, None]
    return t_full, rank


def _interp_core(m_mat: Array, k: int, rtol: float, keep_identity: bool
                 ) -> tuple[Array, Array, Array]:
    """Shared ID core: pivoted QR, then ``finish_interp``'s truncation +
    triangular solve.  Returns (piv, T, rank)."""
    piv, qs = cpqr_select(m_mat, k)
    r_full = qs.T @ m_mat                                   # (k, n)
    t_full, rank = finish_interp(piv, r_full, rtol, keep_identity)
    return piv, t_full, rank


@functools.partial(jax.jit, static_argnames=("k",))
def interp_decomp(m_mat: Array, k: int, rtol: float = 1e-5) -> tuple[Array, Array]:
    """Column ID:  M ≈ M[:, J] @ T  with  T[:, J] = I_k.

    Fixed-rank view: ``rtol`` here is only the NaN-safety truncation for
    rank-deficient blocks (e.g. leaves of inert padding points); all k
    skeleton columns keep their exact-identity interpolation.  Use
    ``interp_decomp_ranked`` for the adaptive tolerance-driven variant.
    """
    piv, t_full, _ = _interp_core(m_mat, k, rtol, keep_identity=True)
    return piv, t_full


@functools.partial(jax.jit, static_argnames=("k",))
def interp_decomp_ranked(m_mat: Array, k: int, rtol: float = 1e-5
                         ) -> tuple[Array, Array, Array]:
    """Adaptive column ID: (piv, T, rank) with rows ≥ rank of T exactly 0.

    ``rank`` is the numerical rank detected from the pivoted-QR diagonal
    decay against ``rtol`` (k stays the static cap, so shapes never depend
    on data).  T[:, J] = I on the first ``rank`` skeleton columns and 0 on
    the truncated ones, so a caller-side column mask ``arange(k) < rank``
    over the interpolation basis is exact, not approximate.
    """
    return _interp_core(m_mat, k, rtol, keep_identity=False)


def row_interp_decomp(m_mat: Array, k: int) -> tuple[Array, Array]:
    """Row ID:  M ≈ P @ M[J, :]  with P (rows, k), P[J, :] = I_k."""
    piv, t = interp_decomp(m_mat.T, k)
    return piv, t.T


def row_interp_decomp_ranked(m_mat: Array, k: int, rtol: float = 1e-5
                             ) -> tuple[Array, Array, Array]:
    """Adaptive row ID: M ≈ P @ M[J, :] with P columns ≥ rank exactly 0."""
    piv, t, rank = interp_decomp_ranked(m_mat.T, k, rtol)
    return piv, t.T, rank
