"""Fused ADMM z-projection + multiplier update, Pallas TPU.

z⁺ = Π_[0,c](x − μ/β);  μ⁺ = μ − β (x − z⁺)

One pass over three N-vectors producing two — 3 reads + 2 writes per element
instead of the 5 reads + 3 writes of the unfused sequence (z, x−z, saxpy).
Pure VPU elementwise work tiled along the (8, 128)-aligned vector layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _zmu_tile(x_ref, mu_ref, c_ref, z_ref, mu_out_ref, *, beta: float):
    x = x_ref[...]
    mu = mu_ref[...]
    c = c_ref[...]
    z = jnp.clip(x - mu * (1.0 / beta), 0.0, c)
    z_ref[...] = z
    mu_out_ref[...] = mu - beta * (x - z)


@functools.partial(jax.jit, static_argnames=("beta", "block", "interpret"))
def fused_zmu_update_pallas(
    x: jax.Array, mu: jax.Array, c_vec: jax.Array, beta: float,
    block: int = 65536, interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """x, mu, c_vec — 1-D of equal length divisible by ``block`` (ops pads)."""
    n = x.shape[0]
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    z, mu_new = pl.pallas_call(
        functools.partial(_zmu_tile, beta=beta),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(x, mu, c_vec)
    return z, mu_new
