"""Public wrapper for the fused ADMM update."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.admm_update.kernel import fused_zmu_update_pallas
from repro.kernels.admm_update.ref import fused_zmu_update_ref


@functools.partial(jax.jit, static_argnames=("beta", "interpret", "use_pallas"))
def fused_zmu_update(
    x: jax.Array, mu: jax.Array, c_vec: jax.Array, beta: float,
    interpret: bool = True, use_pallas: bool = True,
) -> tuple[jax.Array, jax.Array]:
    if not use_pallas:
        return fused_zmu_update_ref(x, mu, c_vec, beta)
    n = x.shape[0]
    block = min(65536, max(((n + 127) // 128) * 128, 128))
    n_p = ((n + block - 1) // block) * block
    pad = lambda a: jnp.pad(a, (0, n_p - n))
    z, mu_new = fused_zmu_update_pallas(
        pad(x), pad(mu), pad(c_vec), beta, block=block, interpret=interpret
    )
    return z[:n], mu_new[:n]
