"""Pure-jnp oracle for the fused ADMM z/mu update (paper Alg. 2 lines 3-4)."""
import jax
import jax.numpy as jnp


def fused_zmu_update_ref(x, mu, c_vec, beta: float):
    z = jnp.clip(x - mu / beta, 0.0, c_vec)
    mu_new = mu - beta * (x - z)
    return z, mu_new
