"""Public wrapper for the Gaussian-kernel Pallas kernel.

Pads rows to the tile size and features to the lane width (128), then crops.
Padding rows are zero vectors — they produce harmless extra tiles that are
sliced away (never exp overflow: sq >= 0 always).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gaussian.kernel import gaussian_block_pallas


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("h", "interpret", "bm", "bn"))
def gaussian_block(
    xa: jax.Array,
    xb: jax.Array,
    h: float,
    interpret: bool = False,
    bm: int = 256,
    bn: int = 256,
) -> jax.Array:
    ma, f = xa.shape
    mb = xb.shape[0]
    bm_eff = min(bm, max(((ma + 7) // 8) * 8, 8))
    bn_eff = min(bn, max(((mb + 127) // 128) * 128, 128))
    ma_p = ((ma + bm_eff - 1) // bm_eff) * bm_eff
    mb_p = ((mb + bn_eff - 1) // bn_eff) * bn_eff
    f_p = max(((f + 127) // 128) * 128, 128)
    out = gaussian_block_pallas(
        _pad_to(xa, ma_p, f_p), _pad_to(xb, mb_p, f_p), h,
        bm=bm_eff, bn=bn_eff, interpret=interpret,
    )
    return out[:ma, :mb]
