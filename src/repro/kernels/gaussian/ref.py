"""Pure-jnp oracle for the tiled Gaussian kernel block."""
import jax
import jax.numpy as jnp


def gaussian_block_ref(xa: jax.Array, xb: jax.Array, h: float) -> jax.Array:
    """K[i,j] = exp(-||xa_i - xb_j||^2 / (2 h^2)), computed naively."""
    diff = xa[:, None, :] - xb[None, :, :]
    sq = jnp.sum(diff * diff, axis=-1)
    return jnp.exp(sq * (-0.5 / (h * h)))
