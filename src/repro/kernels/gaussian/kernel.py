"""Tiled Gaussian-kernel block evaluation, Pallas TPU.

Computes K = exp(-(|xa|^2 + |xb|^2 - 2 xa xbᵀ) / (2h^2)) one (bm, bn) output
tile at a time.  The cross term is an MXU matmul over the (padded) feature
axis; row norms are recomputed per tile in VREGs (F is small for SVM data, so
the redundant flops are negligible next to the exp epilogue); the exp fuses
into the same tile while it is still resident in VMEM — the whole point of
the kernel: one HBM round-trip per output tile instead of three (sqdist,
scale, exp) under unfused XLA.

VMEM budget per grid step (bm = bn = 256, F = 128, f32):
  xa tile 256*128*4 = 128 KiB, xb tile 128 KiB, out tile 256 KiB  « 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gaussian_tile(xa_ref, xb_ref, out_ref, *, inv2h2: float):
    xa = xa_ref[...]                      # (bm, F) in VMEM
    xb = xb_ref[...]                      # (bn, F)
    na = jnp.sum(xa * xa, axis=-1)[:, None]
    nb = jnp.sum(xb * xb, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        xa, xb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    sq = jnp.maximum(na + nb - 2.0 * cross, 0.0)
    out_ref[...] = jnp.exp(sq * (-inv2h2)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("h", "bm", "bn", "interpret"))
def gaussian_block_pallas(
    xa: jax.Array,
    xb: jax.Array,
    h: float,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """xa (Ma, F), xb (Mb, F) -> (Ma, Mb). Ma % bm == Mb % bn == 0 (ops pads)."""
    ma, f = xa.shape
    mb = xb.shape[0]
    grid = (ma // bm, mb // bn)
    return pl.pallas_call(
        functools.partial(_gaussian_tile, inv2h2=0.5 / (h * h)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ma, mb), xa.dtype),
        interpret=interpret,
    )(xa, xb)
