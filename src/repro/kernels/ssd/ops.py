"""Public wrapper for the SSD chunk kernel: model layout -> kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd import ref as ssd_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "use_pallas"))
def ssd_forward(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)   positive step sizes (post-softplus)
    a: jax.Array,      # (H,)        negative decay rates
    b_mat: jax.Array,  # (B, S, G, N)
    c_mat: jax.Array,  # (B, S, G, N)
    d_vec: jax.Array,  # (H,)
    chunk: int = 128,
    interpret: bool = False,
    use_pallas: bool = True,
) -> jax.Array:
    """Returns y (B, S, H, P). Heads share B/C within each of G groups."""
    if not use_pallas:
        return ssd_ref.ssd_batched_ref(x, dt, a, b_mat, c_mat, d_vec,
                                       chunk=chunk)
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    bh = bsz * h
    xk = x.transpose(0, 2, 1, 3).reshape(bh, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(bh, s, 1)
    bk = jnp.repeat(b_mat.transpose(0, 2, 1, 3), rep, axis=1).reshape(bh, s, n)
    ck = jnp.repeat(c_mat.transpose(0, 2, 1, 3), rep, axis=1).reshape(bh, s, n)
    ak = jnp.tile(a, bsz).reshape(bh, 1)
    dk = jnp.tile(d_vec, bsz).reshape(bh, 1)
    y = ssd_pallas(xk, dtk, ak, bk, ck, dk, chunk=chunk, interpret=interpret)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
