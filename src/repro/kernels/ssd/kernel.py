"""Mamba-2 SSD chunk scan, Pallas TPU.

Semiseparable evaluation per (batch*head) sequence: the grid iterates chunks
in order (TPU grids execute sequentially, last axis fastest) and carries the
(N, P) state in a VMEM scratch across chunk steps — zero HBM traffic for the
recurrent state.  Per chunk:

  intra  : ((C Bᵀ) ⊙ decay-mask) (dt ⊙ X)   — dense Q×Q MXU block
  inter  : (C ⊙ exp(L)) h_in                — rank-N carrier (the
           "off-diagonal low-rank" of the semiseparable matrix)
  state  : h_out = exp(L_tot) h_in + (B ⊙ exp(L_tot − L) dt)ᵀ X

All decay exponents are ≤ 0, so every exp() is in (0, 1] — numerically safe
in f32 without rescaling tricks.

VMEM per step (Q=256, P=64, N=128, f32): x 64 KiB + B/C 2*128 KiB + scores
256 KiB + state scratch 32 KiB « 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, h_ref,
               *, chunk: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0, 0]
    d_scalar = d_ref[0, 0]
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)[:, 0]  # (Q,)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    la = jnp.cumsum(dt) * a                   # (Q,) inclusive log-decay
    seg = la[:, None] - la[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * gate
    y = scores @ (x * dt[:, None])

    h = h_ref[...]
    y = y + (c * jnp.exp(la)[:, None]) @ h + d_scalar * x
    y_ref[0] = y.astype(y_ref.dtype)

    la_tot = la[-1]
    carrier = (b * (jnp.exp(la_tot - la) * dt)[:, None]).T @ x
    h_ref[...] = jnp.exp(la_tot) * h + carrier


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_pallas(
    x: jax.Array,      # (BH, S, P)
    dt: jax.Array,     # (BH, S, 1)
    a: jax.Array,      # (BH, 1)  negative per-head decay rates
    b_mat: jax.Array,  # (BH, S, N)
    c_mat: jax.Array,  # (BH, S, N)
    d_vec: jax.Array,  # (BH, 1)  skip-connection scale
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, s, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    return pl.pallas_call(
        functools.partial(_ssd_chunk, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),             # a
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),             # D
            pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),   # x
            pl.BlockSpec((1, chunk, 1), lambda i, c: (i, c, 0)),   # dt
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),   # B
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),   # C
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda i, c: (i, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        # (N, P) recurrent state in VMEM, persists across the chunk axis.
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(a, d_vec, x, dt, b_mat, c_mat)
