"""Pure-jnp oracles for the Mamba-2 SSD (state-space dual) layer.

Two references:
  ssd_scan_ref    — the exact sequential recurrence (ground truth):
                      h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_tᵀ
                      y_t = C_t h_t + D x_t
  ssd_chunked_ref — the chunked semiseparable evaluation (dense intra-chunk
                    block + low-rank inter-chunk state passing).  This is the
                    SAME hierarchical split the paper applies to kernel
                    matrices (diag blocks dense, off-diag through a low-rank
                    carrier) specialized to 1-semiseparable structure
                    (DESIGN.md §5); it is what the Pallas kernel implements.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, b_mat, c_mat, d_scalar):
    """x (S,P), dt (S,), a scalar<0, b_mat/c_mat (S,N), d_scalar scalar.

    Returns (y (S,P), h_final (N,P)).
    """
    s, p = x.shape
    n = b_mat.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        h = jnp.exp(dt_t * a) * h + dt_t * b_t[:, None] * x_t[None, :]
        y_t = (c_t.astype(jnp.float32) @ h
               + d_scalar * x_t.astype(jnp.float32))
        return h, y_t

    # recurrent state in f32 regardless of operand dtype (bf16 operands are
    # fine for the matmuls; the state accumulates — §Perf change C1)
    h0 = jnp.zeros((n, p), jnp.float32)
    h_fin, y = jax.lax.scan(step, h0, (x, dt, b_mat, c_mat))
    return y, h_fin


def ssd_chunked_ref(x, dt, a, b_mat, c_mat, d_scalar, chunk: int = 16):
    """Chunked evaluation — must match ssd_scan_ref to fp tolerance."""
    s, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0
    nc = s // chunk

    xc = x.reshape(nc, chunk, p)
    dtc = dt.reshape(nc, chunk)
    bc = b_mat.reshape(nc, chunk, n)
    cc = c_mat.reshape(nc, chunk, n)

    def chunk_step(h, inp):
        xq, dtq, bq, cq = inp
        dtq = dtq.astype(jnp.float32)
        la = jnp.cumsum(dtq) * a                   # inclusive log decay (Q,)
        seg = la[:, None] - la[None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        gate = jnp.where(mask, jnp.exp(seg), 0.0)
        scores = jnp.dot(cq, bq.T,
                         preferred_element_type=jnp.float32) * gate
        y_intra = scores @ (xq.astype(jnp.float32) * dtq[:, None])
        y_state = (cq.astype(jnp.float32) * jnp.exp(la)[:, None]) @ h
        la_tot = la[-1]
        h_new = jnp.exp(la_tot) * h + (
            bq.astype(jnp.float32) * (jnp.exp(la_tot - la) * dtq)[:, None]
        ).T @ xq.astype(jnp.float32)
        y = y_intra + y_state + d_scalar * xq.astype(jnp.float32)
        return h_new, y

    h0 = jnp.zeros((n, p), jnp.float32)
    h_fin, yc = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    return yc.reshape(s, p), h_fin


def ssd_batched_ref(x, dt, a, b_mat, c_mat, d_vec, chunk: int = 16):
    """Batched-over-(B,H) chunked reference (fully vmapped — no unrolling).

    x (B,S,H,P), dt (B,S,H), a (H,), b_mat/c_mat (B,S,G,N) with G groups
    (heads share B/C within a group), d_vec (H,). Returns y (B,S,H,P).
    """
    bsz, s, h, p = x.shape
    g = b_mat.shape[2]
    rep = h // g
    b_full = jnp.repeat(b_mat, rep, axis=2)   # (B,S,H,N)
    c_full = jnp.repeat(c_mat, rep, axis=2)

    def one(xh, dth, ah, bh, ch, dh):
        y, _ = ssd_chunked_ref(xh, dth, ah, bh, ch, dh, chunk=chunk)
        return y

    per_head = jax.vmap(one, in_axes=(1, 1, 0, 1, 1, 0), out_axes=1)
    per_batch = jax.vmap(per_head, in_axes=(0, 0, None, 0, 0, None))
    return per_batch(x, dt, a, b_full, c_full, d_vec)


def ssd_batched_with_state(x, dt, a, b_mat, c_mat, d_vec, chunk: int = 16):
    """Like ssd_batched_ref but also returns final states (B,H,N,P)."""
    h = x.shape[2]
    g = b_mat.shape[2]
    rep = h // g
    b_full = jnp.repeat(b_mat, rep, axis=2)
    c_full = jnp.repeat(c_mat, rep, axis=2)

    def one(xh, dth, ah, bh, ch, dh):
        return ssd_chunked_ref(xh, dth, ah, bh, ch, dh, chunk=chunk)

    per_head = jax.vmap(one, in_axes=(1, 1, 0, 1, 1, 0), out_axes=(1, 0))
    per_batch = jax.vmap(per_head, in_axes=(0, 0, None, 0, 0, None))
    return per_batch(x, dt, a, b_full, c_full, d_vec)
