"""Flash-style fused attention, Pallas TPU.

Online-softmax over KV tiles with running (max, sum, acc) VMEM scratch.
Grid = (B*H, n_q_blocks, n_kv_blocks), kv fastest (TPU grids are sequential,
so the scratch carries across the kv axis and resets at kv == 0).

Features needed by the assigned architectures:
  - causal masking (decoder LMs)
  - local sliding window (gemma-2 alternating local/global layers)
  - logit softcapping cap*tanh(x/cap) (gemma-2)
  - GQA: the kv-head index is derived from the q-head index inside the
    BlockSpec index_map — no jnp.repeat materialization of K/V.

VMEM per step (bq = bk = 256, D = 128, f32): q/k/v tiles 3*128 KiB,
scores 256 KiB, acc 128 KiB « 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale: float, causal: bool, window: int | None,
                 softcap: float, bq: int, bk: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (bq, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                     # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)[:, None]
    acc_ref[...] = acc_ref[...] * alpha + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,   # (B, H, S, D)
    k: jax.Array,   # (B, Hkv, S, D)
    v: jax.Array,   # (B, Hkv, S, D)
    causal: bool = True,
    window: int | None = None,
    softcap: float = 0.0,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    rep = h // hkv
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    n_q, n_kv = s // bq, s // bk
    grid = (b * h, n_q, n_kv)
    scale = 1.0 / (d ** 0.5)

    def q_map(i, iq, ik):
        return (i, iq, 0)

    def kv_map(i, iq, ik):
        bi = i // h
        hi = i % h
        return (bi * hkv + hi // rep, ik, 0)

    qr = q.reshape(b * h, s, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq, bk=bk, n_kv=n_kv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d)
