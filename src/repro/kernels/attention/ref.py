"""Pure-jnp oracle for fused attention (causal / local window / softcap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,            # (B, H, S, D)
    k: jax.Array,            # (B, Hkv, S, D)
    v: jax.Array,            # (B, Hkv, S, D)
    causal: bool = True,
    window: int | None = None,   # None = global; w = attend to [i-w+1, i]
    softcap: float = 0.0,        # 0 = off; else cap*tanh(logits/cap)
) -> jax.Array:
    b, h, s, d = q.shape
    hkv = k.shape[1]
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qi = jnp.arange(s)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= qi >= kj
    if window is not None:
        mask &= (qi - kj) < window
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    # accumulate P·V in f32 (matches the Pallas kernel), cast once on exit
    return jnp.einsum("bhqk,bhkd->bhqd", p, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
