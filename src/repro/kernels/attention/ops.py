"""Public fused-attention wrapper with XLA fallback.

The XLA fallback is the *chunked online-softmax* implementation from
repro.models.layers (memory-bounded, differentiable); the Pallas kernel is
the TPU fast path for forward/inference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention.kernel import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "interpret", "use_pallas"),
)
def fused_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True, window: int | None = None, softcap: float = 0.0,
    interpret: bool = False, use_pallas: bool = True,
) -> jax.Array:
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    s = q.shape[2]
    blk = min(256, s)
    while s % blk:
        blk //= 2
    blk = max(blk, 1)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, softcap=softcap,
        bq=blk, bk=blk, interpret=interpret,
    )
