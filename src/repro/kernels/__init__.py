"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper with padding/layout handling + XLA fallback
  ref.py    — pure-jnp oracle used by tests (assert_allclose, interpret=True)

Kernels:
  gaussian     — tiled Gaussian kernel block evaluation (paper hot-spot:
                 HSS compression sampling + SVM prediction)
  admm_update  — fused ADMM z-projection + multiplier update (elementwise)
  ssd          — Mamba-2 SSD chunk scan (semiseparable matmul — the
                 paper-adjacent structure, see DESIGN.md §5)
  attention    — flash-style fused attention (causal / local window /
                 logit softcap) for the LM substrate
"""
