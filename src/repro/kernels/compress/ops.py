"""Public wrapper for the fused assemble+ID Pallas kernel.

Pads every node's candidate/proxy point blocks to TPU tile boundaries
(candidates to the 128-lane width — they are the columns of the on-chip
sampled block — proxies to the 8-sublane width, features to the lane
width), launches ALL nodes of a tree level as one batched Pallas dispatch,
and finishes the interpolative decomposition with the shared
``idqr.finish_interp`` truncation + triangular solve on the small (k, m)
projected factor the kernel wrote back.

Numerics: pivot selection and the projected factor R = QᵀAᵀ match
``idqr.cpqr_select`` on the XLA-assembled block (same operation order, f32
state), and the finish stage IS the XLA path's code — so the fused row ID
equals ``idqr.row_interp_decomp(_ranked)`` of the XLA-evaluated block up to
f32 rounding, with identical pivots on non-degenerate blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import idqr
from repro.kernels.compress.kernel import fused_assemble_id_pallas


def _pad3(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(
        x, ((0, 0), (0, rows - x.shape[1]), (0, cols - x.shape[2])))


@functools.partial(jax.jit, static_argnames=(
    "k", "kernel_name", "h", "rtol", "adaptive", "interpret"))
def _batched_assemble_id(
    xc: jax.Array,
    xp: jax.Array,
    cmask: jax.Array,
    k: int,
    kernel_name: str,
    h: float,
    rtol: float,
    adaptive: bool,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    b, m, f = xc.shape
    s = xp.shape[1]
    m_p = max(-(-m // 128) * 128, 128)
    s_p = max(-(-s // 8) * 8, 8)
    f_p = max(-(-f // 128) * 128, 128)
    piv, r_full = fused_assemble_id_pallas(
        _pad3(xc, m_p, f_p), _pad3(xp, s_p, f_p),
        jnp.pad(cmask.astype(jnp.float32), ((0, 0), (0, m_p - m))),
        kernel_name=kernel_name, h=h, k=k,
        m_real=m, s_real=s, f_real=f, interpret=interpret)
    r_full = r_full[:, :, :m]
    t_full, ranks = jax.vmap(
        lambda p, r: idqr.finish_interp(
            p, r, rtol, keep_identity=not adaptive))(piv, r_full)
    p_mat = jnp.transpose(t_full, (0, 2, 1)).astype(xc.dtype)   # (B, m, k)
    return piv, p_mat, ranks


def batched_assemble_id(
    xc: jax.Array,
    xp: jax.Array,
    k: int,
    *,
    kernel_name: str,
    h: float,
    rtol: float,
    adaptive: bool,
    cmask: jax.Array | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """All row IDs of one tree level in ONE fused Pallas launch.

    xc (B, m, f): each node's candidate points (leaf points / child
    skeletons); xp (B, s, f): each node's proxy points (near + far).
    Returns (piv (B, k) int32, p_mat (B, m, k) in xc.dtype, ranks (B,)
    int32) — exactly the per-node ``idqr.row_interp_decomp(_ranked)`` of
    the sampled blocks K(xc_i, xp_i), without ever materializing them in
    HBM.  ``adaptive=False`` reproduces fixed-rank semantics (all-k ranks,
    identity on every skeleton column); ``cmask`` (B, m) zeroes dead
    candidate rows before pivoting (adaptive upper levels).
    """
    if cmask is None:
        cmask = jnp.ones(xc.shape[:2], jnp.float32)
    return _batched_assemble_id(
        xc, xp, cmask, k, kernel_name, h, float(rtol), bool(adaptive),
        bool(interpret))
