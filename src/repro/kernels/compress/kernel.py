"""Fused assemble-then-ID, Pallas TPU: the HSS compression hot stage.

One grid step = one tree node.  The kernel evaluates the node's sampled
block Aᵀ = K(x_proxy, x_candidate) tile-resident in VMEM — gaussian via the
MXU matmul expansion, laplacian via a feature-chunked L1 scan — and then
runs the greedy column-pivoted-QR deflation loop of ``idqr.cpqr_select``
directly on that block while it is still on-chip.  Only the pivot indices
(k,) and the projected factor R = QᵀAᵀ (k, m) are written back to HBM: the
(n_proxy, m) sampled block, its residual, and the Q basis never leave VMEM.
Per node that is O(k·m) HBM traffic instead of O(n_proxy·m) plus the
O(k·n_proxy·m) of an unfused deflation loop's intermediate round-trips.

The CPQR loop mirrors ``idqr.cpqr_select`` operation for operation
(same norm, re-orthogonalization, deflation, and exact-zeroing steps) so the
selected pivots are identical to the XLA path on non-degenerate blocks; all
contractions and the deflation state are f32 regardless of input dtype
(bf16 inputs are upcast on load — the precision-accumulate convention).

Pivot bookkeeping is fully vectorized (one-hot accumulation against a lane
iota) — no dynamic scalar stores, so the same kernel body runs on TPU and
under ``interpret=True`` on CPU.

VMEM budget per grid step at the largest committed shapes (accurate preset
leaf stage: m = 256 candidates, s = 192 proxies, k = 64, f padded to 128):
  xc 256·128·4 = 128 KiB, xp 192·128·4 = 96 KiB, Aᵀ + residual
  2·192·256·4 = 384 KiB, Q 192·64·4 = 48 KiB, R out 64·256·4 = 64 KiB
  — well under 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_F_CHUNK = 8   # laplacian L1 scan: feature sublane chunk


def _assemble_gaussian(xp: jax.Array, xc: jax.Array, h: float) -> jax.Array:
    """exp(-||xp_i - xc_j||² / 2h²) as one MXU contraction + VPU epilogue."""
    np_ = jnp.sum(xp * xp, axis=-1)[:, None]
    nc = jnp.sum(xc * xc, axis=-1)[None, :]
    cross = jax.lax.dot_general(
        xp, xc, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    sq = jnp.maximum(np_ + nc - 2.0 * cross, 0.0)
    return jnp.exp(sq * (-0.5 / (h * h)))


def _assemble_laplacian(xp: jax.Array, xc: jax.Array, h: float,
                        f_real: int) -> jax.Array:
    """exp(-||xp_i - xc_j||₁ / h) via the feature-chunked L1 scan.

    The L1 distance has no matmul expansion; scanning ``_F_CHUNK``-wide
    feature slices keeps the broadcast intermediate at
    (s, m, _F_CHUNK) — the same trick as ``kernelfn.laplacian_block_xla``.
    Only ceil(f_real / _F_CHUNK) chunks are visited: the zero-padded feature
    tail contributes |0 - 0| = 0 and is skipped entirely.
    """
    n_chunks = -(-f_real // _F_CHUNK)

    def body(c, acc):
        a = jax.lax.dynamic_slice_in_dim(xp, c * _F_CHUNK, _F_CHUNK, 1)
        b = jax.lax.dynamic_slice_in_dim(xc, c * _F_CHUNK, _F_CHUNK, 1)
        return acc + jnp.sum(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)

    d1 = jax.lax.fori_loop(
        0, n_chunks, body,
        jnp.zeros((xp.shape[0], xc.shape[0]), jnp.float32))
    return jnp.exp(-d1 / h)


def _fused_tile(xc_ref, xp_ref, cmask_ref, piv_ref, rfull_ref, *,
                kernel_name: str, h: float, k: int,
                m_real: int, s_real: int, f_real: int):
    """One node: assemble Aᵀ = K(xp, xc) in VMEM, run k CPQR steps on it."""
    xc = xc_ref[0].astype(jnp.float32)            # (m_pad, f_pad) candidates
    xp = xp_ref[0].astype(jnp.float32)            # (s_pad, f_pad) proxies
    m_pad, s_pad = xc.shape[0], xp.shape[0]

    if kernel_name == "laplacian":
        a_t = _assemble_laplacian(xp, xc, h, f_real)
    else:
        a_t = _assemble_gaussian(xp, xc, h)

    # Padding rows/columns hold zero points whose kernel values are garbage
    # (exp of a finite distance, not 0) — mask them to exact zeros, and fold
    # in the caller's candidate-liveness mask (dead child skeletons of the
    # adaptive build; all-ones otherwise).
    row_ok = jax.lax.broadcasted_iota(jnp.int32, (s_pad, 1), 0) < s_real
    col_ok = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1) < m_real
    cmask = cmask_ref[0].astype(jnp.float32)[None, :]          # (1, m_pad)
    a_t = a_t * row_ok.astype(jnp.float32) * col_ok.astype(jnp.float32)
    a_t = a_t * cmask

    iota_m = jax.lax.broadcasted_iota(jnp.int32, (1, m_pad), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(i, carry):
        resid, qs, piv, avail = carry
        norms = jnp.where(avail, jnp.sum(resid * resid, axis=0)[None, :],
                          -1.0)
        p = jnp.argmax(norms).astype(jnp.int32)
        onehot = (iota_m == p).astype(jnp.float32)             # (1, m_pad)
        col = jnp.sum(resid * onehot, axis=1)[:, None]         # (s_pad, 1)
        nrm = jnp.sqrt(jnp.maximum(jnp.sum(norms * onehot), 1e-30))
        q = col / nrm
        # "Twice is enough": re-orthogonalize against prior directions.
        proj = jax.lax.dot_general(
            qs, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (k, 1)
        q = q - jax.lax.dot_general(
            qs, proj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        q = q / jnp.sqrt(jnp.maximum(jnp.sum(q * q), 1e-30))
        # Deflate every remaining column; zero the chosen one exactly.
        qr = jax.lax.dot_general(
            q, resid, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # (1, m_pad)
        resid = (resid - q * qr) * (1.0 - onehot)
        # One-hot accumulation instead of dynamic stores (TPU-friendly).
        sel = (iota_k == i).astype(jnp.float32)                # (1, k)
        piv = piv + p * (iota_k == i).astype(jnp.int32)
        qs = qs + q * sel
        avail = jnp.logical_and(avail, onehot < 0.5)
        return resid, qs, piv, avail

    qs0 = jnp.zeros((s_pad, k), jnp.float32)
    piv0 = jnp.zeros((1, k), jnp.int32)
    _, qs, piv, _ = jax.lax.fori_loop(
        0, k, body, (a_t, qs0, piv0, col_ok))
    rfull = jax.lax.dot_general(
        qs, a_t, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (k, m_pad)
    piv_ref[0] = piv[0]
    rfull_ref[0] = rfull


@functools.partial(jax.jit, static_argnames=(
    "kernel_name", "h", "k", "m_real", "s_real", "f_real", "interpret"))
def fused_assemble_id_pallas(
    xc: jax.Array,
    xp: jax.Array,
    cmask: jax.Array,
    kernel_name: str,
    h: float,
    k: int,
    m_real: int,
    s_real: int,
    f_real: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Batched fused assemble+CPQR over nodes.

    xc (B, m_pad, f_pad) candidate points, xp (B, s_pad, f_pad) proxy
    points, cmask (B, m_pad) candidate liveness (f32 0/1).  Returns
    (piv (B, k) int32, r_full (B, k, m_pad) f32) — the inputs of
    ``idqr.finish_interp``.  Shapes must arrive pre-padded (ops pads).
    """
    b, m_pad, f_pad = xc.shape
    s_pad = xp.shape[1]
    return pl.pallas_call(
        functools.partial(
            _fused_tile, kernel_name=kernel_name, h=h, k=k,
            m_real=m_real, s_real=s_real, f_real=f_real),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, m_pad, f_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s_pad, f_pad), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, m_pad), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k, m_pad), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.int32),
            jax.ShapeDtypeStruct((b, k, m_pad), jnp.float32),
        ],
        interpret=interpret,
    )(xc, xp, cmask)
