"""Fused HSS-compression kernels: assemble+ID in one Pallas launch.

``ops.batched_assemble_id`` runs every node ID of one tree level as a single
tiled Pallas dispatch — the sampled kernel block K(x_node, x_proxy) is
evaluated in VMEM and consumed by the pivoted-QR deflation loop in place, so
it never round-trips through HBM.  ``laplacian.laplacian_block`` is the plain
block-eval Pallas kernel for the laplacian kernel (the gaussian analogue
lives in repro.kernels.gaussian).
"""
from repro.kernels.compress import laplacian, ops  # noqa: F401
