"""Tiled laplacian-kernel block evaluation, Pallas TPU.

Computes K = exp(-||xa_i - xb_j||₁ / h) one (bm, bn) output tile at a time.
The L1 distance has no MXU matmul expansion, so each tile accumulates the
distance over feature chunks on the VPU — the broadcast intermediate is
(bm, bn, _F_CHUNK), never (ma, mb, f) — and the exp epilogue fuses into the
tile while it is VMEM-resident.  This is the Pallas twin of the
feature-chunked ``kernelfn.laplacian_block_xla`` scan, closing the gap where
``KernelSpec(name="laplacian", impl="pallas")`` used to warn-and-fall-back.

Padding rows are zero vectors: their pairwise L1 distance to other zero rows
is 0 (kernel value 1), which lands only in cropped-away tiles; zero-padded
FEATURES contribute |0 - 0| = 0 to every distance, so the chunked loop can
simply skip the padded feature tail.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_F_CHUNK = 8


def _laplacian_tile(xa_ref, xb_ref, out_ref, *, inv_h: float, f_real: int):
    # f_real is the pre-padding feature count: chunks past it are all-zero
    # padding and contribute |0 - 0| = 0, so the loop skips them.
    xa = xa_ref[...].astype(jnp.float32)       # (bm, f_pad) in VMEM
    xb = xb_ref[...].astype(jnp.float32)       # (bn, f_pad)
    n_chunks = -(-f_real // _F_CHUNK)

    def body(c, acc):
        a = jax.lax.dynamic_slice_in_dim(xa, c * _F_CHUNK, _F_CHUNK, 1)
        b = jax.lax.dynamic_slice_in_dim(xb, c * _F_CHUNK, _F_CHUNK, 1)
        return acc + jnp.sum(jnp.abs(a[:, None, :] - b[None, :, :]), axis=-1)

    d1 = jax.lax.fori_loop(
        0, n_chunks, body,
        jnp.zeros((xa.shape[0], xb.shape[0]), jnp.float32))
    out_ref[...] = jnp.exp(-d1 * inv_h).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "h", "bm", "bn", "f_real", "interpret"))
def laplacian_block_pallas(
    xa: jax.Array,
    xb: jax.Array,
    h: float,
    bm: int = 256,
    bn: int = 256,
    f_real: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """xa (Ma, F), xb (Mb, F) -> (Ma, Mb). Ma % bm == Mb % bn == 0 (the
    ``laplacian_block`` wrapper pads)."""
    ma, f = xa.shape
    mb = xb.shape[0]
    grid = (ma // bm, mb // bn)
    return pl.pallas_call(
        functools.partial(
            _laplacian_tile, inv_h=1.0 / h,
            f_real=f if f_real is None else f_real),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, f), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, f), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ma, mb), xa.dtype),
        interpret=interpret,
    )(xa, xb)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


@functools.partial(jax.jit, static_argnames=("h", "interpret", "bm", "bn"))
def laplacian_block(
    xa: jax.Array,
    xb: jax.Array,
    h: float,
    interpret: bool = False,
    bm: int = 256,
    bn: int = 256,
) -> jax.Array:
    ma, f = xa.shape
    mb = xb.shape[0]
    bm_eff = min(bm, max(((ma + 7) // 8) * 8, 8))
    bn_eff = min(bn, max(((mb + 127) // 128) * 128, 128))
    ma_p = ((ma + bm_eff - 1) // bm_eff) * bm_eff
    mb_p = ((mb + bn_eff - 1) // bn_eff) * bn_eff
    # Feature padding to the lane width; the in-kernel chunk loop only
    # visits ceil(f / _F_CHUNK) chunks, so the zero tail costs nothing.
    f_p = max(((f + 127) // 128) * 128, 128)
    out = laplacian_block_pallas(
        _pad_to(xa, ma_p, f_p), _pad_to(xb, mb_p, f_p),
        h, bm=bm_eff, bn=bn_eff, f_real=f, interpret=interpret,
    )
    return out[:ma, :mb]
