"""Fault-tolerant checkpointing.

Layout: one directory per step containing
  manifest.json          — step, leaf paths, shapes, dtypes, shard counts,
                           mesh shape at save time
  <leaf-path>.<i>.npz    — zstd-compressed shard i of the leaf (split along
                           dim 0, one file per save-shard)

Design points mirroring multi-host practice:
  * per-leaf SHARD files: on a real cluster each host writes only its local
    shards (here: a configurable shard count emulates that layout);
  * ELASTIC restore: the loader reassembles full arrays from any shard
    count and re-device_puts them under ANY target mesh/sharding — a
    checkpoint written on mesh A restores onto mesh B (tested 8 -> 4 -> 1
    devices in tests/test_ckpt.py);
  * atomicity: writes go to ``<dir>.tmp`` then rename; a crashed save never
    corrupts the latest good checkpoint;
  * async: ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes to disk on a worker thread so the train
    loop is not blocked by IO.
"""
from __future__ import annotations

import io
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

try:
    import zstandard as zstd

    def _compress(b: bytes) -> bytes:
        return zstd.ZstdCompressor(level=3).compress(b)

    def _decompress(b: bytes) -> bytes:
        return zstd.ZstdDecompressor().decompress(b)
except Exception:                                    # pragma: no cover
    _compress = _decompress = lambda b: b

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))
        parts.append(str(key))
    return ".".join(parts) or "root"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[_path_str(path)] = np.asarray(jax.device_get(leaf))
    return out


def save_checkpoint(directory: str, tree: PyTree, step: int,
                    n_shards: int = 4, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, arr in leaves.items():
        shards = max(1, min(n_shards, arr.shape[0] if arr.ndim else 1))
        pieces = np.array_split(arr, shards, axis=0) if arr.ndim else [arr]
        manifest["leaves"][name] = dict(
            shape=list(arr.shape), dtype=str(arr.dtype), shards=shards,
            shard_shapes=[list(p.shape) for p in pieces])
        for i, piece in enumerate(pieces):
            # raw bytes (not np.save): survives ml_dtypes (bfloat16 etc.)
            with open(os.path.join(tmp, f"{name}.{i}.npz"), "wb") as f:
                f.write(_compress(np.ascontiguousarray(piece).tobytes()))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def _resolve_step(directory: str, step: int | None) -> tuple[str, dict]:
    """Locate a checkpoint directory (latest when step is None) and load its
    manifest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    manifest["step"] = step
    return path, manifest


def _read_leaf(path: str, name: str, meta: dict) -> np.ndarray:
    """Reassemble one leaf from its shard files as a host numpy array."""
    import ml_dtypes  # noqa: F401 — registers bfloat16 et al. with numpy

    dtype = np.dtype(meta["dtype"])
    pieces = []
    for i in range(meta["shards"]):
        with open(os.path.join(path, f"{name}.{i}.npz"), "rb") as f:
            raw = _decompress(f.read())
        pieces.append(np.frombuffer(raw, dtype=dtype).reshape(
            meta["shard_shapes"][i]))
    arr = np.concatenate(pieces, axis=0) if len(pieces) > 1 else pieces[0]
    return arr.reshape(meta["shape"])


def load_checkpoint(directory: str, template: PyTree, step: int | None = None,
                    shardings: PyTree | None = None) -> tuple[PyTree, int]:
    """Restore onto the CURRENT mesh (elastic: any device count/layout).

    ``template`` provides the pytree structure; ``shardings`` (optional,
    matching pytree of NamedSharding) places each leaf — this is the
    elastic-rescale path: the checkpoint's own mesh is irrelevant.
    """
    path, manifest = _resolve_step(directory, step)
    leaves_tpl, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_tpl))
    out = []
    for (pth, tpl), sh in zip(leaves_tpl, shard_leaves):
        arr = _read_leaf(path, _path_str(pth), manifest["leaves"][_path_str(pth)])
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), out), manifest["step"]


def load_checkpoint_arrays(
    directory: str, step: int | None = None
) -> tuple[dict[str, np.ndarray], int, dict]:
    """Template-free restore: every saved leaf as a HOST numpy array.

    The manifest already records each leaf's path string, shape and dtype,
    so flat-dict states (e.g. the streamed HSS build's per-level host
    accumulators) can round-trip without the caller reconstructing a
    template pytree — and without touching a device.  Returns
    ``(arrays, step, extra)`` with ``extra`` the metadata dict passed to
    ``save_checkpoint`` (the streamed build keeps its fingerprint there).
    """
    path, manifest = _resolve_step(directory, step)
    arrays = {name: _read_leaf(path, name, meta)
              for name, meta in manifest["leaves"].items()}
    return arrays, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """Async save + retention + resume."""

    def __init__(self, directory: str, keep: int = 3, n_shards: int = 4):
        self.directory = directory
        self.keep = keep
        self.n_shards = n_shards
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, tree: PyTree, step: int,
                   extra: dict | None = None) -> None:
        self.wait()
        host_tree = _flatten(tree)   # snapshot BEFORE returning control

        def work():
            try:
                packed = {}
                for k, v in host_tree.items():
                    packed[k] = v
                # rebuild a flat dict tree; save_checkpoint re-flattens
                save_checkpoint(self.directory, packed, step,
                                n_shards=self.n_shards, extra=extra)
                self._gc()
            except Exception as e:    # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore(self, template: PyTree, shardings: PyTree | None = None,
                step: int | None = None):
        # Drain any in-flight async save first: a restart immediately after
        # a failure must see the just-written checkpoint, not miss it while
        # the worker thread is still renaming <dir>.tmp into place.  A
        # FAILED save must not kill the recovery path though — the latest
        # complete checkpoint on disk is still valid, so the stored error
        # is left for the next wait() call instead of raised here.
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        return load_checkpoint(self.directory, template, step, shardings)
