"""Checkpointing: manifest + per-leaf shard files, async save, elastic reshard."""

from repro.ckpt.checkpoint import (CheckpointManager, load_checkpoint,
                                   save_checkpoint)

__all__ = ["CheckpointManager", "load_checkpoint", "save_checkpoint"]
