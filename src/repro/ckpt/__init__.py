"""Checkpointing: manifest + per-leaf shard files, async save, elastic reshard."""

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   load_checkpoint, load_checkpoint_arrays,
                                   save_checkpoint)

__all__ = ["CheckpointManager", "latest_step", "load_checkpoint",
           "load_checkpoint_arrays", "save_checkpoint"]
