"""Training driver: LM substrate runs and mesh-parallel SVM training.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --preset tiny \
      --steps 50 --ckpt-dir /tmp/run1

  PYTHONPATH=src python -m repro.launch.train --task svm \
      --svm-train 16384 --svm-c-grid 0.1,1,10

  PYTHONPATH=src python -m repro.launch.train --task krr \
      --svm-train 16384 --svm-c-grid 0.5,2,8

LM presets: tiny (CPU-runnable reduced config), full (the assigned config —
requires the production mesh).  Fault tolerance: checkpoints every
--ckpt-every steps (async), resumes from the latest checkpoint, runs under a
StepGuard deadline, and supports failure-injection drills (--fail-at).

The SVM task drives repro.core.engine.HSSSVMEngine: when more than one
device is visible the whole pipeline (compression, factorization, ADMM
C-grid, bias, holdout scoring) runs node/sample-sharded over a mesh of all
local devices.  --task krr / --task gp run the ADMM-free kernel-ridge / GP
posterior-mean path on the same engine: --svm-c-grid then sweeps the ridge
λ (one cached refactorization + one multi-RHS solve each) and the holdout
metric is RMSE.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_svm(args) -> None:
    from repro.core.compression import CompressionParams
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.data import synthetic

    task = args.task
    dataset = args.svm_dataset
    if task in ("krr", "gp") and dataset == "blobs":
        dataset = "noisy_sine"        # regression demo default
    xtr, ytr, xte, yte = synthetic.train_test(
        dataset, args.svm_train, args.svm_test, seed=0)
    mesh = None
    if jax.device_count() > 1 and not args.svm_local:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        print(f"mesh-parallel build over {jax.device_count()} devices")
    engine = HSSSVMEngine(
        spec=KernelSpec(h=args.svm_h),
        comp=CompressionParams(rank=args.svm_rank, n_near=48, n_far=64),
        leaf_size=args.svm_leaf, max_it=10, mesh=mesh, task=task)
    t0 = time.time()
    rep = engine.prepare(xtr, ytr)
    print(f"prepare: compress {rep.compression_s:.1f}s, factorize "
          f"{rep.factorization_s:.2f}s, HSS {rep.memory_mb:.1f} MB, "
          f"beta {rep.beta:g}")
    c_grid = [float(c) for c in args.svm_c_grid.split(",")]
    yte_j = jnp.asarray(yte)
    knob_name = "λ" if task in ("krr", "gp") else "C"
    for c, model in zip(c_grid, engine.train_grid(c_grid)):
        pred = model.predict(jnp.asarray(xte))
        if task in ("krr", "gp"):
            rmse = float(jnp.sqrt(jnp.mean((pred - yte_j) ** 2)))
            print(f"{knob_name}={c:g}: holdout rmse {rmse:.4f} "
                  f"(admm iters {engine.report.iters_run})")
        else:
            acc = float(jnp.mean(pred == yte_j))
            print(f"{knob_name}={c:g}: holdout acc {acc:.4f}")
    stage = "solve" if task in ("krr", "gp") else "ADMM"
    print(f"done in {time.time() - t0:.1f}s "
          f"({stage} total {engine.report.admm_s:.2f}s across the "
          f"{knob_name} grid)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="lm",
                    choices=["lm", "svm", "krr", "gp"])
    ap.add_argument("--arch", default=None, help="LM arch (required for lm)")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small",
                                                         "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--svm-dataset", default="blobs")
    ap.add_argument("--svm-train", type=int, default=16384)
    ap.add_argument("--svm-test", type=int, default=2048)
    ap.add_argument("--svm-h", type=float, default=1.0)
    ap.add_argument("--svm-c-grid", default="0.1,1,10")
    ap.add_argument("--svm-rank", type=int, default=32)
    ap.add_argument("--svm-leaf", type=int, default=256)
    ap.add_argument("--svm-local", action="store_true",
                    help="force the single-device engine path")
    args = ap.parse_args()

    if args.task in ("svm", "krr", "gp"):
        train_svm(args)
        return
    if args.arch is None:
        ap.error("--arch is required for --task lm")

    from repro.ckpt.checkpoint import CheckpointManager
    from repro.configs.registry import get_config
    from repro.data.tokens import batch_for_config
    from repro.dist import fault
    from repro.models.transformer import Model
    from repro.train import optim
    from repro.train.step import make_train_step

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    elif args.preset == "small":
        cfg = cfg.reduced(n_layers=4, d_model=256, n_heads=8, head_dim=32,
                          d_ff=1024, vocab=2048)
    model = Model(cfg)
    opt_cfg = optim.AdamWConfig(lr=args.lr)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      num_microbatches=args.microbatches))
    injector = fault.FailureInjector(tuple(args.fail_at))
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def build_state():
        params = model.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": optim.adamw_init(params, opt_cfg)}

    template = build_state()

    def one_step(state, step):
        injector.check(step)
        batch = jax.tree.map(
            jnp.asarray,
            batch_for_config(cfg, args.batch, args.seq, step))
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        if step % args.log_every == 0:
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}", flush=True)
        return {"params": params, "opt": opt}

    def save(state, step):
        if manager:
            manager.save_async(state, step)

    def restore():
        if not manager:
            return None
        try:
            state, step = manager.restore(template)
            print(f"resumed from step {step}", flush=True)
            return state, step
        except FileNotFoundError:
            return None

    t0 = time.time()
    state, report = fault.run_resilient(
        args.steps, build_state, one_step, save, restore,
        ckpt_every=args.ckpt_every,
        guard=fault.StepGuard(deadline_s=3600.0),
    )
    if manager:
        manager.wait()
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s, "
          f"restarts={report['restarts']}, "
          f"stragglers={len(report['stragglers'])}")


if __name__ == "__main__":
    main()
