"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --preset tiny \
      --steps 50 --ckpt-dir /tmp/run1

Presets: tiny (CPU-runnable reduced config), full (the assigned config —
requires the production mesh).  Fault tolerance: checkpoints every
--ckpt-every steps (async), resumes from the latest checkpoint, runs under a
StepGuard deadline, and supports failure-injection drills (--fail-at).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data.tokens import batch_for_config
from repro.dist import fault
from repro.models.transformer import Model
from repro.train import optim
from repro.train.step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "small",
                                                         "full"])
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    elif args.preset == "small":
        cfg = cfg.reduced(n_layers=4, d_model=256, n_heads=8, head_dim=32,
                          d_ff=1024, vocab=2048)
    model = Model(cfg)
    opt_cfg = optim.AdamWConfig(lr=args.lr)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      num_microbatches=args.microbatches))
    injector = fault.FailureInjector(tuple(args.fail_at))
    manager = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def build_state():
        params = model.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": optim.adamw_init(params, opt_cfg)}

    template = build_state()

    def one_step(state, step):
        injector.check(step)
        batch = jax.tree.map(
            jnp.asarray,
            batch_for_config(cfg, args.batch, args.seq, step))
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        if step % args.log_every == 0:
            print(f"step {step}: loss={float(metrics['loss']):.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f}", flush=True)
        return {"params": params, "opt": opt}

    def save(state, step):
        if manager:
            manager.save_async(state, step)

    def restore():
        if not manager:
            return None
        try:
            state, step = manager.restore(template)
            print(f"resumed from step {step}", flush=True)
            return state, step
        except FileNotFoundError:
            return None

    t0 = time.time()
    state, report = fault.run_resilient(
        args.steps, build_state, one_step, save, restore,
        ckpt_every=args.ckpt_every,
        guard=fault.StepGuard(deadline_s=3600.0),
    )
    if manager:
        manager.wait()
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s, "
          f"restarts={report['restarts']}, "
          f"stragglers={len(report['stragglers'])}")


if __name__ == "__main__":
    main()
