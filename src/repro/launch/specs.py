"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation anywhere: params/optimizer/cache shapes come from
``jax.eval_shape`` over the real constructors, inputs are explicit
ShapeDtypeStructs.  Shardings are produced by repro.dist.sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.train import optim
from repro.train.step import make_train_step


@dataclasses.dataclass
class Cell:
    """A lowered-able unit: fn(*args) with shardings aligned to args."""
    fn: Callable
    arg_shapes: tuple
    in_shardings: tuple
    kind: str           # train | prefill | decode


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio_stub":
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim),
                                           jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
            "mask_indices": jax.ShapeDtypeStruct((b, s), jnp.bool_),
        }
    if cfg.frontend == "vision_stub":
        s_txt = s - cfg.n_prefix_tokens
        return {
            "patches": jax.ShapeDtypeStruct(
                (b, cfg.n_prefix_tokens, cfg.frontend_dim), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, s_txt), i32),
            "labels": jax.ShapeDtypeStruct((b, s_txt), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
        "labels": jax.ShapeDtypeStruct((b, s), i32),
    }


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, fsdp: bool = True,
               step_kwargs: dict | None = None) -> Cell:
    from repro.dist import sharding as shd

    model = Model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = shd.param_shardings(params_shapes, mesh, fsdp=fsdp)

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(optim.adamw_init, params_shapes)
        opt_sh = shd.opt_shardings(opt_shapes, params_sh, mesh)
        batch = batch_specs(cfg, shape)
        batch_sh = shd.batch_shardings(batch, mesh)
        step = make_train_step(model, **(step_kwargs or {}))
        return Cell(step, (params_shapes, opt_shapes, batch),
                    (params_sh, opt_sh, batch_sh), "train")

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape)
        batch.pop("labels", None)
        batch.pop("mask_indices", None)
        batch_sh = shd.batch_shardings(batch, mesh)
        if cfg.family == "encoder":
            # encoder "prefill" = full forward (DESIGN.md §5)
            fn = model.forward_logits
        else:
            fn = lambda params, b: model.prefill(params, b, shape.seq_len)
        return Cell(fn, (params_shapes, batch), (params_sh, batch_sh),
                    "prefill")

    # decode: one new token against a cache of seq_len
    b = shape.global_batch
    cache_shapes = jax.eval_shape(
        lambda: Model(cfg).cache_init(b, shape.seq_len))
    cache_sh = shd.cache_shardings(cache_shapes, mesh, batch=b)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tokens_sh = shd.batch_shardings(tokens, mesh)
    return Cell(model.decode_step, (params_shapes, cache_shapes, tokens),
                (params_sh, cache_sh, tokens_sh), "decode")
