import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch svm-hss-admm --shape admm_grid
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl

Per cell it records compiled.memory_analysis() (proves the memory plan),
cost_analysis() FLOPs/bytes, and the collective schedule parsed from the
optimized HLO — the inputs to EXPERIMENTS.md §Roofline.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax

from repro.configs.registry import get_config, list_archs
from repro.configs.shapes import SHAPES, cell_status
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as ra

SVM_ARCH = "svm-hss-admm"


def run_cell(arch: str, shape_name: str, multi_pod: bool, fsdp: bool = True,
             overrides: dict | None = None,
             step_kwargs: dict | None = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16", n_devices=n_dev,
               fsdp=fsdp)
    if overrides:
        rec["overrides"] = {k: str(v) for k, v in overrides.items()}
    t0 = time.time()

    from repro.dist.api import use_mesh

    if arch == SVM_ARCH:
        from repro.core.distributed import build_svm_cell

        fn, shapes, in_sh = build_svm_cell(mesh)
        with use_mesh(mesh), mesh:
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*shapes)
            compiled = lowered.compile()
        cfg = None
    else:
        from repro.launch.specs import build_cell

        cfg = get_config(arch, **(overrides or {}))
        shape = SHAPES[shape_name]
        ok, why = cell_status(cfg, shape)
        if not ok:
            rec.update(status="skipped", reason=why)
            return rec
        if step_kwargs:
            rec["step_kwargs"] = {k: str(v) for k, v in step_kwargs.items()}
        with use_mesh(mesh), mesh:
            cell = build_cell(cfg, shape, mesh, fsdp=fsdp,
                              step_kwargs=step_kwargs)
            # decode: donate the cache so in-place KV/state updates alias
            # their input buffers instead of copying (§Perf change B2)
            donate = (1,) if cell.kind == "decode" else ()
            lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                              donate_argnums=donate
                              ).lower(*cell.arg_shapes)
            compiled = lowered.compile()
        rec["kind"] = cell.kind

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    # Loop-corrected totals (cost_analysis counts while bodies once —
    # verified in tests/test_roofline.py); raw values kept for reference.
    from repro.roofline import hlo_cost

    cost = hlo_cost.xla_cost_analysis(compiled)

    corrected = hlo_cost.analyze(hlo)
    coll = dict(
        operand_bytes=corrected["collective_bytes"],
        ring_bytes=corrected["collective_ring_bytes"],
        per_op=corrected["collective_per_op"],
        n_collectives=corrected["n_collectives"],
    )
    roof = ra.roofline_report(
        dict(flops=corrected["flops"], **{"bytes accessed": corrected["bytes"]}),
        coll)
    roof["raw_cost_analysis_flops"] = float(cost.get("flops", 0.0) or 0.0)
    roof["loop_multipliers"] = corrected["computation_multipliers"]

    # Pallas-kernel projection: the XLA fallback attention/SSD chunk loops
    # stream every softmax/gate block through HBM; the validated Pallas
    # kernels (kernels/attention, kernels/ssd) keep them in VMEM.  Replace
    # the inner-loop bucket with the kernels' true IO to get the TPU-target
    # memory term (EXPERIMENTS.md §Perf).
    if cfg is not None:
        n_layers = cfg.n_layers
        inner = sum(v for k, v in corrected["bytes_by_mult"].items()
                    if k > n_layers)
        shape = SHAPES[shape_name]
        passes = 3.5 if shape.kind == "train" else 1.0
        b_, s_ = shape.global_batch, shape.seq_len
        io = 0.0
        if shape.kind == "decode":
            # one token: the unavoidable IO is one KV-cache read per layer
            io = n_layers * b_ * s_ * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        else:
            if cfg.family in ("dense", "moe", "encoder", "vlm", "hybrid"):
                io += (passes * n_layers * b_ * s_ *
                       (2 * cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim
                       * 2)
            if cfg.family in ("ssm", "hybrid"):
                io += (passes * n_layers * b_ * s_ *
                       (2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state)
                       * 4)
        io_per_dev = io / n_dev
        proj_bytes = roof["bytes_per_device"] - inner + io_per_dev
        roof["t_memory_projected_pallas_s"] = proj_bytes / ra.HW().hbm_bw
        roof["inner_loop_bytes"] = inner
        roof["projected_kernel_io_bytes"] = io_per_dev
    rec.update(
        status="ok",
        compile_s=round(time.time() - t0, 1),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
            total_per_device=(mem.argument_size_in_bytes
                              + mem.output_size_in_bytes
                              + mem.temp_size_in_bytes),
        ),
        collectives=coll,
        roofline=roof,
    )
    if cfg is not None and shape_name in SHAPES:
        shape = SHAPES[shape_name]
        if shape.kind == "train":
            mf = ra.model_flops_train(cfg, shape)
            rec["model_flops_global"] = mf
            hlo_global = roof["flops_per_device"] * n_dev
            rec["model_vs_hlo_flops"] = mf / hlo_global if hlo_global else 0.0
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (int/float/str)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-dtype", default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
        cells.append((SVM_ARCH, "admm_grid"))
    else:
        cells.append((args.arch, args.shape))
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    step_kwargs = {}
    if args.microbatches > 1:
        step_kwargs["num_microbatches"] = args.microbatches
    if args.grad_dtype:
        step_kwargs["grad_dtype"] = args.grad_dtype

    out_f = open(args.out, "a") if args.out else None
    n_fail = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                rec = run_cell(arch, shape, mp, fsdp=not args.no_fsdp,
                               overrides=overrides or None,
                               step_kwargs=step_kwargs or None)
            except Exception as e:   # noqa: BLE001 — record and continue
                rec = dict(arch=arch, shape=shape,
                           mesh="2x16x16" if mp else "16x16",
                           status="error", error=f"{type(e).__name__}: {e}",
                           trace=traceback.format_exc()[-2000:])
                n_fail += 1
            line = json.dumps(rec)
            print(line, flush=True)
            if out_f:
                out_f.write(line + "\n")
                out_f.flush()
    if out_f:
        out_f.close()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
