"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips; the
"pod" axis composes with "data" for batch/FSDP sharding (DCI collectives),
"model" stays intra-pod (ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CI tests (requires >= n_data*n_model local devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
