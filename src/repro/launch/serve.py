"""Serving driver: batched prefill + decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --preset tiny \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.transformer import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_prefix_tokens,
                             cfg.frontend_dim)), jnp.float32)
        max_len += cfg.n_prefix_tokens

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    generated = []
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        generated.append(np.asarray(nxt)[:, 0])
        logits, cache = decode(params, cache, nxt)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    toks = np.stack(generated, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms")
    print(f"decode: {args.gen} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f}ms "
          f"({args.gen*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", toks[0][:12].tolist())


if __name__ == "__main__":
    main()
