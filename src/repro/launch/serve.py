"""Serving driver: batched LM prefill+decode, or kernel box-QP scoring.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --preset tiny \
      --batch 4 --prompt-len 32 --gen 16

  PYTHONPATH=src python -m repro.launch.serve --task svm \
      --svm-classes 4 --svm-train 8192 --batch 256 --requests 50

  PYTHONPATH=src python -m repro.launch.serve --task svr --batch 256
  PYTHONPATH=src python -m repro.launch.serve --task oneclass --batch 256
  PYTHONPATH=src python -m repro.launch.serve --task krr --batch 256

The kernel paths train their model on ONE shared HSS factorization via the
unified engine (repro.core.engine.HSSSVMEngine; pass --svm-mesh to build
and serve sharded over all local devices), then serve score/predict
requests through the serving tier (``repro.serve``): ``ServingEngine.score``
is the one scoring entry point for every task decode, ``--registry DIR``
round-trips the trained model through the persistent versioned registry
(``--prune-tol`` applies the SV-pruning load transform), and
``--serve-dtype bfloat16`` switches the score path to bf16 block evaluation
with f32 accumulation.  ``--task svm`` is k-class classification; ``--task
svr`` serves ε-SVR regression values on the noisy-sine generator; ``--task
oneclass`` serves ν one-class novelty scores on blobs-with-outliers (the
knobs are --svm-eps / --svm-nu); ``--task krr`` / ``--task gp`` serve kernel
ridge / GP posterior-mean regression values trained by ONE multi-RHS solve
with zero ADMM iterations (the knob is --svm-lam, the ridge/noise λ).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_lm(args) -> None:
    from repro.configs.registry import get_config
    from repro.models.transformer import Model

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.reduced()
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_prefix_tokens,
                             cfg.frontend_dim)), jnp.float32)
        max_len += cfg.n_prefix_tokens

    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    generated = []
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen):
        generated.append(np.asarray(nxt)[:, 0])
        logits, cache = decode(params, cache, nxt)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    toks = np.stack(generated, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} in {t_prefill*1e3:.1f}ms")
    print(f"decode: {args.gen} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f}ms "
          f"({args.gen*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", toks[0][:12].tolist())


def serve_svm(args) -> None:
    from repro.core.compression import CompressionParams
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec
    from repro.data import synthetic

    task = args.task
    n_test = max(args.batch, 512)
    # --svm-h default is task-appropriate for the built-in demo dataset;
    # an explicit value always wins.
    if task == "svr":
        xtr, ytr, xte, yte = synthetic.train_test(
            "noisy_sine", n_train=args.svm_train, n_test=n_test, seed=0,
            noise=0.1)
        knob, h = args.svm_eps, 1.0 if args.svm_h is None else args.svm_h
    elif task in ("krr", "gp"):
        xtr, ytr, xte, yte = synthetic.train_test(
            "noisy_sine", n_train=args.svm_train, n_test=n_test, seed=0,
            noise=0.1)
        knob, h = args.svm_lam, 1.0 if args.svm_h is None else args.svm_h
    elif task == "oneclass":
        xtr, ytr = synthetic.blobs_with_outliers(
            args.svm_train, n_features=4, outlier_frac=0.1, seed=0)
        xte, yte = synthetic.blobs_with_outliers(
            n_test, n_features=4, outlier_frac=0.1, seed=1)
        knob, h = args.svm_nu, 2.0 if args.svm_h is None else args.svm_h
    else:
        xtr, ytr, xte, yte = synthetic.train_test(
            "multiclass_blobs", n_train=args.svm_train, n_test=n_test,
            seed=0, n_classes=args.svm_classes, sep=3.0)
        knob, h = args.svm_c, 1.5 if args.svm_h is None else args.svm_h

    mesh = None
    if args.svm_mesh and jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        print(f"mesh-parallel build over {jax.device_count()} devices")

    t0 = time.time()
    engine = HSSSVMEngine(
        spec=KernelSpec(h=h),
        comp=CompressionParams(rank=32, n_near=48, n_far=64),
        leaf_size=256, max_it=30 if task == "oneclass" else 10,
        mesh=mesh, task=task, svr_c=args.svm_c)
    model = engine.fit(xtr, None if task == "oneclass" else ytr,
                       c_value=knob)
    t_train = time.time() - t0
    pred = model.predict(jnp.asarray(xte))
    if task == "svr":
        quality = (f"holdout rmse "
                   f"{float(jnp.sqrt(jnp.mean((pred - yte) ** 2))):.4f}")
        head = f"ε-SVR (ε={knob})"
    elif task in ("krr", "gp"):
        quality = (f"holdout rmse "
                   f"{float(jnp.sqrt(jnp.mean((pred - yte) ** 2))):.4f}, "
                   f"admm iters {engine.report.iters_run}")
        name = "KRR" if task == "krr" else "GP mean"
        head = f"{name} (λ={knob})"
    elif task == "oneclass":
        from repro.core.tasks import oneclass_metrics

        m = oneclass_metrics(pred, yte)
        quality = (f"outlier precision {m['precision']:.3f} / recall "
                   f"{m['recall']:.3f}")
        head = f"one-class SVM (ν={knob})"
    else:
        acc = float(jnp.mean(pred == jnp.asarray(yte)))
        quality = f"holdout acc {acc:.4f}"
        head = f"{args.svm_classes}-class SVM (C={knob})"
    rep = engine.report
    print(f"trained {head} on {args.svm_train} pts "
          f"in {t_train:.1f}s (compress {rep.compression_s:.1f}s / factor "
          f"{rep.factorization_s:.2f}s / batched ADMM {rep.admm_s:.2f}s), "
          f"{quality}")

    # Request loop through the serving tier: ONE scoring entry point
    # (ServingEngine.score) covers all four task decodes — no per-task
    # closures here.  --registry round-trips the model through the
    # persistent registry first (optionally SV-pruned on load).
    from repro.serve import BatchPolicy, ModelRegistry, ServingEngine

    registry = None
    if args.registry:
        registry = ModelRegistry(args.registry)
        version = registry.save(task, model)
        print(f"registered model {task!r} v{version} under {args.registry}")
    serve = ServingEngine(
        policy=BatchPolicy(compute_dtype=args.serve_dtype), registry=registry)
    if registry is not None:
        mid = serve.load(task, prune_tol=args.prune_tol)
    else:
        mid = serve.add_model(model)

    rng = np.random.default_rng(1)
    serve.score(mid, xte[: args.batch])               # compile outside timing

    t_serve = time.time()
    for _ in range(args.requests):
        idx = rng.integers(0, xte.shape[0], size=args.batch)
        _scores, pred = serve.score(mid, xte[idx])
    t_serve = time.time() - t_serve
    lat_ms = np.sort(np.array(serve.drain_latencies())[-args.requests:]) * 1e3
    qps = args.requests * args.batch / max(t_serve, 1e-9)
    per_pass = (f"{args.svm_classes} classes" if task == "svm"
                else {"svr": "regression values",
                      "krr": "regression values",
                      "gp": "posterior means",
                      "oneclass": "novelty scores"}[task])
    print(f"served {args.requests} requests x batch {args.batch}: "
          f"{qps:.0f} points/s, latency p50 {lat_ms[len(lat_ms)//2]:.2f}ms "
          f"p95 {lat_ms[int(len(lat_ms)*0.95)-1]:.2f}ms "
          f"({per_pass} per pass)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="lm",
                    choices=["lm", "svm", "svr", "oneclass", "krr", "gp"])
    ap.add_argument("--arch", default=None, help="LM arch (required for lm)")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--svm-classes", type=int, default=4)
    ap.add_argument("--svm-train", type=int, default=8192)
    ap.add_argument("--svm-h", type=float, default=None,
                    help="kernel bandwidth (default: per-task demo value "
                         "1.5 svm / 1.0 svr / 2.0 oneclass)")
    ap.add_argument("--svm-c", type=float, default=1.0,
                    help="C (svm); the SVR box bound (svr)")
    ap.add_argument("--svm-eps", type=float, default=0.1,
                    help="ε tube half-width (task svr)")
    ap.add_argument("--svm-nu", type=float, default=0.1,
                    help="ν outlier-fraction bound (task oneclass)")
    ap.add_argument("--svm-lam", type=float, default=1.0,
                    help="ridge / GP noise λ (tasks krr and gp)")
    ap.add_argument("--svm-mesh", action="store_true",
                    help="mesh-parallel HSS build/serve over all local "
                         "devices (core.engine.HSSSVMEngine)")
    ap.add_argument("--registry", default=None,
                    help="model-registry root: save the trained model there "
                         "and serve it back through the registry")
    ap.add_argument("--prune-tol", type=float, default=None,
                    help="SV-pruning tolerance applied on registry load")
    ap.add_argument("--serve-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="serving-tier kernel block compute dtype")
    args = ap.parse_args()

    if args.task in ("svm", "svr", "oneclass", "krr", "gp"):
        serve_svm(args)
    else:
        if args.arch is None:
            ap.error("--arch is required for --task lm")
        serve_lm(args)


if __name__ == "__main__":
    main()
