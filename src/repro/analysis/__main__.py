"""CLI: ``python -m repro.analysis [paths...] [--check] [--write-baseline]``.

Modes
-----
default           AST lint (layer 1) over src/repro (or explicit paths),
                  suppressions applied from the baseline file.
--check           lint + the trace-level checks (layer 2) — the CI gate.
--write-baseline  lint, then (re)write the baseline from what it found;
                  edit the generated ``reason`` fields before committing.
--rules           print the rule table and exit.

Exit codes: 0 clean, 1 findings, 2 bad invocation / internal error.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import baseline as baseline_mod
from repro.analysis.lint import lint_paths
from repro.analysis.rules import ALL_RULES


def _print_rules() -> None:
    width = max(len(r.NAME) for r in ALL_RULES)
    for r in ALL_RULES:
        print(f"{r.NAME:<{width}}  {r.DESCRIPTION}")
        print(f"{'':<{width}}  scope: {', '.join(r.SCOPE)}")


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX/Pallas static analysis: precision, host-sync, "
                    "retrace, PRNG, and tracer-branch lints plus "
                    "trace-level (jaxpr) hot-path checks.")
    parser.add_argument("paths", nargs="*",
                        help="files/dirs to lint (default: src/repro)")
    parser.add_argument("--check", action="store_true",
                        help="also run the trace-level checks (CI gate)")
    parser.add_argument("--baseline", default=baseline_mod.DEFAULT_BASELINE,
                        help="suppression file (default: %(default)s)")
    parser.add_argument("--no-baseline", action="store_true",
                        help="report every finding, ignoring suppressions")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write the current findings to the baseline")
    parser.add_argument("--rules", action="store_true",
                        help="list the lint rules and exit")
    args = parser.parse_args(argv)

    if args.rules:
        _print_rules()
        return 0

    try:
        findings = lint_paths(args.paths or None)
    except (OSError, SyntaxError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline:
        entries = baseline_mod.from_findings(findings)
        baseline_mod.dump(entries, args.baseline)
        print(f"wrote {len(entries)} suppression(s) to {args.baseline} — "
              "fill in the reason fields")
        return 0

    try:
        entries = [] if args.no_baseline else baseline_mod.load(args.baseline)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    new, suppressed, stale = baseline_mod.partition(findings, entries)

    if args.check:
        from repro.analysis import jaxpr_check
        try:
            new.extend(jaxpr_check.run_all())
        except Exception as exc:  # a crashed trace is itself a failure
            print(f"error: trace-level checks crashed: {exc}",
                  file=sys.stderr)
            return 2

    for f in new:
        print(f.render())
    for e in stale:
        print(f"warning: stale baseline entry (nothing matches): "
              f"[{e['rule']}] {e['path']}: {e['line_content']!r}",
              file=sys.stderr)
    n_sup = len(suppressed)
    tail = f" ({n_sup} suppressed by baseline)" if n_sup else ""
    if new:
        print(f"\n{len(new)} finding(s){tail}")
        return 1
    print(f"clean{tail}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
