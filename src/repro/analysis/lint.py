"""Layer 1 driver: discover files, run the AST rules, apply suppressions.

Inline suppression syntax (on the flagged line or the line directly above):

    kz = risky_einsum(...)   # lint: disable=precision-accumulate

Multiple rules: ``# lint: disable=rule-a,rule-b``.  Repo-wide exceptions
with a justification belong in ``analysis/baseline.toml`` instead
(see repro.analysis.baseline).
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable, Sequence

from repro.analysis.findings import Finding
from repro.analysis.rules import ALL_RULES

# default scan roots, repo-relative; benchmarks/examples are host-side
# driver scripts with no traced hot paths
DEFAULT_ROOTS = ("src/repro",)

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([\w,\- ]+)")


def repo_root(start: str | None = None) -> str:
    """Nearest ancestor containing a .git dir (or cwd as fallback)."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        if os.path.isdir(os.path.join(cur, ".git")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start or os.getcwd())
        cur = parent


def iter_python_files(roots: Iterable[str], base: str) -> list[str]:
    out: list[str] = []
    for root in roots:
        abs_root = os.path.join(base, root)
        if os.path.isfile(abs_root):
            out.append(abs_root)
            continue
        for dirpath, dirnames, filenames in os.walk(abs_root):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(filenames) if f.endswith(".py"))
    return out


def _disabled_rules(lines: list[str], lineno: int) -> set[str]:
    rules: set[str] = set()
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines):
            m = _DISABLE_RE.search(lines[ln - 1])
            if m:
                rules.update(r.strip() for r in m.group(1).split(","))
    return rules


def lint_file(abs_path: str, rel_path: str,
              explicit: bool = False) -> list[Finding]:
    """Run every applicable rule on one file."""
    with open(abs_path, encoding="utf-8") as fh:
        src = fh.read()
    try:
        tree = ast.parse(src, filename=rel_path)
    except SyntaxError as exc:
        return [Finding(rule="parse-error", path=rel_path,
                        line=exc.lineno or 0,
                        message=f"file does not parse: {exc.msg}",
                        line_content="")]
    lines = src.splitlines()
    findings: list[Finding] = []
    for rule in ALL_RULES:
        if not explicit and not any(
                rel_path.startswith(p) for p in rule.SCOPE):
            continue
        for f in rule.check(rel_path, tree, lines):
            if f.rule in _disabled_rules(lines, f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def lint_paths(paths: Sequence[str] | None = None,
               base: str | None = None) -> list[Finding]:
    """Lint explicit ``paths`` (all rules) or the default roots (scoped)."""
    base = base or repo_root()
    explicit = bool(paths)
    roots = paths or DEFAULT_ROOTS
    findings: list[Finding] = []
    for abs_path in iter_python_files(roots, base):
        rel = os.path.relpath(abs_path, base).replace(os.sep, "/")
        findings.extend(lint_file(abs_path, rel, explicit=explicit))
    return findings
