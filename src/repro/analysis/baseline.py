"""Baseline (suppression) file: pre-existing, justified findings.

``analysis/baseline.toml`` pins the set of findings that predate the
analyzer or are deliberate; the CI gate then fails only on NEW violations.
Every entry must carry a ``reason`` — an unjustified suppression is itself
an error.  Entries match on (rule, path, stripped source line), NOT line
numbers, so unrelated edits above a suppressed site don't invalidate it.

The container's Python (3.10) has no ``tomllib`` and the repo adds no
dependencies, so this module reads/writes the small TOML subset the file
uses: ``[[suppress]]`` table arrays of string keys.
"""
from __future__ import annotations

import os

from repro.analysis.findings import Finding

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.toml")


def _unquote(raw: str, path: str, lineno: int) -> str:
    raw = raw.strip()
    if len(raw) < 2 or raw[0] != '"' or raw[-1] != '"':
        raise ValueError(f"{path}:{lineno}: expected a quoted string, "
                         f"got {raw!r}")
    out, i, body = [], 0, raw[1:-1]
    while i < len(body):
        ch = body[i]
        if ch == "\\" and i + 1 < len(body):
            nxt = body[i + 1]
            out.append({"n": "\n", "t": "\t", '"': '"', "\\": "\\"}.get(
                nxt, nxt))
            i += 2
        else:
            out.append(ch)
            i += 1
    return "".join(out)


def _quote(s: str) -> str:
    return '"' + s.replace("\\", "\\\\").replace('"', '\\"') + '"'


def load(path: str = DEFAULT_BASELINE) -> list[dict]:
    """Parse the [[suppress]] entries (TOML subset; see module docstring)."""
    if not os.path.exists(path):
        return []
    entries: list[dict] = []
    current: dict | None = None
    with open(path, encoding="utf-8") as fh:
        for lineno, raw in enumerate(fh, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if line == "[[suppress]]":
                current = {}
                entries.append(current)
            elif "=" in line and current is not None:
                key, _, val = line.partition("=")
                current[key.strip()] = _unquote(val, path, lineno)
            else:
                raise ValueError(
                    f"{path}:{lineno}: unsupported baseline syntax {line!r} "
                    "(only [[suppress]] tables of string keys)")
    for i, e in enumerate(entries):
        for req in ("rule", "path", "line_content", "reason"):
            if not e.get(req):
                raise ValueError(
                    f"{path}: suppress entry #{i + 1} is missing {req!r} — "
                    "every suppression needs a justification")
    return entries


def dump(entries: list[dict], path: str = DEFAULT_BASELINE) -> None:
    lines = [
        "# repro.analysis baseline — pre-existing, JUSTIFIED findings.",
        "# The CI gate (python -m repro.analysis --check) fails only on",
        "# findings absent from this file.  Match key: (rule, path,",
        "# stripped source line); every entry must state a reason.",
        "",
    ]
    for e in entries:
        lines.append("[[suppress]]")
        for key in ("rule", "path", "line_content", "reason"):
            lines.append(f"{key} = {_quote(e.get(key, ''))}")
        lines.append("")
    with open(path, "w", encoding="utf-8") as fh:
        fh.write("\n".join(lines))


def partition(findings: list[Finding], entries: list[dict]
              ) -> tuple[list[Finding], list[Finding], list[dict]]:
    """Split findings into (new, suppressed); also return stale entries
    that matched nothing (fixed code whose suppression should be dropped)."""
    new: list[Finding] = []
    suppressed: list[Finding] = []
    hit = [False] * len(entries)
    for f in findings:
        match = None
        for i, e in enumerate(entries):
            if (e["rule"] == f.rule and e["path"] == f.path
                    and e["line_content"] == f.line_content):
                match = i
                break
        if match is None:
            new.append(f)
        else:
            hit[match] = True
            suppressed.append(f)
    stale = [e for i, e in enumerate(entries) if not hit[i]]
    return new, suppressed, stale


def from_findings(findings: list[Finding],
                  reason: str = "TODO: justify or fix") -> list[dict]:
    entries, seen = [], set()
    for f in findings:
        key = (f.rule, f.path, f.line_content)
        if key in seen:
            continue
        seen.add(key)
        entries.append(dict(rule=f.rule, path=f.path,
                            line_content=f.line_content, reason=reason))
    return entries
