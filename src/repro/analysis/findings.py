"""The one shared finding record both analysis layers emit."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: AST lint findings carry a source location, trace-level
    findings (jaxpr_check) carry line 0 and the traced target as ``path``."""

    rule: str          # rule / check name, e.g. "precision-accumulate"
    path: str          # repo-relative file path (or trace target name)
    line: int          # 1-based source line (0 for trace-level findings)
    message: str       # what is wrong and what the fix convention is
    line_content: str  # stripped source line — the stable baseline match key

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        out = f"{loc}: [{self.rule}] {self.message}"
        if self.line_content:
            out += f"\n    {self.line_content}"
        return out
