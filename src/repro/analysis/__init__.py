"""repro.analysis — static analysis for the HSS-ADMM codebase.

Two layers guard the invariants the paper's wall-clock/accuracy claims
rest on (see README "Static analysis"):

  * Layer 1 (AST lint, :mod:`repro.analysis.lint` + ``rules/``): custom
    syntax-level rules — f32 accumulation in hot-path contractions,
    no host syncs inside traced code, the traced-scalar knob convention,
    PRNG key discipline, no Python branches on tracers.
  * Layer 2 (trace-level, :mod:`repro.analysis.jaxpr_check`):
    ``jax.make_jaxpr`` over the real hot paths asserting no dtype
    downcasts inside accumulation chains, no host callbacks, exactly one
    compile across a warm-started C-grid sweep, and (under a mesh) that
    every HSS factor's placement conforms to
    ``repro.dist.api.node_partition_spec``.

Run ``python -m repro.analysis --check`` for both layers; pre-existing,
justified exceptions live in ``analysis/baseline.toml``.
"""
from repro.analysis.findings import Finding
from repro.analysis.lint import lint_paths, repo_root

__all__ = ["Finding", "lint_paths", "repo_root"]
