"""prng-key-reuse: a PRNGKey is consumed at most once without a split.

Reusing a key makes "independent" samples identical — proxy-point sampling
and synthetic-data generation silently correlate, which corrupts the ID
sampling quality the adaptive-rank compression leans on.  The sanctioned
pattern is ``key, sub = jax.random.split(key)`` (the reassignment makes the
name live again) or indexing distinct rows of a ``jax.random.split(key, n)``
batch.

Scope-local, order-approximate analysis: keys are names assigned from
``jax.random.PRNGKey/key/split/fold_in``; passing one to any call consumes
it (``fold_in``/``key_data`` excepted — deriving is not consuming); ``if``
branches are analyzed independently (consuming the same key in exclusive
branches is fine); loop bodies are analyzed twice so loop-carried reuse is
caught.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules import _common

NAME = "prng-key-reuse"
DESCRIPTION = "PRNGKey consumed twice without an intervening split"
SCOPE = ("src/repro",)

_PRODUCERS = {"PRNGKey", "key", "split", "wrap_key_data", "fold_in"}
_NON_CONSUMING = {"fold_in", "key_data", "clone"}

_LIVE = "live"


def _is_key_producer(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and _common.attr_name(node.func) in _PRODUCERS
            and _common.root_name(node.func) in ("jax", "random", "jrandom",
                                                 "jr"))


def _key_expr(node: ast.AST, state: dict) -> str | None:
    """Resolve an expression to a tracked key id ("key" or "keys[0]")."""
    if isinstance(node, ast.Name) and node.id in state:
        return node.id
    if (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Name)):
        base = node.value.id
        if base not in state:
            return None
        idx = node.slice
        if isinstance(idx, ast.Constant) and isinstance(idx.value, int):
            composite = f"{base}[{idx.value}]"
            state.setdefault(composite, (_LIVE, node.lineno))
            return composite
    return None


class _Scope:
    def __init__(self, path: str, lines: list[str]):
        self.path = path
        self.lines = lines
        self.findings: list[Finding] = []
        self._seen: set[tuple[int, str]] = set()

    # -------------------------------------------------------------- #
    def _consume(self, expr: ast.AST, state: dict) -> None:
        for node in ast.walk(expr):
            if not isinstance(node, ast.Call):
                continue
            fname = _common.attr_name(node.func)
            if fname in _NON_CONSUMING or fname in ("PRNGKey", "key"):
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                kid = _key_expr(arg, state)
                if kid is None:
                    continue
                status, line = state[kid]
                if status == _LIVE:
                    state[kid] = ("consumed", node.lineno)
                elif (node.lineno, kid) not in self._seen:
                    self._seen.add((node.lineno, kid))
                    self.findings.append(Finding(
                        rule=NAME, path=self.path, line=node.lineno,
                        message=(f"PRNGKey {kid!r} already consumed at line "
                                 f"{line} — split it first "
                                 "(key, sub = jax.random.split(key)) so "
                                 "samples stay independent"),
                        line_content=self.lines[node.lineno - 1].strip(),
                    ))

    def _assign_targets(self, targets, value, state: dict) -> None:
        is_key = _is_key_producer(value)
        names = []
        for tgt in targets:
            if isinstance(tgt, ast.Name):
                names.append(tgt.id)
            elif isinstance(tgt, (ast.Tuple, ast.List)):
                names.extend(e.id for e in tgt.elts
                             if isinstance(e, ast.Name))
        for name in names:
            if is_key:
                state[name] = (_LIVE, value.lineno)
                # a rebound collection invalidates stale per-index entries
                for k in [k for k in state if k.startswith(f"{name}[")]:
                    del state[k]
            elif name in state:
                del state[name]

    # -------------------------------------------------------------- #
    def walk(self, stmts, state: dict) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue                      # separate scope
            if isinstance(stmt, ast.If):
                self._consume(stmt.test, state)
                s1, s2 = dict(state), dict(state)
                self.walk(stmt.body, s1)
                self.walk(stmt.orelse, s2)
                for k in set(s1) | set(s2):
                    a, b = s1.get(k), s2.get(k)
                    state[k] = (a if a and a[0] != _LIVE else b) or a or b
                state.update({k: v for k, v in state.items() if v})
            elif isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    self._consume(stmt.iter, state)
                else:
                    self._consume(stmt.test, state)
                self.walk(stmt.body, state)
                self.walk(stmt.body, state)   # loop-carried reuse
                self.walk(stmt.orelse, state)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._consume(item.context_expr, state)
                self.walk(stmt.body, state)
            elif isinstance(stmt, (ast.Try,)):
                self.walk(stmt.body, state)
                for h in stmt.handlers:
                    self.walk(h.body, dict(state))
                self.walk(stmt.finalbody, state)
            elif isinstance(stmt, ast.Assign):
                self._consume(stmt.value, state)
                self._assign_targets(stmt.targets, stmt.value, state)
            elif isinstance(stmt, ast.AugAssign):
                self._consume(stmt.value, state)
            elif isinstance(stmt, (ast.Expr, ast.Return)):
                if stmt.value is not None:
                    self._consume(stmt.value, state)
            else:
                for child in ast.iter_child_nodes(stmt):
                    if isinstance(child, ast.expr):
                        self._consume(child, state)


def check(path: str, tree: ast.AST, lines: list[str]) -> list[Finding]:
    scopes: list = [tree]
    scopes += [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    findings: list[Finding] = []
    for scope in scopes:
        sc = _Scope(path, lines)
        sc.walk(scope.body, {})
        findings.extend(sc.findings)
    return findings
