"""python-branch-on-tracer: no Python control flow on traced values.

Inside a traced function body, ``if``/``while``/``assert`` on a value that
derives from a traced argument raises ``TracerBoolConversionError`` at
trace time at best; at worst (when the branch happens to see a concrete
value during tracing, e.g. after a stray host sync) it silently BAKES one
branch into the compiled program — the other branch is gone for every
later call.  Use ``jnp.where`` / ``lax.cond`` / ``lax.select`` instead.

Trace-time-static tests are exempt: ``is None`` / ``is not None``,
``isinstance(...)``, and ``.shape`` / ``.ndim`` / ``.dtype`` / ``.size``
attribute probes — those resolve while tracing and are the sanctioned way
to specialize a traced function on structure.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules import _common

NAME = "python-branch-on-tracer"
DESCRIPTION = "Python if/while/assert on a traced value inside a traced body"
SCOPE = ("src/repro",)

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type", "sharding",
                 "levels", "leaf_size"}
_TRACED_ROOTS = {"jnp", "jax", "lax", "nn"}


def _params(fn: ast.AST) -> set[str]:
    args = fn.args
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return set(names)


def _tracerish_names(fn: ast.AST) -> set[str]:
    """Params of the traced fn (minus static_argnames/nums) + locals
    assigned from jnp/jax expressions or from expressions referencing an
    already-tracerish name.  Assignments whose value is structurally
    static (``b, h, s, d = q.shape``; ``blk = min(256, s)``) stay
    non-tracer even when a tracerish name appears inside."""
    tracerish = _params(fn) - _common.static_params(fn)
    changed = True
    while changed:               # fixpoint over straight-line derivations
        changed = False
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            if _common.is_nontracer_expr(node.value):
                continue
            derives = False
            for sub in ast.walk(node.value):
                if (isinstance(sub, ast.Name)
                        and sub.id in tracerish):
                    derives = True
                elif (isinstance(sub, ast.Call)
                      and _common.root_name(sub.func) in _TRACED_ROOTS):
                    derives = True
            if not derives:
                continue
            for tgt in node.targets:
                tnames = []
                if isinstance(tgt, ast.Name):
                    tnames = [tgt.id]
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    tnames = [e.id for e in tgt.elts
                              if isinstance(e, ast.Name)]
                for name in tnames:
                    if name not in tracerish:
                        tracerish.add(name)
                        changed = True
    return tracerish


def _is_static_test(test: ast.AST) -> bool:
    """Tests that resolve at trace time."""
    if isinstance(test, ast.Compare):
        ops_static = all(isinstance(op, (ast.Is, ast.IsNot))
                         for op in test.ops)
        none_side = any(isinstance(c, ast.Constant) and c.value is None
                        for c in [test.left] + test.comparators)
        if ops_static and none_side:
            return True
    if (isinstance(test, ast.Call)
            and _common.attr_name(test.func) in ("isinstance", "hasattr",
                                                 "callable", "len")):
        return True
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _is_static_test(test.operand)
    if isinstance(test, ast.BoolOp):
        return all(_is_static_test(v) for v in test.values)
    return False


def _traced_name_in_test(test: ast.AST, tracerish: set[str],
                         parents: dict) -> str | None:
    """A tracerish name used non-statically inside the test, if any."""
    for node in ast.walk(test):
        if not (isinstance(node, ast.Name) and node.id in tracerish):
            continue
        # exempt x.shape / x.ndim / ... probes and isinstance(x, ...)
        cur = node
        exempt = False
        while id(cur) in parents:
            parent = parents[id(cur)]
            if (isinstance(parent, ast.Attribute)
                    and parent.attr in _STATIC_ATTRS):
                exempt = True
                break
            if (isinstance(parent, ast.Call)
                    and _common.attr_name(parent.func)
                    in ("isinstance", "len", "hasattr")):
                exempt = True
                break
            if (isinstance(parent, ast.Compare)
                    and all(isinstance(op, (ast.Is, ast.IsNot))
                            for op in parent.ops)):
                exempt = True
                break
            if parent is test:
                break
            cur = parent
        if not exempt:
            return node.id
    return None


def check(path: str, tree: ast.AST, lines: list[str]) -> list[Finding]:
    findings = []
    seen: set[int] = set()
    for fn in _common.traced_functions(tree):
        tracerish = _tracerish_names(fn)
        parents = _common.build_parent_map(fn)
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                test, kind = node.test, ("while" if isinstance(node, ast.While)
                                         else "if")
            elif isinstance(node, ast.Assert):
                test, kind = node.test, "assert"
            elif isinstance(node, ast.IfExp):
                test, kind = node.test, "conditional expression"
            else:
                continue
            if _is_static_test(test):
                continue
            name = _traced_name_in_test(test, tracerish, parents)
            if name is None or test.lineno in seen:
                continue
            seen.add(test.lineno)
            findings.append(Finding(
                rule=NAME, path=path, line=test.lineno,
                message=(f"Python {kind} on {name!r}, which derives from a "
                         "traced value — use jnp.where / lax.cond / "
                         "lax.select so both branches stay in the compiled "
                         "program"),
                line_content=lines[test.lineno - 1].strip(),
            ))
    return findings
