"""Rule registry for the AST lint layer.

Each rule module exposes ``NAME`` (the id used in reports, baselines and
``# lint: disable=`` comments), ``DESCRIPTION``, ``SCOPE`` (repo-relative
path prefixes the rule applies to when scanning the repo — explicit file
arguments always run every rule), and ``check(path, tree, lines)``.
"""
from repro.analysis.rules import (host_sync, precision, prng, retrace,
                                  tracer_branch)

ALL_RULES = (precision, host_sync, retrace, prng, tracer_branch)

__all__ = ["ALL_RULES"]
