"""host-sync-in-traced: no implicit host round-trips inside traced code.

Inside a function that jax traces (jit / scan / shard_map / vmap / ...,
module-locally visible — see rules._common.traced_functions), any of

  ``.item()``, ``.tolist()``, ``float(x)``, ``int(x)``, ``bool(x)``,
  ``np.asarray(x)``, ``np.array(x)``, ``jax.device_get``,
  ``.block_until_ready()``

either fails at trace time (ConcretizationTypeError deep inside a sweep)
or — worse, under ``io_callback``-style escape hatches and concrete-value
leaks — forces a device→host sync per call, serializing the exact hot
loops the HSS machinery exists to keep on-device.

Static/shape-only casts are exempt: ``int(x.shape[0])``, ``float(len(a))``
and friends resolve at trace time and never touch device data.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules import _common

NAME = "host-sync-in-traced"
DESCRIPTION = "host synchronization reachable inside a jit/scan/shard_map body"
SCOPE = ("src/repro",)

_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_SYNC_CASTS = {"float", "int", "bool", "complex"}
_NP_SYNC_FUNCS = {"asarray", "array"}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "levels", "leaf_size"}


def _is_static_expr(node: ast.AST) -> bool:
    """Trace-time-static expressions: literals, shape/ndim/size chains,
    len(...), arithmetic thereof."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr in _STATIC_ATTRS or _is_static_expr(node.value)
    if isinstance(node, ast.Subscript):
        return _is_static_expr(node.value)
    if isinstance(node, ast.Call):
        if _common.attr_name(node.func) in {"len", "prod", "min", "max"}:
            return True
        return False
    if isinstance(node, ast.BinOp):
        return _is_static_expr(node.left) and _is_static_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_static_expr(node.operand)
    if isinstance(node, ast.Name):
        return False
    return False


def check(path: str, tree: ast.AST, lines: list[str]) -> list[Finding]:
    findings = []
    seen_lines: set[int] = set()
    for fn in _common.traced_functions(tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _common.attr_name(node.func)
            bad = None
            if (isinstance(node.func, ast.Attribute)
                    and name in _SYNC_METHODS):
                bad = f".{name}()"
            elif isinstance(node.func, ast.Name) and name in _SYNC_CASTS:
                if node.args and not _is_static_expr(node.args[0]):
                    bad = f"{name}()"
            elif (name in _NP_SYNC_FUNCS
                  and _common.root_name(node.func) in ("np", "numpy")):
                bad = f"np.{name}()"
            elif name == "device_get":
                bad = "jax.device_get()"
            if bad is None or node.lineno in seen_lines:
                continue
            seen_lines.add(node.lineno)
            findings.append(Finding(
                rule=NAME, path=path, line=node.lineno,
                message=(f"{bad} inside a traced function body — this "
                         "either breaks tracing or forces a device→host "
                         "sync per call; keep the value on-device (jnp "
                         "ops) or hoist the host work out of the traced "
                         "region"),
                line_content=lines[node.lineno - 1].strip(),
            ))
    return findings
