"""retrace-knob: sweep knobs must enter jitted calls as traced scalars.

The PR 5 convention: a grid-sweep knob (C / ε / ν) crosses into a jitted
function as ``jnp.asarray(value, jnp.float32)`` — a strong-typed traced
scalar — so one compile serves the whole warm-started sweep.  Passing raw
Python literals is fragile: a grid like ``[1, 2.0, 4]`` silently mixes
weak-int and weak-float signatures and recompiles mid-sweep, and a later
refactor to ``static_argnums`` turns every grid point into a compile.
The trace layer (jaxpr_check.check_recompile_engine) proves the invariant
end-to-end; this rule catches the idiom at the call site.

Flags calls to module-locally visible jit-bound callables
(``f = jax.jit(...)`` / ``self._jit_x = jax.jit(...)``) where an argument
is a Python numeric literal, a ``float()``/``int()`` cast, or a local name
carrying a numeric literal.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules import _common

NAME = "retrace-knob"
DESCRIPTION = ("Python scalar passed to a jitted callable where the "
               "traced-scalar knob convention applies (PR 5)")
SCOPE = ("src/repro",)


def _is_jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    if _common.attr_name(node.func) == "jit":
        return True
    # jax.jit(f, ...) spelled through partial
    return _common.is_partial_of(node, {"jit"})


def _jit_bound_names(tree: ast.AST) -> set[str]:
    """Names (or attribute tails, e.g. "_jit_admm") bound to jax.jit(...)."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign) or not _is_jit_call(node.value):
            continue
        for tgt in node.targets:
            name = _common.attr_name(tgt)
            if name:
                names.add(name)
    return names


def _numeric_constants(tree: ast.AST) -> set[str]:
    """Local names that carry Python numeric literals: plain assignments
    and for-loop variables iterating literal numeric collections/range."""
    consts: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            if (isinstance(node.value, ast.Constant)
                    and isinstance(node.value.value, (int, float))
                    and not isinstance(node.value.value, bool)):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        consts.add(tgt.id)
        elif isinstance(node, ast.For) and isinstance(node.target, ast.Name):
            it = node.iter
            if (isinstance(it, (ast.List, ast.Tuple))
                    and it.elts
                    and all(isinstance(e, ast.Constant)
                            and isinstance(e.value, (int, float))
                            for e in it.elts)):
                consts.add(node.target.id)
            elif (isinstance(it, ast.Call)
                  and _common.attr_name(it.func) == "range"):
                consts.add(node.target.id)
    return consts


def _scalar_reason(arg: ast.AST, consts: set[str]) -> str | None:
    if (isinstance(arg, ast.Constant)
            and isinstance(arg.value, (int, float))
            and not isinstance(arg.value, bool)):
        return f"numeric literal {arg.value!r}"
    if (isinstance(arg, ast.Call) and isinstance(arg.func, ast.Name)
            and arg.func.id in ("float", "int")):
        return f"{arg.func.id}() cast"
    if isinstance(arg, ast.Name) and arg.id in consts:
        return f"Python numeric {arg.id!r}"
    return None


def check(path: str, tree: ast.AST, lines: list[str]) -> list[Finding]:
    jit_names = _jit_bound_names(tree)
    if not jit_names:
        return []
    consts = _numeric_constants(tree)
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _common.attr_name(node.func)
        if fname not in jit_names or _is_jit_call(node):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            reason = _scalar_reason(arg, consts)
            if reason is None:
                continue
            findings.append(Finding(
                rule=NAME, path=path, line=node.lineno,
                message=(f"{reason} passed to jitted {fname!r} — thread "
                         "sweep knobs as jnp.asarray(v, jnp.float32) "
                         "traced scalars (one compile per sweep, PR 5 "
                         "convention)"),
                line_content=lines[node.lineno - 1].strip(),
            ))
    return findings
