"""Shared AST helpers for the lint rules.

Everything here is deliberately module-local and syntactic: the rules never
import the code under analysis, so the lint runs in milliseconds and cannot
be broken by import-time side effects.  The trace-level layer
(repro.analysis.jaxpr_check) is where whole-program facts are checked.
"""
from __future__ import annotations

import ast
from typing import Iterator

# jax transforms whose function argument(s) get TRACED — a function handed
# to any of these (or decorated with one) must contain no host syncs and no
# Python control flow on traced values.  Maps transform name -> positions of
# the traced-callable arguments.
TRACED_CALL_ARGS: dict[str, tuple[int, ...]] = {
    "jit": (0,),
    "vmap": (0,),
    "pmap": (0,),
    "grad": (0,),
    "value_and_grad": (0,),
    "checkpoint": (0,),
    "remat": (0,),
    "shard_map": (0,),
    "scan": (0,),          # jax.lax.scan(body, ...)
    "map": (0,),           # jax.lax.map(body, ...)
    "fori_loop": (2,),     # jax.lax.fori_loop(lo, hi, body, init)
    "while_loop": (0, 1),  # cond_fun, body_fun
    "cond": (1, 2),        # pred, true_fun, false_fun
    "switch": None,        # index, *branches — every arg past 0 is a callable
}

# decorators that make the decorated function a traced body
TRACED_DECORATORS = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                     "checkpoint", "remat", "shard_map"}

# control-flow names that only count when spelled through jax.lax — a bare
# "map"/"scan"/"cond" otherwise collides with jax.tree.map, builtins.map,
# itertools chains, etc.
_LAX_ONLY = {"scan", "map", "fori_loop", "while_loop", "cond", "switch"}


def attr_name(node: ast.AST) -> str | None:
    """Trailing name of a Name / dotted Attribute: jax.lax.scan -> "scan"."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def root_name(node: ast.AST) -> str | None:
    """Leading name of a dotted chain: jax.lax.scan -> "jax"."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def dotted_parts(node: ast.AST) -> tuple[str, ...]:
    """All names of a dotted chain: jax.lax.scan -> ("jax", "lax", "scan")."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


def is_partial_of(call: ast.Call, names: set[str]) -> bool:
    """functools.partial(jax.jit, ...) / partial(shard_map, ...)."""
    if attr_name(call.func) != "partial" or not call.args:
        return False
    return attr_name(call.args[0]) in names


def iter_functions(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            yield node


def _callable_args(call: ast.Call) -> list[ast.AST]:
    """The argument expressions of ``call`` that jax will trace."""
    name = attr_name(call.func)
    fn = call.func
    # functools.partial(jax.jit, ...) produces a transform: its later
    # application is out of local reach; but partial(jax.jit)(f) style is
    # rare enough to ignore.
    if name not in TRACED_CALL_ARGS:
        if isinstance(fn, ast.Call) and is_partial_of(fn, set(TRACED_CALL_ARGS)):
            return list(call.args)          # partial(jax.jit, ...)(f)
        return []
    if name in _LAX_ONLY and "lax" not in dotted_parts(fn):
        return []
    positions = TRACED_CALL_ARGS[name]
    if positions is None:                   # lax.switch: all tail args
        return list(call.args[1:])
    return [call.args[i] for i in positions if i < len(call.args)]


def traced_functions(tree: ast.AST) -> list[ast.AST]:
    """Module-locally visible traced function bodies.

    Collects (a) defs decorated with a jit-family transform, (b) defs whose
    NAME is passed as the callable argument of a transform call in the same
    module, and (c) lambdas appearing inline in those argument positions.
    One module-local hop only — deliberately conservative, so the rule
    never flags plain helpers that merely *could* be traced elsewhere.
    """
    defs_by_name: dict[str, list[ast.AST]] = {}
    traced: list[ast.AST] = []
    for fn in iter_functions(tree):
        if isinstance(fn, ast.Lambda):
            continue
        defs_by_name.setdefault(fn.name, []).append(fn)
        for dec in fn.decorator_list:
            dname = attr_name(dec if not isinstance(dec, ast.Call)
                              else dec.func)
            if dname in TRACED_DECORATORS:
                traced.append(fn)
            elif isinstance(dec, ast.Call) and is_partial_of(
                    dec, TRACED_DECORATORS):
                traced.append(fn)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for arg in _callable_args(node):
            if isinstance(arg, ast.Lambda):
                traced.append(arg)
            else:
                name = attr_name(arg)
                if name and name in defs_by_name:
                    traced.extend(defs_by_name[name])
    # dedupe, preserve order
    seen: set[int] = set()
    out = []
    for fn in traced:
        if id(fn) not in seen:
            seen.add(id(fn))
            out.append(fn)
    return out


def build_parent_map(tree: ast.AST) -> dict[int, ast.AST]:
    parents: dict[int, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[id(child)] = node
    return parents


F32_NAMES = {"float32", "f32"}


def is_f32_expr(node: ast.AST) -> bool:
    """jnp.float32 / np.float32 / "float32" / a local alias named f32."""
    if isinstance(node, ast.Constant) and node.value in F32_NAMES:
        return True
    return attr_name(node) in F32_NAMES


def is_astype_f32(node: ast.AST) -> bool:
    """x.astype(jnp.float32)-shaped call."""
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "astype"
            and len(node.args) == 1
            and is_f32_expr(node.args[0]))


def contains(node: ast.AST, pred) -> bool:
    return any(pred(n) for n in ast.walk(node))


# attributes/calls whose results are trace-time-static (never tracers)
STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "weak_type", "sharding"}
_STATIC_CALLS = {"len", "min", "max", "tuple", "list", "set", "dict",
                 "range", "enumerate", "zip", "sorted", "isinstance",
                 "hasattr", "getattr", "prod", "str", "repr"}


def is_nontracer_expr(node: ast.AST) -> bool:
    """Conservatively true when an expression cannot produce a tracer:
    literals, .shape/.ndim/.dtype probes, len()/min()/tuple() and other
    structural builtins, and arithmetic/comparison chains thereof."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute):
        return node.attr in STATIC_ATTRS
    if isinstance(node, ast.Subscript):
        return is_nontracer_expr(node.value)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(is_nontracer_expr(e) for e in node.elts)
    if isinstance(node, ast.Call):
        return attr_name(node.func) in _STATIC_CALLS
    if isinstance(node, ast.BinOp):
        return is_nontracer_expr(node.left) and is_nontracer_expr(node.right)
    if isinstance(node, ast.UnaryOp):
        return is_nontracer_expr(node.operand)
    if isinstance(node, (ast.BoolOp, ast.Compare)):
        return True                      # Python bool results, not tracers
    return False


def static_params(fn: ast.AST) -> set[str]:
    """Parameter names marked static via jit(..., static_argnames=...) /
    static_argnums in the function's decorators (sanctioned Python values —
    branching on them is the POINT of marking them static)."""
    if isinstance(fn, ast.Lambda):
        return set()
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    out: set[str] = set()
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                vals = (kw.value.elts
                        if isinstance(kw.value, (ast.Tuple, ast.List))
                        else [kw.value])
                out.update(v.value for v in vals
                           if isinstance(v, ast.Constant)
                           and isinstance(v.value, str))
            elif kw.arg == "static_argnums":
                vals = (kw.value.elts
                        if isinstance(kw.value, (ast.Tuple, ast.List))
                        else [kw.value])
                for v in vals:
                    if (isinstance(v, ast.Constant)
                            and isinstance(v.value, int)
                            and v.value < len(pos)):
                        out.add(pos[v.value])
    return out
