"""precision-accumulate: hot-path contractions must pin f32 accumulation.

Every ``jnp.einsum`` / ``matmul`` / ``dot`` / ``tensordot`` /
``lax.dot_general`` on the hot paths (core/, kernels/, models/) must pass
``preferred_element_type`` — otherwise a bf16-stored operand silently
accumulates in bf16 and the ADMM inner solves drift (Boyd's convergence
analysis assumes exact inner solves; the PR 3 bf16-vs-f32 regression pins
the contract at ~3e-3 rel, bf16 accumulation would be ~1e-1).

Exemptions (explicit intent, not silence):
  * the call already passes ``preferred_element_type=...``;
  * the result is immediately ``.astype(jnp.float32)`` — the author
    acknowledged the precision boundary in-code;
  * an operand is ``.astype(jnp.float32)``-cast — the inputs are forced to
    f32, so accumulation is f32 by dtype semantics.

The bare ``@`` operator is deliberately out of scope here: it has no
``preferred_element_type`` channel and is used on host-side/f32-only small
dense math throughout core/.  The trace layer
(jaxpr_check.dtype_downcasts) sees every ``dot_general`` on the real hot
paths regardless of surface syntax, so ``@`` on bf16 data cannot hide.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules import _common

NAME = "precision-accumulate"
DESCRIPTION = ("contraction without preferred_element_type on a hot path "
               "(f32-accumulation convention, PR 3)")
SCOPE = ("src/repro/core", "src/repro/kernels", "src/repro/models")

_ACC_FUNCS = {"einsum", "matmul", "dot", "tensordot", "vdot", "dot_general"}
# only device-side namespaces: host numpy (np./numpy.) math has no
# bf16-accumulation hazard
_DEVICE_ROOTS = {"jnp", "jax", "lax", "pl", "plgpu", "pltpu"}


def _is_acc_call(node: ast.Call) -> bool:
    name = _common.attr_name(node.func)
    if name not in _ACC_FUNCS:
        return False
    if isinstance(node.func, ast.Name):       # from jax.numpy import einsum
        return True
    root = _common.root_name(node.func)
    return root in _DEVICE_ROOTS


def check(path: str, tree: ast.AST, lines: list[str]) -> list[Finding]:
    # nodes living inside the value of an .astype(f32) call are exempt
    exempt: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _common.is_astype_f32(node):
            for sub in ast.walk(node.func.value):
                exempt.add(id(sub))

    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_acc_call(node)):
            continue
        if id(node) in exempt:
            continue
        if any(kw.arg == "preferred_element_type" for kw in node.keywords):
            continue
        if any(_common.contains(arg, _common.is_astype_f32)
               for arg in node.args):
            continue
        fn = _common.attr_name(node.func)
        findings.append(Finding(
            rule=NAME, path=path, line=node.lineno,
            message=(f"{fn} without preferred_element_type — pass "
                     "preferred_element_type=jnp.float32 (or cast the "
                     "result/operands to f32 explicitly) so bf16-stored "
                     "operands cannot silently accumulate in bf16"),
            line_content=lines[node.lineno - 1].strip(),
        ))
    return findings
