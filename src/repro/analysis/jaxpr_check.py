"""Layer 2: trace-level checks over the pipeline's hot paths.

The AST rules (repro.analysis.rules) see syntax; this layer sees the
program jax actually builds.  It traces the hot paths with
``jax.make_jaxpr`` / ``jax.eval_shape`` on a small synthetic problem and
asserts whole-program facts no syntactic rule can prove:

  * **no-downcast** — no ``dot_general``/conv in the traced graph
    accumulates in bf16/f16 (covers every spelling: ``einsum``, ``@``,
    ``jnp.dot``, ``lax.dot_general``) — the f32-accumulation convention
    the ``precision-accumulate`` AST rule enforces at the source level;
  * **no-host-callback** — no callback primitive inside a traced hot
    path (a ``pure_callback``/``io_callback`` smuggled into a jitted
    body serializes every step on the host);
  * **one-compile-per-sweep** — a warm-started 4-point C-grid on the
    engine triggers exactly ONE compilation of the ADMM run (the traced
    scalar-knob convention: knobs enter as ``jnp.asarray(c, f32)``);
  * **streamed-stage purity** — the per-batch stage functions of the
    out-of-core ``compress_streamed`` walk are callback-free and
    f32-accumulating in both fixed-rank and adaptive modes (the host
    orchestrates BETWEEN batches; nothing may call back DURING one);
  * **mesh-placement** — under a multi-device mesh, the compressed /
    factorized artifacts land exactly where ``dist.api
    .node_partition_spec`` says, and the matmat/solve jaxprs pin their
    per-level intermediates with sharding constraints (the PR 3 route
    around the XLA SPMD reshape miscompile);
  * **serve-path** — the serving tier's batch scorer
    (``repro.serve.batched_scores``) is callback-free and f32-accumulating
    in BOTH compute dtypes (the bf16 block path is exactly where a missing
    ``preferred_element_type`` would silently bite), and a tick stream
    with varying queue occupancy compiles once per configured bucket —
    never once per occupancy (the pad-to-bucket rule, end to end);
  * **kernel-linalg** — the RAW streamed scoring matvec probed with bf16
    inputs (the path the serve check's f32 probes never reached), plus
    the KRR solve and Lanczos sweeps of the kernel linear-algebra task
    family, all callback-free and f32-accumulating.

Scope note: ``compression.compress`` is deliberately NOT traced here —
it is host-orchestrated by design (proxy-index selection runs in numpy
via ``jax.device_get``), so ``make_jaxpr`` cannot see through it.  Its
output PLACEMENT is still checked (mesh check), and its inner jitted
stages are covered by the AST layer.

Checks report ``Finding``s with line 0 and a pseudo-path naming the
traced entry point, so the CLI renders them uniformly with lint hits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.findings import Finding

# primitives that contract-and-accumulate: their output dtype IS the
# accumulator dtype, so a bf16/f16 output means a low-precision accumulator
_ACCUM_PRIMS = {"dot_general", "conv_general_dilated"}
_LOW_PRECISION = {jnp.bfloat16.dtype, jnp.float16.dtype}

# callback primitives across jax versions
_CALLBACK_PRIMS = {"pure_callback", "io_callback", "python_callback",
                   "debug_callback", "outside_call", "host_callback_call"}


# --------------------------------------------------------------------- #
# jaxpr walkers                                                          #
# --------------------------------------------------------------------- #
def iter_eqns(jaxpr):
    """All equations of a (closed) jaxpr, recursing into sub-jaxprs
    (pjit bodies, scan/while/cond branches, custom_jvp calls, ...)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn
        for sub in jax.core.jaxprs_in_params(eqn.params):
            yield from iter_eqns(sub)


def dtype_downcasts(jaxpr) -> list[str]:
    """dot_general/conv eqns whose ACCUMULATOR is bf16/f16.

    With ``preferred_element_type=float32`` a bf16×bf16 contraction gets
    an f32 out-aval; without it the output (= accumulator) stays bf16.
    """
    bad = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name not in _ACCUM_PRIMS:
            continue
        in_dts = [v.aval.dtype for v in eqn.invars
                  if hasattr(v.aval, "dtype")]
        if not in_dts or not all(jnp.issubdtype(d, jnp.floating)
                                 for d in in_dts):
            continue
        out_dts = [v.aval.dtype for v in eqn.outvars
                   if hasattr(v.aval, "dtype")]
        for d in out_dts:
            if d in _LOW_PRECISION:
                bad.append(f"{eqn.primitive.name}: "
                           f"{[str(x) for x in in_dts]} -> {d}")
    return bad


def host_callbacks(jaxpr) -> list[str]:
    return [eqn.primitive.name for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in _CALLBACK_PRIMS
            or "callback" in eqn.primitive.name]


def sharding_constraint_count(jaxpr) -> int:
    return sum(1 for eqn in iter_eqns(jaxpr)
               if "sharding_constraint" in eqn.primitive.name)


def abstract_signature(*args):
    """Mirror of jit's cache key for array/scalar args — two calls with
    equal signatures hit the same executable.  Python scalars map to
    their weak result dtype: a C-grid of plain floats shares one entry,
    but a grid mixing int and float (or a grid of 0-d np arrays with
    drifting dtypes) does NOT — which is why the repo's convention is
    ``jnp.asarray(c, jnp.float32)`` at every jit boundary."""
    sig = []
    for a in jax.tree.leaves(args):
        if isinstance(a, (jax.Array, np.ndarray)):
            weak = bool(getattr(a, "weak_type", False))
            sig.append((tuple(a.shape), str(a.dtype), weak))
        else:
            sig.append(("scalar", str(jnp.result_type(type(a))), True))
    return tuple(sig)


# --------------------------------------------------------------------- #
# probe problem                                                          #
# --------------------------------------------------------------------- #
def _blobs(n: int, seed: int = 0):
    r = np.random.default_rng(seed)
    half = n // 2
    mu = np.zeros(4, np.float32)
    mu[0] = 2.5
    x = np.concatenate([r.normal(size=(half, 4)) + mu,
                        r.normal(size=(n - half, 4)) - mu]).astype(np.float32)
    y = np.concatenate([np.ones(half), -np.ones(n - half)]).astype(np.float32)
    return x, y


def build_probe(n: int = 256, leaf: int = 32, store_dtype: str | None = None,
                mesh=None):
    """A small compress+factorize instance for tracing the hot paths."""
    from repro.core import compression, factorization, tree as tree_mod
    from repro.core.kernelfn import KernelSpec

    x, y = _blobs(n)
    t = tree_mod.build_tree(x, leaf_size=leaf)
    xp = x[t.perm]
    spec = KernelSpec(h=1.0)
    params = compression.CompressionParams(rank=16, n_near=16, n_far=24)
    if mesh is None:
        hss = compression.compress(jnp.asarray(xp), t, spec, params)
        fac = factorization.factorize(hss, 8.0, store_dtype=store_dtype)
    else:
        hss = compression.compress_sharded(xp, t, spec, params, mesh)
        fac = factorization.factorize_sharded(hss, 8.0, mesh,
                                              store_dtype=store_dtype)
    yp = jnp.asarray(y[t.perm])
    return hss, fac, yp


def _finding(entry: str, message: str) -> Finding:
    return Finding(rule="trace-check", path=f"<trace:{entry}>", line=0,
                   message=message, line_content="")


def _check_traced(entry: str, jaxpr, want_constraints: bool = False
                  ) -> list[Finding]:
    out = []
    for bad in dtype_downcasts(jaxpr):
        out.append(_finding(entry, f"low-precision accumulation: {bad} — "
                            "pass preferred_element_type=jnp.float32"))
    for cb in host_callbacks(jaxpr):
        out.append(_finding(entry, f"host callback {cb!r} inside a traced "
                            "hot path"))
    if want_constraints and sharding_constraint_count(jaxpr) == 0:
        out.append(_finding(entry, "no sharding constraints in the traced "
                            "graph under an active mesh — per-level "
                            "intermediates must be pinned via "
                            "dist.api.constrain_nodes"))
    return out


# --------------------------------------------------------------------- #
# the checks                                                             #
# --------------------------------------------------------------------- #
def check_hot_paths(store_dtype: str | None = "bfloat16") -> list[Finding]:
    """Trace matmat / solve_mat / factorize / the ADMM scan and assert
    no low-precision accumulation and no host callbacks.  Runs with bf16
    factor storage by default — the configuration where a missing
    ``preferred_element_type`` actually bites."""
    from repro.core import admm as admm_mod
    from repro.core import factorization
    from repro.core.svm import compute_bias_batched

    hss, fac, yp = build_probe(store_dtype=store_dtype)
    n = hss.n
    v = jnp.zeros((n, 2), jnp.float32)
    findings = []

    findings += _check_traced(
        "HSSMatrix.matmat", jax.make_jaxpr(lambda b: hss.matmat(b))(v))
    findings += _check_traced(
        "hss_solve_mat", jax.make_jaxpr(lambda b: fac.solve_mat(b))(v))
    findings += _check_traced(
        "factorize",
        jax.make_jaxpr(lambda h: factorization.factorize(
            h, 8.0, store_dtype=store_dtype))(hss))

    ys = yp[None, :]
    pmask = jnp.ones_like(ys)

    def admm_run(knob, z0, mu0):
        task = admm_mod.svm_task(ys, knob * pmask)
        state, trace = admm_mod.admm_boxqp(fac.solve_mat, task, fac.beta,
                                           4, z0=z0, mu0=mu0)
        return state.z, state.mu, trace.iters_run

    z0 = jnp.zeros((n, 1), jnp.float32)
    knob = jnp.asarray(1.0, jnp.float32)
    findings += _check_traced(
        "admm_boxqp", jax.make_jaxpr(admm_run)(knob, z0, z0))
    findings += _check_traced(
        "compute_bias_batched",
        jax.make_jaxpr(lambda z, c: compute_bias_batched(
            hss, ys.T, z, c * pmask.T, pmask.T))(z0, knob))
    return findings


def check_compress_kernels() -> list[Finding]:
    """Trace the fused Pallas compression stages (repro.kernels.compress)
    and assert no sub-f32 accumulation and no host callbacks.

    Probed on bf16 inputs — the configuration where a missing
    ``preferred_element_type`` inside the fused assemble+ID deflation loop
    (or the laplacian block kernel's epilogue) would actually produce a
    bf16 accumulator.  ``iter_eqns`` recurses through the ``pallas_call``
    body jaxpr, so the on-chip contractions are covered, not just the
    padding wrapper.  The plain ``compress`` orchestration stays
    deliberately untraced (host-orchestrated by design — see module
    docstring); this check covers the device stages it dispatches to.
    """
    from repro.kernels.compress import ops as cops
    from repro.kernels.compress.laplacian import laplacian_block

    b, m, s, f, k = 2, 32, 16, 4, 8
    xc = jnp.zeros((b, m, f), jnp.bfloat16)
    xp = jnp.zeros((b, s, f), jnp.bfloat16)
    findings = []
    for name in ("gaussian", "laplacian"):
        jaxpr = jax.make_jaxpr(lambda c, p: cops.batched_assemble_id(
            c, p, k, kernel_name=name, h=1.0, rtol=1e-4, adaptive=True,
            interpret=True))(xc, xp)
        findings += _check_traced(f"fused_assemble_id[{name}]", jaxpr)
    xa = jnp.zeros((33, f), jnp.bfloat16)
    xb = jnp.zeros((65, f), jnp.bfloat16)
    findings += _check_traced(
        "laplacian_block",
        jax.make_jaxpr(lambda a, c: laplacian_block(
            a, c, 1.0, interpret=True))(xa, xb))
    return findings


def check_streamed_stage() -> list[Finding]:
    """Trace the streamed out-of-core compression stages and assert no host
    callbacks and no sub-f32 accumulation.

    ``compress_streamed`` is host-orchestrated on purpose (batch slicing,
    checkpointing and skeleton bookkeeping run in numpy), but each batch
    dispatches to the three pure stage functions traced here — a callback
    smuggled into one of them would serialize every batch of a paper-scale
    build on the host.  Probed in f32 (the streamed path computes in the
    input dtype; bf16 storage is a factorization-layer concern), in both
    fixed-rank and adaptive modes, so the rank-masked candidate branch is
    covered too.
    """
    from repro.core import compression as comp
    from repro.core.kernelfn import KernelSpec

    spec = KernelSpec(h=1.0)
    b, m, f, r0, nf = 2, 32, 4, 8, 12
    xl = jnp.zeros((b, m, f), jnp.float32)
    xp_leaf = jnp.zeros((b, m + nf, f), jnp.float32)
    cp = jnp.zeros((b, 2 * r0, f), jnp.float32)
    xp_lvl = jnp.zeros((b, 2 * r0 + nf, f), jnp.float32)
    cm = jnp.ones((b, 2 * r0), jnp.float32)
    findings = []
    for adaptive in (False, True):
        tag = "adaptive" if adaptive else "fixed"
        rtol = 1e-4 if adaptive else None
        findings += _check_traced(
            f"stream_leaf_batch[{tag}]",
            jax.make_jaxpr(lambda a, p: comp._stream_leaf_batch(
                spec, a, p, r0, rtol, adaptive))(xl, xp_leaf))
        findings += _check_traced(
            f"stream_level_batch[{tag}]",
            jax.make_jaxpr(lambda c, p, k: comp._stream_level_batch(
                spec, c, p, k if adaptive else None, r0, rtol,
                adaptive))(cp, xp_lvl, cm))
        findings += _check_traced(
            f"stream_root_batch[{tag}]",
            jax.make_jaxpr(lambda c, k: comp._stream_root_batch(
                spec, c, k if adaptive else None, adaptive))(cp, cm))
    return findings


def check_recompile_engine(c_grid=(0.5, 1.0, 2.0, 4.0)) -> list[Finding]:
    """A warm-started C-sweep on the engine must compile the ADMM run
    exactly once (PR 5's traced-scalar knob convention, end to end)."""
    from repro.core import compression
    from repro.core.engine import HSSSVMEngine
    from repro.core.kernelfn import KernelSpec

    x, y = _blobs(256)
    engine = HSSSVMEngine(
        spec=KernelSpec(h=1.0),
        comp=compression.CompressionParams(rank=16, n_near=16, n_far=24),
        leaf_size=32, max_it=4)
    engine.prepare(x, y)
    engine.train_grid(list(c_grid))
    findings = []
    cache_size = getattr(engine._jit_admm, "_cache_size", lambda: None)()
    if cache_size is None:
        findings.append(_finding(
            "engine.train_grid",
            "cannot read the jit cache size on this jax version — "
            "recompile guard inconclusive"))
    elif cache_size != 1:
        sigs = abstract_signature(jnp.asarray(c_grid[0], jnp.float32))
        findings.append(_finding(
            "engine.train_grid",
            f"{len(c_grid)}-point C-sweep compiled {cache_size}x "
            f"(expected 1): a knob is reaching jit as a fresh Python "
            f"value instead of a traced jnp.asarray scalar "
            f"(expected signature per call: {sigs})"))
    return findings


def check_serve_path() -> list[Finding]:
    """The serving tier's hot path, both halves of its contract:

    1. ``batched_scores`` traced in f32 AND bf16 must show no sub-f32
       dot_general accumulator and no host callback — the bf16 block
       path is all einsums, so one missing ``preferred_element_type``
       flips every score accumulation to bf16;
    2. a ``ServingEngine`` fed ticks at many different queue occupancies
       must compile its scorer exactly once per configured bucket (the
       pad-to-bucket rule): a compile count tracking occupancy means the
       padding broke and every distinct queue length pays an XLA compile.
    """
    from repro.core.engine import EngineModel
    from repro.core.kernelfn import KernelSpec
    from repro.serve import BatchPolicy, ServingEngine, batched_scores

    d, f, p = 64, 4, 3
    spec = KernelSpec(h=1.0)
    xs = jnp.zeros((d, f), jnp.float32)
    zy = jnp.zeros((d, p), jnp.float32)
    biases = jnp.zeros((p,), jnp.float32)
    xq = jnp.zeros((32, f), jnp.float32)
    findings = []
    for dt in ("float32", "bfloat16"):
        jaxpr = jax.make_jaxpr(
            lambda q, s, z, b: batched_scores(
                q, s, z, b, spec=spec, block=16, compute_dtype=dt)
        )(xq, xs, zy, biases)
        findings += _check_traced(f"serve.batched_scores[{dt}]", jaxpr)

    model = EngineModel(
        x_perm=xs, z_y=zy, biases=biases,
        classes=np.array([0.0, 1.0, 2.0], np.float32), spec=spec,
        c_value=1.0, binary=False, strategy="ovr", task="svm", beta=8.0)
    engine = ServingEngine(policy=BatchPolicy(buckets=(16, 64), block=16))
    mid = engine.add_model(model)
    occupancies = (1, 3, 7, 11, 16, 20, 40, 64)   # 2 buckets, 8 shapes
    for occ in occupancies:
        engine.score(mid, np.zeros((occ, f), np.float32))
    compiles = engine.scorer_compiles()
    if compiles is None:
        findings.append(_finding(
            "serve.tick", "cannot read the jit cache size on this jax "
            "version — occupancy recompile guard inconclusive"))
    elif compiles != 2:
        findings.append(_finding(
            "serve.tick",
            f"{len(occupancies)} tick occupancies over 2 buckets compiled "
            f"{compiles}x (expected 2): queue shapes are reaching the "
            "scorer unpadded — the bucket padding rule broke"))
    return findings


def check_kernel_linalg() -> list[Finding]:
    """The kernel linear-algebra family's traced paths.

    1. the RAW streamed scoring matvec (``kernel_matvec_streamed``) probed
       with bf16 rows/support/coefficients — exactly the path the layer-2
       sweep never saw before this check (``batched_scores`` routes bf16
       through its own einsum twin, so the raw path's bare ``@``
       accumulations sat outside every earlier probe);
    2. the KRR/GP train step (``krr.krr_solve``) on a bf16-stored
       factorization — ONE multi-RHS solve, callback-free, f32-accumulating;
    3. the Lanczos sweep (``lanczos.top_eigenpairs``) on the HSS matvec —
       the scan body's reorthogonalization and Ritz recombination are all
       contractions and must hold the f32 convention too.
    """
    from repro.core import krr as krr_mod
    from repro.core import lanczos as lanczos_mod
    from repro.core.kernelfn import KernelSpec, kernel_matvec_streamed

    findings = []
    spec = KernelSpec(h=1.0)
    for dt in (jnp.float32, jnp.bfloat16):
        xr = jnp.zeros((40, 4), dt)
        xc = jnp.zeros((64, 4), dt)
        v = jnp.zeros((64, 3), dt)
        jaxpr = jax.make_jaxpr(
            lambda a, c, w: kernel_matvec_streamed(spec, a, c, w, block=16)
        )(xr, xc, v)
        findings += _check_traced(
            f"kernel_matvec_streamed[{jnp.dtype(dt).name}]", jaxpr)

    hss, fac, _ = build_probe(store_dtype="bfloat16")
    targets = jnp.zeros((hss.n, 2), jnp.float32)
    findings += _check_traced(
        "krr.krr_solve",
        jax.make_jaxpr(lambda b: krr_mod.krr_solve(fac, b))(targets))
    findings += _check_traced(
        "lanczos.top_eigenpairs",
        jax.make_jaxpr(lambda: lanczos_mod.top_eigenpairs(hss, 4, seed=0))())
    return findings


def _constraint_spec_violations(entry: str, jaxpr, mesh) -> list[Finding]:
    """Each sharding_constraint pin on a node-stacked (ndim>=3)
    intermediate must carry EXACTLY the node_partition_spec placement —
    a drifted pin is worse than none (it forces the wrong layout)."""
    from jax.sharding import NamedSharding

    from repro.dist import api as dist_api

    out = []
    for eqn in iter_eqns(jaxpr):
        if "sharding_constraint" not in eqn.primitive.name:
            continue
        aval = eqn.outvars[0].aval
        if not hasattr(aval, "shape") or len(aval.shape) < 3:
            continue                       # vectors/matrices: other rules
        got = eqn.params.get("sharding")
        if got is None or not hasattr(got, "is_equivalent_to"):
            continue
        want = NamedSharding(mesh, dist_api.node_partition_spec(
            mesh, len(aval.shape), aval.shape[0]))
        if not got.is_equivalent_to(want, len(aval.shape)):
            out.append(_finding(
                entry,
                f"sharding pin on {tuple(aval.shape)} intermediate is "
                f"{got}, but node_partition_spec says {want.spec} — the "
                "placement rule drifted between dist.api and this sweep"))
    return out


def check_mesh_placement() -> list[Finding]:
    """Under a multi-device mesh: the factorization sits exactly where
    ``fac_shardings`` (= node_partition_spec per leaf) puts it, no
    O(N·m) compression artifact is fully replicated, and the matmat /
    solve graphs pin their node-stacked per-level intermediates with
    sharding constraints that MATCH node_partition_spec."""
    from jax.sharding import NamedSharding

    from repro.core.distributed import fac_shardings
    from repro.dist import api as dist_api

    ndev = len(jax.devices())
    if ndev < 2 or ndev & (ndev - 1):
        return [_finding(
            "mesh", f"skipped: needs a power-of-two multi-device setup, "
            f"have {ndev} device(s) — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8")]
    mesh = jax.make_mesh((ndev,), ("data",))
    hss, fac, _ = build_probe(n=32 * ndev * 2, leaf=32, mesh=mesh)
    findings = []

    # factorization placement: fac_shardings is the contract
    want_tree = fac_shardings(jax.eval_shape(lambda: fac), mesh)
    for i, (leaf, want) in enumerate(zip(jax.tree.leaves(fac),
                                         jax.tree.leaves(want_tree))):
        if not isinstance(leaf, jax.Array):
            continue
        if not leaf.sharding.is_equivalent_to(want, leaf.ndim):
            findings.append(_finding(
                "mesh:fac",
                f"factor leaf {i} shape {tuple(leaf.shape)} placed as "
                f"{leaf.sharding}, but fac_shardings says {want.spec}"))

    # compression placement: the O(N·m)/O(N·r) arrays must be sharded
    for name in ("d_leaf", "u_leaf", "x"):
        a = getattr(hss, name)
        if a.sharding.is_fully_replicated:
            findings.append(_finding(
                "mesh:hss",
                f"hss.{name} shape {tuple(a.shape)} is fully replicated "
                "under the mesh — an O(N·m) artifact landed whole on "
                "every device"))

    n = hss.n
    v = jnp.zeros((n, 2), jnp.float32)
    with dist_api.use_mesh(mesh), mesh:
        mm = jax.make_jaxpr(lambda b: hss.matmat(b))(v)
        sv = jax.make_jaxpr(lambda b: fac.solve_mat(b))(v)
    findings += _check_traced("mesh:matmat", mm, want_constraints=True)
    findings += _check_traced("mesh:solve_mat", sv, want_constraints=True)
    findings += _constraint_spec_violations("mesh:matmat", mm, mesh)
    findings += _constraint_spec_violations("mesh:solve_mat", sv, mesh)
    return findings


def run_all() -> list[Finding]:
    """Every trace-level check; empty result = hot paths are clean."""
    findings = []
    findings += check_hot_paths()
    findings += check_compress_kernels()
    findings += check_streamed_stage()
    findings += check_recompile_engine()
    findings += check_serve_path()
    findings += check_kernel_linalg()
    findings += check_mesh_placement()
    # informational skips are not failures
    return [f for f in findings if not f.message.startswith("skipped:")]
