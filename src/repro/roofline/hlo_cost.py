"""Loop-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE (verified in
tests/test_roofline.py), which under-counts scan-over-layers models by ~L and
chunked attention/MoE by their trip counts.  This module walks the HLO
computation graph, multiplies each computation by the product of enclosing
while trip counts, and produces loop-corrected totals:

  flops       — 2 * numel(dot output) * contracted extent, summed over dots
                (matmuls dominate these models; elementwise flops ignored,
                documented in EXPERIMENTS.md)
  bytes       — per instruction: output + operand bytes, where fusions count
                as single ops (their internals are register/VMEM traffic,
                not HBM) and bookkeeping ops (tuple plumbing, parameters,
                constants, while carry) are skipped
  collectives — operand bytes of all-reduce / all-gather / reduce-scatter /
                all-to-all / collective-permute, same multipliers

Trip counts are read from each while's condition computation: jax lowers
``lax.scan``/``lax.map``/``fori_loop`` to a counted while whose condition
compares the induction variable against a constant.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_KNOWN_TRIPS = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"(\d+)"')
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_CALLED = re.compile(
    r"(?:to_apply|calls|body|condition|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "while",
    "conditional", "call", "bitcast", "copy-start", "copy-done",
    "after-all", "iota", "partition-id", "replica-id",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a flat dict.

    Newer jaxlibs return a list with one properties-dict per program
    (executable); older ones return the dict directly.  Callers always want
    the entry program's dict, so indexing with a string key works either way.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _shape_bytes_of(text: str) -> int:
    return sum(
        _numel(dims) * _DTYPE_BYTES.get(t, 0)
        for t, dims in _SHAPE_TOKEN.findall(text))


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    out_bytes: int
    out_shape: tuple[tuple[str, str], ...]
    opcode: str
    operands_text: str
    attrs_text: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]          # instr name -> "type[dims]" text

    def param_read_bytes(self) -> dict[int, int]:
        """Effective read size per parameter index.

        A parameter consumed ONLY through dynamic-slice / gather is read at
        the slice size, not the full array — this is what makes per-layer
        reads of scan-stacked weights count as one layer, not L layers.
        """
        out: dict[int, int] = {}
        params: dict[str, int] = {}
        for ins in self.instrs:
            if ins.opcode == "parameter":
                m = re.match(r"(\d+)", ins.operands_text)
                if m:
                    params[ins.name] = int(m.group(1))
        for pname, pidx in params.items():
            full = _shape_bytes_of(self.shapes.get(pname, ""))
            consumers = [i for i in self.instrs
                         if pname in _operand_names(i.operands_text)]
            if consumers and all(
                    c.opcode in ("dynamic-slice", "gather") and
                    _operand_names(c.operands_text)[:1] == [pname]
                    for c in consumers):
                out[pidx] = sum(c.out_bytes for c in consumers)
            else:
                out[pidx] = full
        return out

    def root_is_dus(self) -> Instr | None:
        for ins in self.instrs:
            if ins.opcode == "dynamic-update-slice":
                return ins
        return None


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_HEAD.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, outsig, opcode, rest = m.groups()
        # rest = "operands), attrs..." — split at the matching close paren
        depth = 1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands = rest[:i] if depth == 0 else rest
        attrs = rest[i + 1:] if depth == 0 else ""
        cur.shapes[name] = outsig
        cur.instrs.append(Instr(
            name=name,
            out_bytes=_shape_bytes_of(outsig),
            out_shape=tuple(_SHAPE_TOKEN.findall(outsig)),
            opcode=opcode,
            operands_text=operands,
            attrs_text=attrs,
        ))
    return comps


def _operand_names(text: str) -> list[str]:
    return re.findall(r"%([\w.\-]+)", text)


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the while condition ~ the trip bound."""
    best = 1
    for ins in cond.instrs:
        for m in _CONST_INT.finditer(ins.opcode + "(" + ins.operands_text):
            best = max(best, int(m.group(1)))
        for m in _CONST_INT.finditer(ins.attrs_text):
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    out_elems = sum(_numel(d) for _, d in ins.out_shape) or 1
    ops = _operand_names(ins.operands_text)
    if not ops:
        return 0.0
    lhs = shapes.get(ops[0], "")
    mdims = _SHAPE_TOKEN.search(lhs)
    if not mdims:
        return 0.0
    lhs_dims = [int(d) for d in mdims.group(2).split(",") if d]
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs_text)
    contracted = 1
    if mc and mc.group(1):
        for ax in mc.group(1).split(","):
            ax = int(ax)
            if ax < len(lhs_dims):
                contracted *= lhs_dims[ax]
    return 2.0 * out_elems * contracted


def analyze(hlo: str, entry: str | None = None) -> dict[str, Any]:
    comps = parse_module(hlo)
    if entry is None:
        # the ENTRY computation is usually named main.<n>
        entry = next((n for n in comps if n.startswith("main")), None) or \
            next(iter(comps))

    totals = dict(flops=0.0, bytes=0.0, collective_bytes=0.0,
                  collective_ring_bytes=0.0, collective_per_op={},
                  n_collectives=0, n_while=0, max_depth_mult=1.0,
                  bytes_by_mult={})

    def _acc_bytes(mult, nbytes):
        totals["bytes"] += mult * nbytes
        d = totals["bytes_by_mult"]
        key = int(mult)
        d[key] = d.get(key, 0.0) + mult * nbytes
    visited_mult: dict[str, float] = {}

    def visit(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        # allow revisits with different multipliers (shared computations)
        key = comp_name
        visited_mult[key] = visited_mult.get(key, 0.0) + mult
        totals["max_depth_mult"] = max(totals["max_depth_mult"], mult)
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                called = dict(
                    (k, v) for k, v in re.findall(
                        r"(body|condition)=%?([\w.\-]+)",
                        ins.operands_text + " " + ins.attrs_text))
                mt = _KNOWN_TRIPS.search(ins.attrs_text)
                if mt:      # XLA's own annotation — authoritative
                    trips = int(mt.group(1))
                else:
                    cond = comps.get(called.get("condition", ""))
                    trips = _trip_count(cond) if cond else 1
                totals["n_while"] += 1
                if called.get("body") and called["body"] != comp_name:
                    visit(called["body"], mult * trips)
                continue
            if op == "conditional":
                mb = _BRANCHES.search(ins.attrs_text + ins.operands_text)
                branches = []
                if mb:
                    branches = _operand_names(mb.group(1))
                else:
                    branches = [c for _, c in re.findall(
                        r"(true_computation|false_computation)=%?([\w.\-]+)",
                        ins.attrs_text + ins.operands_text)]
                for b in branches:
                    visit(b, mult)   # upper bound: both branches counted
                continue
            if op in ("call", "async-start"):
                m = _CALLED.search(ins.attrs_text + ins.operands_text)
                if m:
                    visit(m.group(1), mult)
                continue
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)",
                              ins.attrs_text + ins.operands_text)
                fcomp = comps.get(m.group(1)) if m else None
                # fusion counts as ONE op for bytes; dots inside still count
                out_eff = ins.out_bytes
                reads = sum(_shape_bytes_of(comp.shapes.get(n, ""))
                            for n in _operand_names(ins.operands_text))
                if fcomp is not None:
                    for fins in fcomp.instrs:
                        if fins.opcode == "dot":
                            totals["flops"] += mult * _dot_flops(
                                fins, fcomp.shapes)
                    # slice-aware reads + in-place update-slice writes
                    pr = fcomp.param_read_bytes()
                    onames = _operand_names(ins.operands_text)
                    reads = sum(
                        pr.get(i, _shape_bytes_of(comp.shapes.get(n, "")))
                        for i, n in enumerate(onames))
                    dus = fcomp.root_is_dus()
                    if dus is not None:
                        ops = _operand_names(dus.operands_text)
                        upd = (_shape_bytes_of(fcomp.shapes.get(ops[1], ""))
                               if len(ops) > 1 else 0)
                        out_eff = upd or ins.out_bytes
                        # the full buffer passes through in place: drop its
                        # read too (it equals the fusion output size)
                        reads = max(reads - ins.out_bytes, 0)
                _acc_bytes(mult, out_eff + reads)
                continue
            if op == "dot":
                totals["flops"] += mult * _dot_flops(ins, comp.shapes)
            base = op.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                ob = sum(_shape_bytes_of(comp.shapes.get(n, ""))
                         for n in _operand_names(ins.operands_text))
                totals["collective_bytes"] += mult * ob
                totals["n_collectives"] += 1
                d = totals["collective_per_op"]
                d[base] = d.get(base, 0.0) + mult * ob
                mg = re.search(r"replica_groups=\[(\d+),(\d+)\]",
                               ins.attrs_text)
                if mg:
                    n_grp = int(mg.group(2))
                else:
                    mg2 = re.search(r"replica_groups=\{\{([\d,]+)\}",
                                    ins.attrs_text)
                    n_grp = (len(mg2.group(1).split(",")) if mg2 else 2)
                frac = (n_grp - 1) / max(n_grp, 1)
                ring = {"all-reduce": 2 * ob * frac,
                        "all-gather": ob * (n_grp - 1),
                        "reduce-scatter": ob * frac,
                        "all-to-all": ob * frac,
                        "collective-permute": float(ob)}[base]
                totals["collective_ring_bytes"] += mult * ring
            if op in _SKIP_BYTES_OPS or op.endswith("-done") or \
                    base in ("all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute"):
                continue    # collectives belong to the collective term
            ops = _operand_names(ins.operands_text)
            if op == "dynamic-slice" or op == "gather":
                op_bytes = 2 * ins.out_bytes           # slice read + write
            elif op == "dynamic-update-slice":
                upd = (_shape_bytes_of(comp.shapes.get(ops[1], ""))
                       if len(ops) > 1 else ins.out_bytes)
                op_bytes = 2 * upd                     # in-place update
            else:
                op_bytes = ins.out_bytes + sum(
                    _shape_bytes_of(comp.shapes.get(n, "")) for n in ops)
            _acc_bytes(mult, op_bytes)

    visit(entry, 1.0)
    totals["computation_multipliers"] = {
        k: v for k, v in sorted(visited_mult.items()) if v > 1.0}
    return totals
