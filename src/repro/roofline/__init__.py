"""Roofline-term extraction from compiled XLA artifacts."""

from repro.roofline.analysis import (HW, collective_bytes, roofline_report)

__all__ = ["HW", "collective_bytes", "roofline_report"]
