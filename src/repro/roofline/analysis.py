"""Roofline terms from a compiled (SPMD-partitioned) module.

Facts (verified in tests/test_roofline.py):
  * compiled.cost_analysis()["flops"] / bytes are PER-DEVICE quantities of
    the partitioned module;
  * HLO shapes in compiled.as_text() are per-device shapes; collective
    operands are referenced by NAME, so operand sizes are resolved through a
    name -> bytes table built from all definition lines.

Terms (TPU v5e targets, per chip):
  compute    = flops / peak_flops                (197 TFLOP/s bf16)
  memory     = bytes_accessed / hbm_bw           (819 GB/s)
  collective = collective_bytes / link_bw        (~50 GB/s/link ICI)

collective_bytes follows the assignment's definition: sum of operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (per-device).  A ring-model estimate (x2(n-1)/n for
all-reduce etc.) is reported alongside for the §Perf iteration.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e per-chip hardware constants."""
    peak_flops: float = 197e12      # bf16
    hbm_bw: float = 819e9           # bytes/s
    link_bw: float = 50e9           # bytes/s per ICI link


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_TUPLE_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*\(")
_COLL_RE = re.compile(
    r"=\s*(?:\(|)[\w\[\],{} ]*?(all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done|)\(([^)]*)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _name_table(hlo: str) -> dict[str, int]:
    table: dict[str, int] = {}
    for line in hlo.splitlines():
        m = _DEF_RE.match(line)
        if m:
            table[m.group(1)] = _shape_bytes(m.group(2), m.group(3))
            continue
        m = _TUPLE_DEF_RE.match(line)
        if m:
            # tuple-shaped def: sum all shapes on the line up to the op name
            head = line.split("=", 1)[1]
            head = head.split(")")[0]
            table[m.group(1)] = sum(
                _shape_bytes(t, d) for t, d in _SHAPE_RE.findall(head))
    return table


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo: str, n_devices: int) -> dict[str, Any]:
    """Per-device collective operand bytes + ring-model estimate."""
    table = _name_table(hlo)
    per_op: dict[str, float] = {}
    operand_total = 0.0
    ring_total = 0.0
    count = 0
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op, operands = m.group(1), m.group(2)
        if "-done(" in line:
            continue    # the -start carries the operands
        names = re.findall(r"%([\w.\-]+)", operands)
        obytes = sum(table.get(n, 0) for n in names)
        if obytes == 0:
            # operands may carry inline types (older dialect)
            obytes = sum(_shape_bytes(t, d)
                         for t, d in _SHAPE_RE.findall(operands))
        n = _group_size(line, n_devices)
        frac = (n - 1) / max(n, 1)
        ring = {
            "all-reduce": 2 * obytes * frac,
            "all-gather": obytes * (n - 1),   # operand is the shard
            "reduce-scatter": obytes * frac,
            "all-to-all": obytes * frac,
            "collective-permute": float(obytes),
        }[op]
        per_op[op] = per_op.get(op, 0.0) + obytes
        operand_total += obytes
        ring_total += ring
        count += 1
    return dict(operand_bytes=operand_total, ring_bytes=ring_total,
                per_op=per_op, n_collectives=count)


def roofline_report(cost: dict, coll: dict, hw: HW = HW()) -> dict:
    """The three roofline terms in seconds + dominant-term tag."""
    flops = float(cost.get("flops", 0.0) or 0.0)
    if "bytes accessed" in cost:
        bytes_acc = float(cost["bytes accessed"] or 0.0)
    else:   # CPU backend reports only per-operand keys
        bytes_acc = sum(float(v or 0.0) for k, v in cost.items()
                        if k.startswith("bytes accessed"))
    t_compute = flops / hw.peak_flops
    t_memory = bytes_acc / hw.hbm_bw
    t_coll = coll["operand_bytes"] / hw.link_bw
    t_coll_ring = coll["ring_bytes"] / hw.link_bw
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return dict(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes=coll["operand_bytes"],
        collective_ring_bytes=coll["ring_bytes"],
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_coll,
        t_collective_ring_s=t_coll_ring,
        dominant=dominant,
        step_time_bound_s=max(t_compute, t_memory, t_coll),
    )


def model_flops_train(cfg, shape) -> float:
    """6·N_active·D model FLOPs for one training step (global)."""
    n_active = active_param_count(cfg)
    tokens = shape.global_batch * shape.seq_len
    return 6.0 * n_active * tokens


def active_param_count(cfg) -> float:
    """Per-token active parameter count (MoE counts top_k experts)."""
    d, l, v = cfg.d_model, cfg.n_layers, cfg.vocab
    total = 2.0 * v * d          # embed + head
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.d_inner
        gn = cfg.ssm_groups * cfg.ssm_state
        per = d * (2 * d_in + 2 * gn + cfg.ssm_heads) + d_in * d
        total += l * per
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            napp = l // cfg.shared_attn_every
            attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
                + cfg.n_heads * cfg.head_dim * d
            mlp = 3 * d * cfg.d_ff
            total += napp * (attn + mlp)    # active at every application
        return total
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d
    if cfg.family == "moe":
        ff = 3 * d * cfg.d_ff * cfg.top_k
        if cfg.moe_dense_ff:
            ff += 3 * d * cfg.moe_dense_ff
        ff += d * cfg.n_experts      # router
    else:
        ff = 3 * d * cfg.d_ff
    total += l * (attn + ff)
    return total
