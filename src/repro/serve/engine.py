"""High-throughput serving engine: many models, one process, batched ticks.

The paper's serve-time asset is that scoring is a *streamed kernel matvec*
over the support set — and the PR 2/PR 5 economy (classification, SVR and
one-class SVM all train on one ``(K + βI)`` factorization) applies at serve
time too: every model trained on that factorization scores against the SAME
support points.  The engine exploits this three ways:

  * **Shared-factorization score cache.**  Loaded models are grouped by the
    key ``(kernel, h, β, support-set digest)``; one LRU entry per key holds
    the ONE device-resident copy of the support points plus the (d, ΣP)
    block of every member model's dual-coefficient columns.  k models from
    one training factorization cost one support upload, not k — and one
    kernel pass scores all of them.
  * **Request-level dynamic batching.**  ``submit`` enqueues; a *tick*
    (``flush`` — fired by the max-batch threshold, the max-wait timer of
    the threaded driver, or an explicit call) concatenates every queued
    query across the group's models into one ``(batch, f)`` block, pads it
    to a fixed BUCKET shape (one XLA compile per bucket, never one per
    occupancy), and runs ONE multi-column ``kernel_matvec_streamed`` launch
    covering all queued queries and all member models.  Scores come back to
    the host once per tick and are de-interleaved per request.
  * **bf16 block evaluation.**  ``BatchPolicy.compute_dtype="bfloat16"``
    evaluates the test×support kernel blocks from bf16 operands with
    f32-accumulation einsums (the PR 3 convention) — half the score-path
    bandwidth at a pinned tolerance (tests/test_serve.py).

``batched_scores`` is the one scoring entry point (the launch CLI's
demo loop and the bench's per-request baseline call the same function the
batched tick uses, so the two paths can be compared at identical numerics).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import EngineModel
from repro.core.kernelfn import (
    DEFAULT_SCORE_BLOCK, KernelSpec, kernel_block, kernel_matvec_streamed,
)

Array = jax.Array


# --------------------------------------------------------------------- #
# scoring kernels                                                        #
# --------------------------------------------------------------------- #
def _bf16_matvec_streamed(spec: KernelSpec, x_rows: Array, x_cols: Array,
                          v: Array, block: int) -> Array:
    """``kernel_matvec_streamed`` with bf16 block evaluation, f32 accumulation.

    Gaussian blocks use the matmul expansion with every contraction pinned
    to an f32 accumulator (`preferred_element_type`) — the bf16×bf16→f32
    MXU shape; the exp and the score reduction then run in f32.  Non-matmul
    kernels (laplacian) evaluate the block on bf16 operands and accumulate
    the score einsum in f32.
    """
    bf16, f32 = jnp.bfloat16, jnp.float32
    n = x_rows.shape[0]
    pad = (-n) % block
    xr = jnp.pad(x_rows, ((0, pad), (0, 0))).astype(bf16)
    xr = xr.reshape(-1, block, x_rows.shape[1])
    xc = x_cols.astype(bf16)
    vc = v.astype(bf16)
    if spec.name == "gaussian":
        nb = jnp.einsum("df,df->d", xc, xc, preferred_element_type=f32)
        scale = -0.5 / (spec.h * spec.h)

        def body(xblk):
            na = jnp.einsum("qf,qf->q", xblk, xblk,
                            preferred_element_type=f32)
            cross = jnp.einsum("qf,df->qd", xblk, xc,
                               preferred_element_type=f32)
            sq = jnp.maximum(na[:, None] + nb[None, :] - 2.0 * cross, 0.0)
            k = jnp.exp(sq * scale)
            return jnp.einsum("qd,dp->qp", k, vc,
                              preferred_element_type=f32)
    else:
        def body(xblk):
            k = kernel_block(spec, xblk, xc).astype(f32)
            return jnp.einsum("qd,dp->qp", k, vc,
                              preferred_element_type=f32)

    out = jax.lax.map(body, xr)
    return out.reshape(-1, v.shape[1])[:n]


def batched_scores(xq: Array, xs: Array, zy: Array, biases: Array, *,
                   spec: KernelSpec, block: int = DEFAULT_SCORE_BLOCK,
                   compute_dtype: str = "float32") -> Array:
    """Scores ``(n_q, P) = K(xq, xs) @ zy + biases`` for a column block
    covering any number of same-factorization models.

    The f32 path is literally ``kernel_matvec_streamed`` — the same code
    ``EngineModel.decision_function`` runs, so batch-scored f32 results are
    bit-identical to per-model scoring at matched ``block``.
    """
    if compute_dtype == "float32":
        scores = kernel_matvec_streamed(spec, xq, xs, zy, block=block)
    elif compute_dtype == "bfloat16":
        scores = _bf16_matvec_streamed(spec, xq, xs, zy, block)
    else:
        raise ValueError(f"unknown compute_dtype {compute_dtype!r}")
    return scores + biases[None, :]


# --------------------------------------------------------------------- #
# per-task decode (host side, once per tick)                             #
# --------------------------------------------------------------------- #
def _ovo_vote_np(scores: np.ndarray, pairs: np.ndarray, n_classes: int
                 ) -> np.ndarray:
    """Numpy twin of ``multiclass.ovo_vote`` (same tie-break, host-side).

    The per-class scatter-adds are expressed as matmuls against fixed
    (P, k) incidence matrices — ``np.add.at`` is an order of magnitude
    slower and sat squarely in the per-tick decode budget."""
    scores = scores.astype(np.float32)
    winner = np.where(scores >= 0, pairs[:, 0][None, :],
                      pairs[:, 1][None, :])
    votes = (winner[:, :, None]
             == np.arange(n_classes)[None, None, :]).sum(axis=1)
    inc = np.zeros((pairs.shape[0], n_classes), np.float32)
    rows = np.arange(pairs.shape[0])
    inc[rows, pairs[:, 0]] = 1.0
    inc[rows, pairs[:, 1]] = -1.0
    margin = scores @ inc
    return np.argmax(votes + 1e-3 * np.tanh(margin), axis=1)


def decode_predictions(scores: np.ndarray, *, task: str, binary: bool,
                       strategy: str, classes: np.ndarray,
                       pairs: np.ndarray | None
                       ) -> tuple[np.ndarray, np.ndarray]:
    """(decision values, predictions) from a model's (n, P) score columns,
    matching ``EngineModel.decision_function`` / ``predict`` conventions:
    single-column tasks return the flat score column."""
    if task in ("svr", "krr", "gp"):     # regression: raw-value decode
        flat = scores[:, 0]
        return flat, flat
    if task == "oneclass" or binary:
        flat = scores[:, 0]
        return flat, np.where(flat >= 0, 1, -1)
    if strategy == "ovr":
        idx = np.argmax(scores, axis=1)
    else:
        idx = _ovo_vote_np(scores, pairs, int(classes.shape[0]))
    return scores, np.asarray(classes)[idx]


# --------------------------------------------------------------------- #
# batching policy / tickets / groups                                     #
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Tick policy knobs.

    ``buckets`` are the padded batch shapes a tick may launch — occupancy
    is padded UP to the smallest fitting bucket, so XLA compiles once per
    bucket (and per loaded column count), never once per queue length.
    Oversize ticks are chunked at ``buckets[-1]``.  ``max_batch`` queued
    queries trigger an immediate tick; ``max_wait_ms`` is the threaded
    driver's tick period.  ``block`` is the streamed score block size
    (``DEFAULT_SCORE_BLOCK`` — one constant for every predict path).
    """

    max_batch: int = 4096
    max_wait_ms: float = 2.0
    buckets: tuple = (64, 256, 1024, 4096)
    block: int = DEFAULT_SCORE_BLOCK
    compute_dtype: str = "float32"      # "float32" | "bfloat16"

    def __post_init__(self):
        if not self.buckets or tuple(sorted(self.buckets)) != self.buckets:
            raise ValueError("buckets must be ascending and non-empty")
        if self.compute_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"unknown compute_dtype {self.compute_dtype!r}")

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]


class Ticket:
    """Handle for one submitted request; resolved at the covering tick."""

    __slots__ = ("_engine", "_event", "scores", "predictions", "t_submit",
                 "t_done")

    def __init__(self, engine: "ServingEngine"):
        self._engine = engine
        self._event = threading.Event()
        self.scores = None
        self.predictions = None
        self.t_submit = time.perf_counter()
        self.t_done = None

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def _resolve(self, scores, predictions) -> None:
        self.scores, self.predictions = scores, predictions
        self.t_done = time.perf_counter()
        self._event.set()

    def result(self, timeout: float | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
        """(decision values, predictions).  Without the threaded driver a
        pending ticket is resolved by running a tick now."""
        if not self._event.is_set() and not self._engine.running:
            self._engine.flush()
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        return self.scores, self.predictions

    @property
    def latency_s(self) -> float:
        assert self.t_done is not None, "not resolved yet"
        return self.t_done - self.t_submit


@dataclasses.dataclass
class _ModelEntry:
    key: tuple
    col0: int
    col1: int
    task: str
    binary: bool
    strategy: str
    classes: np.ndarray
    pairs: np.ndarray | None


class _Group:
    """One cache entry: host master copies + the device-resident mirrors."""

    def __init__(self, key: tuple, spec: KernelSpec, xs: np.ndarray):
        self.key = key
        self.spec = spec
        self.xs_host = xs                     # (d, f) — shared, immutable
        self.zy_host = np.zeros((xs.shape[0], 0), np.float32)
        self.biases_host = np.zeros((0,), np.float32)
        self.xs_dev: Array | None = None      # uploaded at most once per
        self.zy_dev: Array | None = None      # residency span
        self.biases_dev: Array | None = None
        self.queue: list[tuple[Ticket, _ModelEntry, np.ndarray]] = []
        self.queued_rows = 0

    @property
    def resident(self) -> bool:
        return self.xs_dev is not None

    def append_columns(self, zy: np.ndarray, biases: np.ndarray
                       ) -> tuple[int, int]:
        col0 = self.zy_host.shape[1]
        self.zy_host = np.concatenate(
            [self.zy_host, zy.astype(np.float32)], axis=1)
        self.biases_host = np.concatenate(
            [self.biases_host, biases.astype(np.float32).reshape(-1)])
        # the column block changed shape: the device mirror is stale (the
        # support points are NOT — xs_dev survives)
        self.zy_dev = self.biases_dev = None
        return col0, self.zy_host.shape[1]


def _support_digest(xs: np.ndarray) -> str:
    h = hashlib.sha1()
    h.update(str((xs.shape, str(xs.dtype))).encode())
    h.update(np.ascontiguousarray(xs).tobytes())
    return h.hexdigest()


def group_key(model: EngineModel, xs_host: np.ndarray) -> tuple:
    """The factorization-sharing cache key: models agreeing on it were
    trained on the same ``(K̃ + βI)`` build and score against the same
    device-resident support state."""
    spec = model.spec
    beta = None if model.beta is None else float(model.beta)
    return (spec.name, float(spec.h), spec.impl, beta,
            _support_digest(xs_host))


# --------------------------------------------------------------------- #
# the engine                                                             #
# --------------------------------------------------------------------- #
class ServingEngine:
    """Many trained models behind one process, scored in batched ticks.

    ``max_resident`` bounds how many cache entries hold device memory at
    once (LRU): evicting drops the entry's device arrays only — the host
    master copies stay, and the next request to a member model re-uploads
    (counted in ``stats()['support_uploads']``).
    """

    def __init__(self, policy: BatchPolicy = BatchPolicy(),
                 registry=None, max_resident: int = 8):
        self.policy = policy
        self.registry = registry
        self.max_resident = max_resident
        self._groups: "OrderedDict[tuple, _Group]" = OrderedDict()
        self._models: dict[str, _ModelEntry] = {}
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        self._running = False
        self._counter = 0
        self._latencies: list[float] = []
        self._n_uploads = 0
        self._n_evictions = 0
        self._n_ticks = 0
        self._n_launches = 0
        self._n_queries = 0
        self._n_requests = 0
        # one jit PER ENGINE — wrapped in a fresh closure so the jit cache
        # (keyed on function identity) is private to this engine and the
        # compile-count guard (`_cache_size`) sees exactly its bucket shapes
        def _score_entry(xq, xs, zy, biases, *, spec, block, compute_dtype):
            return batched_scores(xq, xs, zy, biases, spec=spec,
                                  block=block, compute_dtype=compute_dtype)

        self._scorer = jax.jit(
            _score_entry,
            static_argnames=("spec", "block", "compute_dtype"))

    # ------------------------------------------------------------------ #
    # model management                                                    #
    # ------------------------------------------------------------------ #
    def add_model(self, model: EngineModel, model_id: str | None = None
                  ) -> str:
        """Register an in-memory model; returns its id.  Same-key models
        join the existing cache entry (no second support upload)."""
        if model.mesh is not None:
            # gather once: serving is single-process device-local
            model = dataclasses.replace(
                model, x_perm=jnp.asarray(jax.device_get(model.x_perm)),
                z_y=jnp.asarray(jax.device_get(model.z_y)), mesh=None)
        xs = np.asarray(jax.device_get(model.x_perm))
        zy = np.asarray(jax.device_get(model.z_y))
        if zy.ndim == 1:
            zy = zy[:, None]
        biases = np.asarray(jax.device_get(model.biases)).reshape(-1)
        key = group_key(model, xs)
        with self._lock:
            if model_id is None:
                self._counter += 1
                model_id = f"m{self._counter}"
            if model_id in self._models:
                raise ValueError(f"model id {model_id!r} already loaded")
            group = self._groups.get(key)
            if group is None:
                group = _Group(key, model.spec, xs)
                self._groups[key] = group
            col0, col1 = group.append_columns(zy, biases)
            self._models[model_id] = _ModelEntry(
                key=key, col0=col0, col1=col1, task=model.task,
                binary=model.binary, strategy=model.strategy,
                classes=np.asarray(model.classes),
                pairs=None if model.pairs is None
                else np.asarray(model.pairs))
        return model_id

    def load(self, name: str, version: int | None = None,
             prune_tol: float | None = None, model_id: str | None = None
             ) -> str:
        """Load a registry model into the engine; returns its id."""
        if self.registry is None:
            raise RuntimeError("engine was built without a registry")
        model, info = self.registry.load(name, version=version,
                                         prune_tol=prune_tol)
        return self.add_model(
            model, model_id=model_id or f"{name}@v{info.version}")

    def model_group(self, model_id: str):
        """The cache entry a model scores through (tests/introspection)."""
        return self._groups[self._models[model_id].key]

    # ------------------------------------------------------------------ #
    # cache residency                                                     #
    # ------------------------------------------------------------------ #
    def _ensure_resident(self, group: _Group) -> None:
        self._groups.move_to_end(group.key)          # LRU touch
        if group.xs_dev is None:
            group.xs_dev = jnp.asarray(group.xs_host)
            self._n_uploads += 1
        if group.zy_dev is None:
            group.zy_dev = jnp.asarray(group.zy_host)
            group.biases_dev = jnp.asarray(group.biases_host)
        # evict least-recently-used resident entries past the budget
        # (device arrays only — the host master copies stay)
        resident = [g for g in self._groups.values()
                    if g.resident and g.key != group.key]
        excess = len(resident) + 1 - self.max_resident
        for g in resident[:max(excess, 0)]:
            g.xs_dev = g.zy_dev = g.biases_dev = None
            self._n_evictions += 1

    # ------------------------------------------------------------------ #
    # request path                                                        #
    # ------------------------------------------------------------------ #
    def submit(self, model_id: str, x) -> Ticket:
        """Enqueue a request of one or more query points; returns a ticket
        resolved at the next covering tick."""
        entry = self._models[model_id]
        xq = np.asarray(x, np.float32)
        if xq.ndim == 1:
            xq = xq[None, :]
        ticket = Ticket(self)
        with self._lock:
            group = self._groups[entry.key]
            group.queue.append((ticket, entry, xq))
            group.queued_rows += xq.shape[0]
            if group.queued_rows >= self.policy.max_batch:
                if self._running:
                    self._cond.notify()       # wake the driver for the tick
                else:
                    self._flush_group(group)
        return ticket

    def score(self, model_id: str, x, timeout: float | None = 30.0
              ) -> tuple[np.ndarray, np.ndarray]:
        """Synchronous scoring entry point: submit + tick + result.

        This is THE scoring routine (the launch CLI's request loop uses it
        instead of hand-rolling per-task closures); under the threaded
        driver it waits for the covering tick instead of forcing one.
        """
        return self.submit(model_id, x).result(timeout=timeout)

    def flush(self) -> int:
        """Run one tick: score every queued request, group by group.
        Returns the number of requests resolved."""
        n = 0
        with self._lock:
            for group in list(self._groups.values()):
                n += self._flush_group(group)
        return n

    def _flush_group(self, group: _Group) -> int:
        queue, group.queue = group.queue, []
        group.queued_rows = 0
        if not queue:
            return 0
        self._ensure_resident(group)
        xq = np.concatenate([q for _, _, q in queue], axis=0)
        scores = self._score_rows(group, xq)
        self._n_ticks += 1
        # de-interleave: rows per request, columns per model
        row = 0
        for ticket, entry, q in queue:
            sl = scores[row:row + q.shape[0], entry.col0:entry.col1]
            row += q.shape[0]
            vals, preds = decode_predictions(
                sl, task=entry.task, binary=entry.binary,
                strategy=entry.strategy, classes=entry.classes,
                pairs=entry.pairs)
            ticket._resolve(vals, preds)
            self._latencies.append(ticket.latency_s)
        self._n_requests += len(queue)
        return len(queue)

    def _score_rows(self, group: _Group, xq: np.ndarray) -> np.ndarray:
        """One (or, past the largest bucket, a few) padded scorer launches
        covering every queued query row of the tick."""
        pol = self.policy
        out = []
        top = pol.buckets[-1]
        for start in range(0, xq.shape[0], top):
            chunk = xq[start:start + top]
            bucket = pol.bucket_for(chunk.shape[0])
            pad = bucket - chunk.shape[0]
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad, chunk.shape[1]), chunk.dtype)])
            # row-streaming the kernel exists to bound memory on LARGE
            # query sets — a small bucket must not pad up to a full
            # policy.block of kernel rows (block is a function of bucket,
            # so this stays one compile per bucket)
            block = min(pol.block, bucket)
            scores = self._scorer(
                jnp.asarray(chunk), group.xs_dev, group.zy_dev,
                group.biases_dev, spec=group.spec, block=block,
                compute_dtype=pol.compute_dtype)
            self._n_launches += 1
            self._n_queries += bucket - pad
            out.append(np.asarray(scores)[:bucket - pad])
        return np.concatenate(out, axis=0) if len(out) > 1 else out[0]

    # ------------------------------------------------------------------ #
    # threaded max-wait driver                                            #
    # ------------------------------------------------------------------ #
    @property
    def running(self) -> bool:
        return self._running

    def start(self) -> None:
        """Background tick loop: flush every ``max_wait_ms`` or as soon as
        a group hits ``max_batch`` queued queries."""
        with self._lock:
            if self._running:
                return
            self._running = True

        def loop():
            while True:
                with self._cond:
                    if not self._running:
                        return
                    self._cond.wait(self.policy.max_wait_ms / 1e3)
                    if not self._running:
                        return
                self.flush()

        self._thread = threading.Thread(target=loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        with self._cond:
            self._running = False
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.flush()                         # drain anything still queued

    # ------------------------------------------------------------------ #
    # observability                                                       #
    # ------------------------------------------------------------------ #
    def drain_latencies(self) -> list[float]:
        with self._lock:
            out, self._latencies = self._latencies, []
        return out

    def scorer_compiles(self) -> int | None:
        """Jit cache entries of the batch scorer (None if unreadable) —
        must equal the number of distinct (bucket, column-count) shapes."""
        size = getattr(self._scorer, "_cache_size", lambda: None)()
        return size

    def stats(self) -> dict:
        with self._lock:
            resident = [g for g in self._groups.values() if g.resident]
            return dict(
                models=len(self._models),
                groups=len(self._groups),
                cache_entries=len(resident),
                resident_support_bytes=sum(
                    g.xs_host.nbytes for g in resident),
                support_uploads=self._n_uploads,
                evictions=self._n_evictions,
                ticks=self._n_ticks,
                launches=self._n_launches,
                queries=self._n_queries,
                requests=self._n_requests,
                scorer_compiles=self.scorer_compiles(),
            )
