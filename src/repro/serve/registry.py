"""Model registry: trained ``EngineModel``s as persistent, versioned artifacts.

Layout (all IO through ``repro.ckpt`` — the same manifest + per-leaf shard
files, atomic rename, zstd compression the training checkpoints use):

    <root>/
      <name>/
        step_00000001/            # version 1
          manifest.json           # shapes/dtypes + the serve fingerprint
          x_perm.0.npz ...        # (d, f) support points, sharded
          z_y.0.npz ...           # (d, P) dual coefficient columns
          biases.0.npz
          classes.0.npz
          pairs.0.npz             # ovo only
        step_00000002/            # version 2 (a re-train of the same name)

Every version's manifest carries a **fingerprint** (``model_fingerprint``):
artifact kind, format version, task/strategy, kernel spec, β, shapes and
dtypes.  ``load`` refuses anything whose fingerprint is missing, foreign
(a training checkpoint, some other tool's files) or stale (written by an
older/newer FORMAT_VERSION) — the same trust-nothing rule as the streamed
build's resume fingerprint (PR 8).  Checkpoints are data, not code: a
rejected artifact raises ``RegistryError`` instead of deserializing.

Load transform: ``prune_tol`` drops support vectors whose dual weight is
negligible across ALL problem columns (the approximate-extreme-points
observation, Nandan et al. — most duals sit at 0 after training, and a row
with ``max_p |z_y[i, p]| <= prune_tol * max|z_y|`` contributes nothing
detectable to any score).  Pruning directly cuts per-query kernel
evaluations at serve time; the registry records how many rows survived.
"""
from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.core.engine import EngineModel
from repro.core.kernelfn import KernelSpec

# Bump when the saved-artifact schema changes incompatibly; load() rejects
# any other value (stale artifacts are re-exported, never reinterpreted).
FORMAT_VERSION = 1

_KIND = "hss_svm_serve_model"


class RegistryError(RuntimeError):
    """A registry artifact is missing, foreign, stale, or inconsistent."""


def model_fingerprint(model: EngineModel) -> dict:
    """Identity of a serve artifact — JSON-plain scalars only (the dict
    round-trips through the checkpoint manifest)."""
    d, f = model.x_perm.shape
    return dict(
        kind=_KIND,
        format_version=FORMAT_VERSION,
        task=model.task,
        strategy=model.strategy,
        binary=bool(model.binary),
        kernel=model.spec.name,
        h=float(model.spec.h),
        impl=model.spec.impl,
        beta=None if model.beta is None else float(model.beta),
        c_value=float(model.c_value),
        n_support=int(d),
        n_features=int(f),
        n_problems=int(model.z_y.shape[1]),
        n_classes=int(model.classes.shape[0]),
        has_pairs=model.pairs is not None,
        dtype=str(np.dtype(model.x_perm.dtype)),
    )


@dataclasses.dataclass
class LoadInfo:
    """What a load did: which version, and what the pruning transform kept."""

    name: str
    version: int
    n_support_stored: int
    n_support_kept: int
    fingerprint: dict

    @property
    def pruned_frac(self) -> float:
        return 1.0 - self.n_support_kept / max(self.n_support_stored, 1)


class ModelRegistry:
    """Persist/load trained models under one root directory, versioned."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def _dir(self, name: str) -> str:
        if not name or os.sep in name or name.startswith("."):
            raise RegistryError(f"bad model name {name!r}")
        return os.path.join(self.root, name)

    # ------------------------------------------------------------------ #
    def names(self) -> list[str]:
        return sorted(
            d for d in os.listdir(self.root)
            if os.path.isdir(os.path.join(self.root, d))
            and ckpt.latest_step(os.path.join(self.root, d)) is not None)

    def versions(self, name: str) -> list[int]:
        path = self._dir(name)
        if not os.path.isdir(path):
            return []
        return sorted(
            int(d.split("_")[1]) for d in os.listdir(path)
            if d.startswith("step_") and not d.endswith(".tmp"))

    # ------------------------------------------------------------------ #
    def save(self, name: str, model: EngineModel,
             extra: dict | None = None) -> int:
        """Persist ``model`` as the next version of ``name``; returns it.

        Mesh-resident models are gathered to host by the checkpoint layer
        (``save_checkpoint`` device_gets every leaf), so a model trained
        sharded serves from any process.
        """
        if model.z_y.ndim != 2:
            raise RegistryError("EngineModel.z_y must be (d, P)")
        version = (ckpt.latest_step(self._dir(name)) or 0) + 1
        tree = dict(
            x_perm=np.asarray(model.x_perm),
            z_y=np.asarray(model.z_y),
            biases=np.asarray(model.biases),
            classes=np.asarray(model.classes),
        )
        if model.pairs is not None:
            tree["pairs"] = np.asarray(model.pairs)
        meta = dict(fingerprint=model_fingerprint(model))
        if extra:
            meta["extra"] = dict(extra)
        ckpt.save_checkpoint(self._dir(name), tree, step=version, extra=meta)
        return version

    # ------------------------------------------------------------------ #
    def _verify(self, name: str, fp: dict, arrays: dict) -> None:
        if not isinstance(fp, dict) or fp.get("kind") != _KIND:
            raise RegistryError(
                f"{name}: foreign artifact (fingerprint kind "
                f"{fp.get('kind') if isinstance(fp, dict) else None!r}, "
                f"expected {_KIND!r}) — refusing to load")
        if fp.get("format_version") != FORMAT_VERSION:
            raise RegistryError(
                f"{name}: stale artifact format {fp.get('format_version')!r} "
                f"(this build reads {FORMAT_VERSION}) — re-export the model")
        for key in ("x_perm", "z_y", "biases", "classes"):
            if key not in arrays:
                raise RegistryError(f"{name}: artifact is missing {key!r}")
        d, f = arrays["x_perm"].shape
        p = arrays["z_y"].shape[1]
        want = dict(n_support=d, n_features=f, n_problems=p,
                    n_classes=arrays["classes"].shape[0],
                    has_pairs="pairs" in arrays)
        for key, val in want.items():
            if fp.get(key) != val:
                raise RegistryError(
                    f"{name}: fingerprint/{key} says {fp.get(key)!r} but the "
                    f"stored arrays say {val!r} — corrupt or tampered "
                    "artifact")
        if arrays["z_y"].shape[0] != d or arrays["biases"].shape[0] != p:
            raise RegistryError(f"{name}: inconsistent array shapes")

    def load(self, name: str, version: int | None = None,
             prune_tol: float | None = None,
             ) -> tuple[EngineModel, LoadInfo]:
        """Load a version (latest by default) back into an ``EngineModel``.

        ``prune_tol`` applies the support-vector pruning transform (module
        docstring); ``None`` loads the stored arrays bit-identically.
        """
        try:
            arrays, step, meta = ckpt.load_checkpoint_arrays(
                self._dir(name), step=version)
        except FileNotFoundError as e:
            raise RegistryError(f"{name}: no such model/version") from e
        fp = meta.get("fingerprint", {})
        self._verify(name, fp, arrays)

        x_perm, z_y = arrays["x_perm"], arrays["z_y"]
        n_stored = x_perm.shape[0]
        if prune_tol is not None:
            weight = np.max(np.abs(z_y), axis=1)           # (d,)
            keep = weight > prune_tol * max(float(weight.max()), 1e-30)
            if not keep.any():                  # degenerate: keep the top SV
                keep[int(np.argmax(weight))] = True
            x_perm, z_y = x_perm[keep], z_y[keep]

        model = EngineModel(
            x_perm=jnp.asarray(x_perm),
            z_y=jnp.asarray(z_y),
            biases=jnp.asarray(arrays["biases"]),
            classes=arrays["classes"],
            spec=KernelSpec(name=fp["kernel"], h=fp["h"], impl=fp["impl"]),
            c_value=fp["c_value"],
            binary=fp["binary"],
            strategy=fp["strategy"],
            task=fp["task"],
            pairs=arrays.get("pairs"),
            mesh=None,
            beta=fp["beta"],
        )
        info = LoadInfo(name=name, version=step, n_support_stored=n_stored,
                        n_support_kept=x_perm.shape[0], fingerprint=fp)
        return model, info
