"""Serving tier: model registry + batched-tick scoring engine.

``ModelRegistry`` persists trained ``EngineModel``s as versioned,
fingerprinted artifacts (through ``repro.ckpt``); ``ServingEngine`` holds
many loaded models behind a shared-factorization LRU cache and scores
queued requests in dynamically batched ticks.  See the module docstrings
for the design.
"""
from repro.serve.engine import (
    BatchPolicy, ServingEngine, Ticket, batched_scores, decode_predictions,
    group_key,
)
from repro.serve.registry import (
    FORMAT_VERSION, LoadInfo, ModelRegistry, RegistryError,
    model_fingerprint,
)

__all__ = [
    "BatchPolicy",
    "ServingEngine",
    "Ticket",
    "batched_scores",
    "decode_predictions",
    "group_key",
    "FORMAT_VERSION",
    "LoadInfo",
    "ModelRegistry",
    "RegistryError",
    "model_fingerprint",
]
