"""Model configuration schema covering all assigned architecture families."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int                   # dense-FFN hidden size (per-expert size for moe)
    vocab: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_dense_ff: int = 0       # arctic: parallel dense residual FFN width
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4

    # --- attention behaviour ---
    causal: bool = True
    window: int = 0             # >0: local window size for local layers
    alt_local_global: bool = False   # gemma-2: even layers local, odd global
    attn_softcap: float = 0.0        # gemma-2: 50.0
    final_softcap: float = 0.0       # gemma-2: 30.0

    # --- hybrid (zamba-2) ---
    shared_attn_every: int = 0  # apply the shared attention block every k layers

    # --- modality frontend stubs ---
    frontend: str = "none"      # none | audio_stub | vision_stub
    frontend_dim: int = 0       # stub embedding dim (conv-stem/SigLIP output)
    n_prefix_tokens: int = 0    # vlm: number of patch tokens prepended

    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # implementation knobs (hill-climbing levers — see EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    ssd_chunk: int = 128
    loss_chunk: int = 512
    remat: str = "block"        # none | block  (activation checkpointing)
    use_pallas: bool = False    # TPU fast path (tests use interpret mode)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_decoder(self) -> bool:
        return self.family not in ("encoder",)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k runs only for sub-quadratic-decode-state families."""
        return self.family in ("ssm", "hybrid")

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family (see configs/*)."""
        base = dict(
            n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16, d_ff=128, vocab=256,
        )
        if self.n_experts:
            base.update(n_experts=4, top_k=min(self.top_k, 2))
        if self.moe_dense_ff:
            base.update(moe_dense_ff=128)
        if self.family in ("ssm", "hybrid"):
            base.update(ssm_state=16, ssm_head_dim=16)
        if self.frontend != "none":
            base.update(frontend_dim=32, n_prefix_tokens=min(self.n_prefix_tokens, 8) or 0)
        if self.window:
            base.update(window=16)
        if self.shared_attn_every:
            base.update(shared_attn_every=2)
        base.update(overrides)
        return dataclasses.replace(self, **base)
