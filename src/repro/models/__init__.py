"""LM substrate for the assigned architecture pool (DESIGN.md §5)."""

from repro.models.config import ModelConfig
from repro.models.transformer import Model

__all__ = ["ModelConfig", "Model"]
