"""Mamba-2 (SSD) layer: train-time chunked scan + O(1) decode step.

The chunked evaluation treats the token-mixing operator as a semiseparable
matrix — dense diagonal chunk blocks + rank-N off-diagonal state carriers —
which is the same decomposition the paper applies hierarchically to kernel
matrices (DESIGN.md §5).  The Pallas kernel (repro.kernels.ssd) implements
the same schedule on TPU; the jnp path here is the differentiable reference.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.kernels.ssd import ops as ssd_ops

Array = jax.Array


class SSMParams(NamedTuple):
    in_proj: Array    # (d, 2*d_inner + 2*G*N + H)
    conv_w: Array     # (convw, d_inner + 2*G*N)  depthwise causal conv
    conv_b: Array     # (d_inner + 2*G*N,)
    a_log: Array      # (H,)
    d_skip: Array     # (H,)
    dt_bias: Array    # (H,)
    norm: Array       # (d_inner,)
    out_proj: Array   # (d_inner, d)


class SSMCache(NamedTuple):
    conv: Array       # (B, convw-1, conv_dim)
    state: Array      # (B, H, N, P)


def _split_proj(cfg, zxbcdt: Array):
    d_in = cfg.d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    z, x, b, c, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, b, c, dt


def _gated_norm(y: Array, z: Array, gain: Array, eps: float) -> Array:
    g = y * jax.nn.silu(z)
    g32 = g.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(g32 * g32, axis=-1, keepdims=True) + eps)
    return (g32 * scale * (1.0 + gain.astype(jnp.float32))).astype(y.dtype)


def ssm_block(x: Array, p: SSMParams, cfg, return_cache: bool = False):
    """Training/prefill forward. x (B, S, d) -> (B, S, d) [, SSMCache]."""
    bsz, s, _ = x.shape
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    zxbcdt = x @ p.in_proj
    z, xs, b, c, dt = _split_proj(cfg, zxbcdt)

    xbc_raw = jnp.concatenate([xs, b, c], axis=-1)       # (B, S, conv_dim)
    convw = p.conv_w.shape[0]
    pad = jnp.pad(xbc_raw, ((0, 0), (convw - 1, 0), (0, 0)))
    # depthwise causal conv as a sum of shifted slices (convw is tiny: 4)
    out = jnp.zeros_like(xbc_raw)
    for i in range(convw):
        out = out + pad[:, i:i + s] * p.conv_w[i]
    xbc = jax.nn.silu(out + p.conv_b)

    d_in = cfg.d_inner
    xs, b, c = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(bsz, s, h, pdim)
    xs = constrain(xs, ("data", None, "model", None))
    b = b.reshape(bsz, s, g, n)
    c = c.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)  # (B, S, H)
    a = -jnp.exp(p.a_log.astype(jnp.float32))

    # §Perf change C1 (REFUTED, reverted): passing bf16 x/B/C into the SSD
    # chunks was predicted to halve chunk-tensor traffic but MEASURED +2%
    # (the per-operand f32 casts materialize as extra passes, same lesson
    # as change A3).  The measured-best path upcasts once here; on real TPU
    # the Pallas SSD kernel (kernels/ssd) supersedes the XLA chunk loop.
    xs = xs.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    if return_cache:
        from repro.kernels.ssd.ref import ssd_batched_with_state

        y, h_fin = ssd_batched_with_state(
            xs, dt, a, b, c, p.d_skip.astype(jnp.float32),
            chunk=min(cfg.ssd_chunk, s))
        y = y.astype(x.dtype)
    else:
        y = ssd_ops.ssd_forward(
            xs, dt, a, b, c, p.d_skip.astype(jnp.float32),
            chunk=min(cfg.ssd_chunk, s), use_pallas=False,
        ).astype(x.dtype)
    y = y.reshape(bsz, s, d_in)
    y = _gated_norm(y, z, p.norm, cfg.norm_eps)
    out_proj = constrain(y @ p.out_proj, ("data", None, None))
    if return_cache:
        # h_fin from the ref is (B, H, N, P); conv cache stores the RAW
        # (pre-activation) xBC tail, matching ssm_decode_step's window.
        conv_tail = xbc_raw[:, s - (convw - 1):s] if convw > 1 else \
            xbc_raw[:, :0]
        return out_proj, SSMCache(conv=conv_tail, state=h_fin)
    return out_proj


def ssm_cache_init(cfg, batch: int, dtype) -> SSMCache:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32),
    )


def ssm_decode_step(x: Array, p: SSMParams, cache: SSMCache, cfg
                    ) -> tuple[Array, SSMCache]:
    """One-token decode. x (B, 1, d) -> (B, 1, d); O(1) state update."""
    bsz = x.shape[0]
    h, pdim, n, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    zxbcdt = x[:, 0] @ p.in_proj                        # (B, proj)
    z, xs, b, c, dt = _split_proj(cfg, zxbcdt)

    xbc = jnp.concatenate([xs, b, c], axis=-1)          # (B, conv_dim)
    window = jnp.concatenate([cache.conv, xbc[:, None]], axis=1)  # (B,convw,·)
    conv_out = jnp.einsum("bwc,wc->bc", window, p.conv_w,
                          preferred_element_type=jnp.float32
                          ).astype(window.dtype) + p.conv_b
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    d_in = cfg.d_inner
    xs, b, c = jnp.split(xbc, [d_in, d_in + g * n], axis=-1)
    xs = xs.reshape(bsz, h, pdim)
    b = b.reshape(bsz, g, n)
    c = c.reshape(bsz, g, n)
    rep = h // g
    b = jnp.repeat(b, rep, axis=1)                      # (B, H, N)
    c = jnp.repeat(c, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias)   # (B, H)
    a = -jnp.exp(p.a_log.astype(jnp.float32))

    decay = jnp.exp(dt * a)[..., None, None]            # (B, H, 1, 1)
    upd = dt[..., None, None] * b[..., None] * xs[:, :, None, :]
    state = cache.state * decay + upd                   # (B, H, N, P)
    y = jnp.einsum("bhn,bhnp->bhp", c, state,
                   preferred_element_type=jnp.float32)
    y = y + p.d_skip[None, :, None] * xs
    y = y.reshape(bsz, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p.norm, cfg.norm_eps)
    out = (y @ p.out_proj)[:, None]
    return out, SSMCache(conv=new_conv, state=state)
