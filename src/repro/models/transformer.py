"""Model assembly: parameter init, scan-over-layers forward, decode.

Parameters are a plain nested dict whose per-layer leaves are STACKED along a
leading (L,) axis and consumed by ``lax.scan`` — the HLO is one block body
regardless of depth (essential for 100+-layer dry-run compiles), and the
remat policy wraps the scan body.

Families:
  dense   — attn + SwiGLU MLP                      (gemma2/mistral/llama3/dsc)
  moe     — attn + top-k MoE (+ optional parallel dense FFN — arctic)
  ssm     — Mamba-2 SSD blocks only                (mamba2)
  hybrid  — Mamba-2 blocks + ONE shared attention+MLP block applied every
            ``shared_attn_every`` layers (zamba2; the shared block's weights
            are reused at each application — simplification noted: the
            per-application LoRA adapters of the real model are replaced by
            per-application cache slots only)
  encoder — bidirectional attn blocks (hubert) + masked-prediction head
  vlm     — patch-prefix + causal text (paligemma; prefix-LM mask)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.api import constrain
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (AttnParams, MLPParams, MoEParams,
                                 attention_block, decode_attention, mlp_block,
                                 moe_block, rms_norm)

Array = jax.Array
Params = dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def _cast_tree(tree, dtype):
    """Cast float params to the compute dtype (fp32 masters -> bf16 compute)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating)
        else a, tree)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------------ #
    # init                                                               #
    # ------------------------------------------------------------------ #
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = iter(jax.random.split(key, 64))
        d, l = cfg.d_model, cfg.n_layers

        def mat(k, *shape, scale=None):
            scale = scale if scale is not None else shape[-2] ** -0.5
            return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

        p: Params = {
            "embed": mat(next(keys), cfg.vocab, d, scale=0.02),
            "final_norm": jnp.zeros((d,), dt),
        }
        if not cfg.tie_embeddings:
            p["head"] = mat(next(keys), d, cfg.vocab)

        layers: Params = {"ln1": jnp.zeros((l, d), dt)}
        if cfg.family in ("dense", "moe", "encoder", "vlm"):
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            layers["attn"] = AttnParams(
                wq=mat(next(keys), l, d, hq * hd),
                wk=mat(next(keys), l, d, hkv * hd),
                wv=mat(next(keys), l, d, hkv * hd),
                wo=mat(next(keys), l, hq * hd, d),
            )._asdict()
            layers["ln2"] = jnp.zeros((l, d), dt)
            if cfg.family == "moe":
                e, ffe = cfg.n_experts, cfg.d_ff
                layers["moe"] = MoEParams(
                    router=mat(next(keys), l, d, e),
                    w_gate=mat(next(keys), l, e, d, ffe),
                    w_up=mat(next(keys), l, e, d, ffe),
                    w_down=mat(next(keys), l, e, ffe, d),
                )._asdict()
                if cfg.moe_dense_ff:
                    layers["mlp"] = MLPParams(
                        w_gate=mat(next(keys), l, d, cfg.moe_dense_ff),
                        w_up=mat(next(keys), l, d, cfg.moe_dense_ff),
                        w_down=mat(next(keys), l, cfg.moe_dense_ff, d),
                    )._asdict()
            else:
                layers["mlp"] = MLPParams(
                    w_gate=mat(next(keys), l, d, cfg.d_ff),
                    w_up=mat(next(keys), l, d, cfg.d_ff),
                    w_down=mat(next(keys), l, cfg.d_ff, d),
                )._asdict()
        if cfg.family in ("ssm", "hybrid"):
            layers.update(self._ssm_layer_init(next(keys), l))
        p["layers"] = layers

        if cfg.family == "hybrid":
            hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            p["shared"] = {
                "ln1": jnp.zeros((d,), dt),
                "ln2": jnp.zeros((d,), dt),
                "attn": AttnParams(
                    wq=mat(next(keys), d, hq * hd),
                    wk=mat(next(keys), d, hkv * hd),
                    wv=mat(next(keys), d, hkv * hd),
                    wo=mat(next(keys), hq * hd, d),
                )._asdict(),
                "mlp": MLPParams(
                    w_gate=mat(next(keys), d, cfg.d_ff),
                    w_up=mat(next(keys), d, cfg.d_ff),
                    w_down=mat(next(keys), cfg.d_ff, d),
                )._asdict(),
            }
        if cfg.frontend == "vision_stub":
            p["vision_proj"] = mat(next(keys), cfg.frontend_dim, d)
        if cfg.frontend == "audio_stub":
            p["frontend_proj"] = mat(next(keys), cfg.frontend_dim, d)
            p["mask_emb"] = mat(next(keys), d, scale=0.02)
        return p

    def _ssm_layer_init(self, key, l):
        cfg = self.cfg
        dt = _dtype(cfg)
        d = cfg.d_model
        keys = jax.random.split(key, 4)
        d_in = cfg.d_inner
        gn = cfg.ssm_groups * cfg.ssm_state
        proj_out = 2 * d_in + 2 * gn + cfg.ssm_heads
        conv_dim = d_in + 2 * gn
        dt0 = jnp.exp(jax.random.uniform(
            keys[2], (l, cfg.ssm_heads), jnp.float32,
            jnp.log(1e-3), jnp.log(1e-1)))
        dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))   # inverse softplus
        return {
            "ssm": ssm_mod.SSMParams(
                in_proj=(jax.random.normal(keys[0], (l, d, proj_out)) *
                         d ** -0.5).astype(dt),
                conv_w=(jax.random.normal(keys[1], (l, cfg.ssm_conv, conv_dim))
                        * cfg.ssm_conv ** -0.5).astype(dt),
                conv_b=jnp.zeros((l, conv_dim), dt),
                a_log=jnp.log(jnp.broadcast_to(
                    jnp.linspace(1.0, 16.0, cfg.ssm_heads), (l, cfg.ssm_heads))
                ).astype(jnp.float32),
                d_skip=jnp.ones((l, cfg.ssm_heads), jnp.float32),
                dt_bias=dt_bias.astype(jnp.float32),
                norm=jnp.zeros((l, d_in), dt),
                out_proj=(jax.random.normal(keys[3], (l, d_in, d)) *
                          d_in ** -0.5).astype(dt),
            )._asdict()
        }

    # ------------------------------------------------------------------ #
    # embedding / unembedding                                            #
    # ------------------------------------------------------------------ #
    def embed_tokens(self, params: Params, tokens: Array) -> Array:
        cfg = self.cfg
        emb = params["embed"].astype(_cdtype(cfg))
        x = jnp.take(emb, tokens, axis=0) * (cfg.d_model ** 0.5)
        return constrain(x, ("data", None, None))

    def embed_inputs(self, params: Params, batch: dict) -> tuple[Array, Array]:
        """Returns (x (B,S,d), prefix_len) handling modality frontends."""
        cfg = self.cfg
        cd = _cdtype(cfg)
        if cfg.frontend == "audio_stub":
            x = batch["frames"].astype(cd) @ params["frontend_proj"].astype(cd)
            if "mask_indices" in batch:
                m = batch["mask_indices"][..., None]
                x = jnp.where(m, params["mask_emb"].astype(cd), x)
            return constrain(x, ("data", None, None)), 0
        if cfg.frontend == "vision_stub":
            vis = batch["patches"].astype(cd) @ params["vision_proj"].astype(cd)
            txt = self.embed_tokens(params, batch["tokens"])
            x = jnp.concatenate([vis, txt], axis=1)
            return constrain(x, ("data", None, None)), cfg.n_prefix_tokens
        return self.embed_tokens(params, batch["tokens"]), 0

    def logits(self, params: Params, x: Array) -> Array:
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings
                else params["head"]).astype(_cdtype(cfg))
        out = (x @ head).astype(jnp.float32)
        if cfg.final_softcap > 0:
            out = cfg.final_softcap * jnp.tanh(out / cfg.final_softcap)
        return out

    # ------------------------------------------------------------------ #
    # forward (train / prefill)                                          #
    # ------------------------------------------------------------------ #
    def _layer_windows(self) -> Array:
        """Per-layer window sizes: gemma2 alternates local/global."""
        cfg = self.cfg
        if cfg.alt_local_global:
            return jnp.where(jnp.arange(cfg.n_layers) % 2 == 0, cfg.window, 0)
        return jnp.full((cfg.n_layers,), cfg.window, jnp.int32)

    def _block(self, x, lp, positions, window, prefix_len, collect_kv=False):
        cfg = self.cfg
        lp = _cast_tree(lp, _cdtype(cfg))
        aux = jnp.zeros((), jnp.float32)
        kv = None
        if cfg.family in ("ssm", "hybrid"):
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            x = x + ssm_mod.ssm_block(h, ssm_mod.SSMParams(**lp["ssm"]), cfg)
        else:
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            ap = AttnParams(**lp["attn"])
            x = x + attention_block(h, ap, positions, cfg, window, prefix_len)
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                mo, aux = moe_block(h2, MoEParams(**lp["moe"]), cfg.top_k,
                                    cfg.capacity_factor)
                if cfg.moe_dense_ff:
                    mo = mo + mlp_block(h2, MLPParams(**lp["mlp"]))
                x = x + mo
            else:
                x = x + mlp_block(h2, MLPParams(**lp["mlp"]))
            if collect_kv:
                from repro.models.layers import apply_rope
                b, s, _ = h.shape
                k_rot = apply_rope(
                    (h @ ap.wk).reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
                    positions, cfg.rope_theta)
                kv = (
                    k_rot,
                    (h @ ap.wv).reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
                )
        return x, aux, kv

    def _shared_block(self, x, sp, positions, prefix_len):
        cfg = self.cfg
        sp = _cast_tree(sp, _cdtype(cfg))
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        x = x + attention_block(h, AttnParams(**sp["attn"]), positions, cfg,
                                0, prefix_len)
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        return x + mlp_block(h2, MLPParams(**sp["mlp"]))

    def backbone(self, params: Params, x: Array, positions: Array,
                 prefix_len: Array | int = 0) -> tuple[Array, Array]:
        """Scan over layers. Returns (hidden (B,S,d), aux_loss)."""
        cfg = self.cfg
        windows = self._layer_windows()
        shared = params.get("shared")

        def body(carry, xs):
            h, aux = carry
            lp, win, idx = xs
            h, a, _ = self._block(h, lp, positions, win, prefix_len)
            if shared is not None and cfg.shared_attn_every:
                h = jax.lax.cond(
                    (idx + 1) % cfg.shared_attn_every == 0,
                    lambda v: self._shared_block(v, shared, positions,
                                                 prefix_len),
                    lambda v: v, h)
            # Megatron-SP-style: keep the saved residual sequence-sharded on
            # "model" — the per-layer remat residual is the dominant training
            # memory at 100B+ scale (see EXPERIMENTS.md §Perf, llama3 cell).
            h = constrain(h, ("data", "model", None))
            return (h, aux + a), None

        if cfg.remat == "block":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], windows, jnp.arange(cfg.n_layers)))
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux

    # ------------------------------------------------------------------ #
    # losses                                                             #
    # ------------------------------------------------------------------ #
    def chunked_ce(self, params: Params, hidden: Array, labels: Array
                   ) -> Array:
        """Cross-entropy without materializing (B, S, V): scan over S chunks.

        labels == -1 are ignored (padding / prefix positions).
        """
        cfg = self.cfg
        b, s, d = hidden.shape
        cs = min(cfg.loss_chunk, s)
        while s % cs:
            cs -= 1
        nch = s // cs
        hx = jnp.moveaxis(hidden.reshape(b, nch, cs, d), 1, 0)
        lx = jnp.moveaxis(labels.reshape(b, nch, cs), 1, 0)

        def chunk_loss(h_chunk, l_chunk):
            logits = self.logits(params, h_chunk)          # (B, cs, V) f32
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(l_chunk, 0)[..., None], axis=-1)[..., 0]
            valid = (l_chunk >= 0).astype(jnp.float32)
            return jnp.sum((logz - gold) * valid), jnp.sum(valid)

        chunk_loss = jax.checkpoint(chunk_loss)

        def body(carry, xs):
            tot, cnt = carry
            h_chunk, l_chunk = xs
            dl, dc = chunk_loss(h_chunk, l_chunk)
            return (tot + dl, cnt + dc), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hx, lx))
        return tot / jnp.maximum(cnt, 1.0)

    def forward_logits(self, params: Params, batch: dict) -> Array:
        """Full-sequence logits (small-scale tests / serving prefill only)."""
        x, prefix_len = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        hidden, _ = self.backbone(params, x, positions, prefix_len)
        return self.logits(params, hidden)

    def loss_fn(self, params: Params, batch: dict) -> tuple[Array, dict]:
        cfg = self.cfg
        x, prefix_len = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        hidden, aux = self.backbone(params, x, positions, prefix_len)
        labels = batch["labels"]
        if cfg.frontend == "vision_stub":
            hidden = hidden[:, cfg.n_prefix_tokens:]
        if cfg.frontend == "audio_stub" and "mask_indices" in batch:
            labels = jnp.where(batch["mask_indices"], labels, -1)
        ce = self.chunked_ce(params, hidden, labels)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------ #
    # serving: prefill + decode                                          #
    # ------------------------------------------------------------------ #
    def cache_init(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        cd = _cdtype(cfg)
        l = cfg.n_layers
        cache: dict = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.family in ("dense", "moe", "vlm", "encoder"):
            shape = (l, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            cache["k"] = jnp.zeros(shape, cd)
            cache["v"] = jnp.zeros(shape, cd)
        if cfg.family in ("ssm", "hybrid"):
            conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            cache["ssm_conv"] = jnp.zeros((l, batch, cfg.ssm_conv - 1,
                                           conv_dim), cd)
            cache["ssm_state"] = jnp.zeros(
                (l, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                jnp.float32)
        if cfg.family == "hybrid" and cfg.shared_attn_every:
            napp = cfg.n_layers // cfg.shared_attn_every
            shape = (napp, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            cache["shared_k"] = jnp.zeros(shape, cd)
            cache["shared_v"] = jnp.zeros(shape, cd)
        return cache

    def decode_step(self, params: Params, cache: dict, tokens: Array
                    ) -> tuple[Array, dict]:
        """One decode step for ALL families. tokens (B, 1) -> logits (B, V)."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)            # (B, 1, d)
        pos = cache["pos"]
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        windows = self._layer_windows()
        shared = params.get("shared")
        if shared is not None:
            shared = _cast_tree(shared, _cdtype(cfg))
        new_cache = dict(cache)

        def attn_decode(h, ap, k_cache, v_cache, win):
            bq = (h @ ap.wq).reshape(b, 1, cfg.n_heads, cfg.head_dim)
            bk = (h @ ap.wk).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            bv = (h @ ap.wv).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            from repro.models.layers import apply_rope
            bq = apply_rope(bq, positions, cfg.rope_theta)
            bk = apply_rope(bk, positions, cfg.rope_theta)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, bk.astype(k_cache.dtype), pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, bv.astype(v_cache.dtype), pos, axis=1)
            cur = jnp.full((b,), pos + 1, jnp.int32)
            out = decode_attention(bq, k_cache, v_cache, cur,
                                   softcap=cfg.attn_softcap, window=win)
            return (out.reshape(b, 1, -1) @ ap.wo), k_cache, v_cache

        if cfg.family in ("dense", "moe", "vlm", "encoder"):
            def body(carry, xs):
                h = carry
                lp, kc, vc, win = xs
                lp = _cast_tree(lp, _cdtype(cfg))
                hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
                att, kc, vc = attn_decode(hn, AttnParams(**lp["attn"]), kc,
                                          vc, win)
                h = h + att
                h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    mo, _ = moe_block(h2, MoEParams(**lp["moe"]), cfg.top_k,
                                      cfg.capacity_factor)
                    if cfg.moe_dense_ff:
                        mo = mo + mlp_block(h2, MLPParams(**lp["mlp"]))
                    h = h + mo
                else:
                    h = h + mlp_block(h2, MLPParams(**lp["mlp"]))
                return h, (kc, vc)

            x, (ks, vs) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"], windows))
            new_cache["k"], new_cache["v"] = ks, vs
        else:   # ssm / hybrid
            def ssm_scan(x_in, lp_seg, conv_seg, state_seg):
                def body(h, xs):
                    lp, conv_c, state_c = xs
                    lp = _cast_tree(lp, _cdtype(cfg))
                    hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
                    out, sc = ssm_mod.ssm_decode_step(
                        hn, ssm_mod.SSMParams(**lp["ssm"]),
                        ssm_mod.SSMCache(conv=conv_c, state=state_c), cfg)
                    return h + out, (sc.conv, sc.state)

                return jax.lax.scan(body, x_in, (lp_seg, conv_seg, state_seg))

            every = cfg.shared_attn_every
            if shared is None or not every:
                x, (convs, states) = ssm_scan(
                    x, params["layers"], cache["ssm_conv"],
                    cache["ssm_state"])
                new_cache["ssm_conv"], new_cache["ssm_state"] = convs, states
            else:
                # §Perf change B1: shared-attention KV caches must NOT ride
                # the layer-scan carry (each iteration copies the whole
                # cache: 38 x 100 MB/token at 500k).  Segment the loop so
                # each shared application is OUTSIDE the scan with a STATIC
                # cache index.
                napp = cfg.n_layers // every
                take = lambda t, a, b: jax.tree.map(lambda v: v[a:b], t)
                convs_out, states_out, sks, svs = [], [], [], []
                sk_cache, sv_cache = cache["shared_k"], cache["shared_v"]
                for seg in range(napp):
                    a, b_ = seg * every, (seg + 1) * every
                    x, (cv, st) = ssm_scan(
                        x, take(params["layers"], a, b_),
                        cache["ssm_conv"][a:b_], cache["ssm_state"][a:b_])
                    convs_out.append(cv)
                    states_out.append(st)
                    hn = rms_norm(x, shared["ln1"], cfg.norm_eps)
                    att, kc, vc = attn_decode(
                        hn, AttnParams(**shared["attn"]),
                        sk_cache[seg], sv_cache[seg], 0)
                    x = x + att
                    h2 = rms_norm(x, shared["ln2"], cfg.norm_eps)
                    x = x + mlp_block(h2, MLPParams(**shared["mlp"]))
                    sks.append(kc)
                    svs.append(vc)
                if napp * every < cfg.n_layers:
                    x, (cv, st) = ssm_scan(
                        x, take(params["layers"], napp * every,
                                cfg.n_layers),
                        cache["ssm_conv"][napp * every:],
                        cache["ssm_state"][napp * every:])
                    convs_out.append(cv)
                    states_out.append(st)
                new_cache["ssm_conv"] = jnp.concatenate(convs_out, axis=0)
                new_cache["ssm_state"] = jnp.concatenate(states_out, axis=0)
                new_cache["shared_k"] = jnp.stack(sks, axis=0)
                new_cache["shared_v"] = jnp.stack(svs, axis=0)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self.logits(params, x)[:, 0]
        new_cache["pos"] = pos + 1
        return logits, new_cache

    def prefill(self, params: Params, batch: dict, max_len: int
                ) -> tuple[Array, dict]:
        """Process a full prompt; returns (last-token logits, filled cache).

        For attention families the per-layer K/V are recomputed from the
        block inputs (one extra pair of projections — cheap next to the
        attention itself) and written into the cache.
        """
        cfg = self.cfg
        x, prefix_len = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        windows = self._layer_windows()
        cache = self.cache_init(b, max_len)

        if cfg.family in ("ssm", "hybrid"):
            return self._prefill_ssm(params, x, positions, prefix_len, cache,
                                     max_len)

        def body(carry, xs):
            h = carry
            lp, win = xs
            h2, _, kv = self._block(h, lp, positions, win, prefix_len,
                                    collect_kv=True)
            return h2, kv

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], windows))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        pad = max_len - s
        cache["k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["pos"] = jnp.asarray(s, jnp.int32)
        logits = self.logits(params, x[:, -1:])[:, 0]
        return logits, cache

    def _prefill_ssm(self, params, x, positions, prefix_len, cache, max_len):
        """SSM / hybrid prefill: fills SSD states (+ shared-block KV)."""
        from repro.models.layers import apply_rope

        cfg = self.cfg
        b, s, _ = x.shape
        shared = params.get("shared")
        if shared is not None:
            shared = _cast_tree(shared, _cdtype(cfg))
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        cd = _cdtype(cfg)

        def body(h, xs):
            lp, idx = xs
            lp = _cast_tree(lp, _cdtype(cfg))
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            out, sc = ssm_mod.ssm_block(
                hn, ssm_mod.SSMParams(**lp["ssm"]), cfg, return_cache=True)
            h = h + out
            sk = jnp.zeros((b, s, kvh, hd), cd)
            sv = jnp.zeros((b, s, kvh, hd), cd)
            if shared is not None and cfg.shared_attn_every:
                def apply_shared(v):
                    hn2 = rms_norm(v, shared["ln1"], cfg.norm_eps)
                    ap = AttnParams(**shared["attn"])
                    att = attention_block(hn2, ap, positions, cfg, 0,
                                          prefix_len)
                    h2 = v + att
                    h3 = rms_norm(h2, shared["ln2"], cfg.norm_eps)
                    h2 = h2 + mlp_block(h3, MLPParams(**shared["mlp"]))
                    k_rot = apply_rope((hn2 @ ap.wk).reshape(b, s, kvh, hd),
                                       positions, cfg.rope_theta)
                    v_raw = (hn2 @ ap.wv).reshape(b, s, kvh, hd)
                    return h2, k_rot.astype(cd), v_raw.astype(cd)

                h, sk, sv = jax.lax.cond(
                    (idx + 1) % cfg.shared_attn_every == 0,
                    apply_shared, lambda v: (v, sk, sv), h)
            return h, (sc.conv.astype(cd), sc.state, sk, sv)

        x, (convs, states, sks, svs) = jax.lax.scan(
            body, x, (params["layers"], jnp.arange(cfg.n_layers)))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        cache["ssm_conv"], cache["ssm_state"] = convs, states
        if shared is not None and cfg.shared_attn_every:
            k = cfg.shared_attn_every
            app_layers = jnp.arange(k - 1, cfg.n_layers, k)
            pad = max_len - s
            cache["shared_k"] = jnp.pad(
                sks[app_layers], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            cache["shared_v"] = jnp.pad(
                svs[app_layers], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["pos"] = jnp.asarray(s, jnp.int32)
        logits = self.logits(params, x[:, -1:])[:, 0]
        return logits, cache
