"""Transformer building blocks: norms, RoPE, chunked attention, MLP, MoE.

Everything is a pure function over explicit parameter pytrees (stacked over
layers by the caller and scanned — see transformer.py).  Activation-sharding
constraints are injected through repro.dist.api.constrain (no-op outside a
mesh context), so the same code serves single-device smoke tests and the
512-chip dry-run.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.dist.api import constrain

Array = jax.Array


# --------------------------------------------------------------------- #
# norms / embeddings / rope                                             #
# --------------------------------------------------------------------- #
def rms_norm(x: Array, gain: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * scale) * (1.0 + gain.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (..., S, n_heads, head_dim), positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# attention                                                             #
# --------------------------------------------------------------------- #
class AttnParams(NamedTuple):
    wq: Array   # (d, H*hd)
    wk: Array   # (d, KV*hd)
    wv: Array   # (d, KV*hd)
    wo: Array   # (H*hd, d)


def _block_mask(qpos: Array, kpos: Array, causal: bool, window: Array | int,
                prefix_len: Array | int) -> Array:
    """(bq, bk) mask; window <= 0 means global; prefix positions always visible."""
    q = qpos[:, None]
    k = kpos[None, :]
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= (q >= k) | (k < prefix_len)
    w = jnp.asarray(window)
    m &= (w <= 0) | ((q - k) < w) | (k < prefix_len)
    return m


def chunked_attention(
    q: Array, k: Array, v: Array,
    *, causal: bool, window: Array | int = 0, softcap: float = 0.0,
    prefix_len: Array | int = 0, chunk_q: int = 512, chunk_kv: int = 1024,
    q_offset: Array | int = 0,
) -> Array:
    """Memory-bounded online-softmax attention (Rabe–Staats), pure XLA.

    q (B, S, H, D); k, v (B, Skv, KV, D).  GQA by head-group reshape — no
    K/V repetition is materialized.  This is the differentiable/dry-run path;
    repro.kernels.attention is the TPU fast path with identical semantics.
    """
    b, s, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = 1.0 / (d ** 0.5)

    cq = min(chunk_q, s)
    while s % cq:
        cq -= 1
    ck = min(chunk_kv, skv)
    while skv % ck:
        ck -= 1
    nq, nk = s // cq, skv // ck

    qr = q.reshape(b, nq, cq, kvh, rep, d)
    kr = k.reshape(b, nk, ck, kvh, d)
    vr = v.reshape(b, nk, ck, kvh, d)

    def q_block(iq, q_blk):
        qpos = q_offset + iq * cq + jnp.arange(cq)

        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ik, k_blk, v_blk = inp
            kpos = ik * ck + jnp.arange(ck)
            logits = jnp.einsum(
                "bckrd,bzkd->bkrcz", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale                                   # (b,kv,rep,cq,ck)
            if softcap > 0:
                logits = softcap * jnp.tanh(logits / softcap)
            mask = _block_mask(qpos, kpos, causal, window, prefix_len)
            logits = jnp.where(mask[None, None, None], logits, -1e30)
            m_cur = jnp.max(logits, axis=-1)
            m_new = jnp.maximum(m_run, m_cur)
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            # NOTE (§Perf change A3, REFUTED): casting p to bf16 before the
            # PV matmul was predicted to halve block traffic but MEASURED
            # +8% memory — the cast materializes an extra pass over the f32
            # block instead of fusing.  Kept in f32; on real TPU the Pallas
            # flash kernel supersedes this whole path.
            upd = jnp.einsum("bkrcz,bzkd->bkrcd", p,
                             v_blk.astype(jnp.float32))
            acc = acc * alpha[..., None] + upd
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, rep, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, rep, cq), jnp.float32)
        a0 = jnp.zeros((b, kvh, rep, cq, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kr, 1, 0), jnp.moveaxis(vr, 1, 0)),
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return out.astype(q.dtype)                      # (b,kv,rep,cq,d)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qr, 1, 0)))
    # (nq, b, kv, rep, cq, d) -> (b, s, h, d)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 2, 3, 1, 4, 5)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def _attention_sharded(q, k, v, cfg, layer_window, prefix_len):
    """Head-parallel attention under shard_map: H on "model", B on "data".

    Inside the map every device runs plain chunked attention on ITS heads
    with the full (replicated-over-model) K/V — ZERO collectives inside the
    chunk loops.  Without this, the SPMD partitioner re-gathers K/V blocks
    on every (q-chunk, kv-chunk) iteration (measured 4.3 TB/step on the
    llama3-405b train cell — §Perf change A2).
    """
    from repro.dist import api as dist_api
    from jax.sharding import PartitionSpec as P

    ctx = dist_api._current()
    mesh, tr = ctx
    model_ax = tr.get("model")
    data_ax = tr.get("data")
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    mp = mesh.shape[model_ax] if model_ax else 1
    if mp == 1 or h % mp:
        return None     # fall back to the pjit-auto path
    h_loc = h // mp
    group = h // kvh                    # q heads per kv head
    kv_loc = max(1, h_loc // group)
    # each device's q heads must map to a CONTIGUOUS kv-head range
    if h_loc % kv_loc or not (group % h_loc == 0 or h_loc % group == 0):
        return None
    dspec = dist_api.resolve_spec(("data",), (b,))[0]

    def local(q_l, k_l, v_l, win, plen):
        # slice the kv heads this device's q heads attend to
        midx = jax.lax.axis_index(model_ax)
        start = (midx * h_loc * kvh) // h
        k_s = jax.lax.dynamic_slice_in_dim(k_l, start, kv_loc, axis=2)
        v_s = jax.lax.dynamic_slice_in_dim(v_l, start, kv_loc, axis=2)
        return chunked_attention(
            q_l, k_s, v_s, causal=cfg.causal, window=win,
            softcap=cfg.attn_softcap, prefix_len=plen,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)

    return dist_api.shard_map(
        local, mesh,
        in_specs=(P(dspec, None, model_ax, None),
                  P(dspec, None, None, None),
                  P(dspec, None, None, None), P(), P()),
        out_specs=P(dspec, None, model_ax, None),
    )(q, k, v, jnp.asarray(layer_window), jnp.asarray(prefix_len))


def attention_block(
    x: Array, p: AttnParams, positions: Array, cfg, layer_window: Array | int,
    prefix_len: Array | int = 0,
) -> Array:
    """Full attention sub-block: proj -> rope -> attention -> out proj."""
    from repro.dist import api as dist_api

    b, s, d_model = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p.wq).reshape(b, s, h, hd)
    k = (x @ p.wk).reshape(b, s, kv, hd)
    v = (x @ p.wv).reshape(b, s, kv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = None
    if cfg.use_pallas:
        from repro.kernels.attention import ops as attn_ops
        out = attn_ops.fused_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=cfg.causal,
            window=None, softcap=cfg.attn_softcap, interpret=True,
        ).transpose(0, 2, 1, 3)
    elif dist_api._current() is not None:
        out = _attention_sharded(q, k, v, cfg, layer_window, prefix_len)
    if out is None:
        q = constrain(q, ("data", None, "model", None))
        k = constrain(k, ("data", None, "model", None))
        out = chunked_attention(
            q, k, v, causal=cfg.causal, window=layer_window,
            softcap=cfg.attn_softcap, prefix_len=prefix_len,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
        )
    out = out.reshape(b, s, h * hd)
    return constrain(out @ p.wo, ("data", None, None))


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, cur_len: Array,
    *, softcap: float = 0.0, window: Array | int = 0,
) -> Array:
    """Single-token decode: q (B, 1, H, D) vs cache (B, Smax, KV, D).

    Positions >= cur_len are masked.  The contraction over the cache length
    axis is sharding-friendly: when Smax is sharded (long_500k SP decode) XLA
    turns the softmax/reduction into the split-K flash-decoding pattern.
    """
    b, _, h, d = q.shape
    smax, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    qr = q.reshape(b, kvh, rep, d)
    logits = jnp.einsum("bkrd,bskd->bkrs", qr.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / (d ** 0.5)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(smax)
    valid = pos[None, :] < cur_len[:, None]              # (B, Smax)
    w = jnp.asarray(window)
    in_window = (w <= 0) | ((cur_len[:, None] - 1 - pos[None, :]) < w)
    mask = (valid & in_window)[:, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrs,bskd->bkrd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# --------------------------------------------------------------------- #
# MLP / MoE                                                             #
# --------------------------------------------------------------------- #
class MLPParams(NamedTuple):
    w_gate: Array   # (d, ff)
    w_up: Array     # (d, ff)
    w_down: Array   # (ff, d)


def mlp_block(x: Array, p: MLPParams) -> Array:
    h = jax.nn.silu(x @ p.w_gate) * (x @ p.w_up)
    h = constrain(h, ("data", None, "model"))
    return constrain(h @ p.w_down, ("data", None, None))


class MoEParams(NamedTuple):
    router: Array    # (d, E)
    w_gate: Array    # (E, d, ffe)
    w_up: Array      # (E, d, ffe)
    w_down: Array    # (E, ffe, d)


def _moe_dispatch_chunk(xf: Array, p: MoEParams, top_k: int, cap: int,
                        e_pad: int) -> tuple[Array, Array]:
    """Dispatch/compute/combine for one token chunk. xf (Tc, d)."""
    t, d = xf.shape
    e = p.router.shape[-1]
    logits = (xf @ p.router).astype(jnp.float32)           # (Tc, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # (Tc, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balancing auxiliary loss.
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((t * top_k,), jnp.float32)) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    flat_e = gate_idx.reshape(-1)                          # (Tc*k,)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)                            # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * top_k) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e_pad * cap)    # overflow -> trash

    buf = jnp.zeros((e_pad * cap + 1, d), xf.dtype).at[slot].set(
        jnp.where(keep[:, None], xf[st], 0.0))
    buf = buf[:-1].reshape(e_pad, cap, d)
    # Shard capacity on "data" as well: without it every data-group computes
    # every expert's FULL capacity redundantly (16x wasted FLOPs — found via
    # the dry-run roofline, see EXPERIMENTS.md §Perf).
    buf = constrain(buf, ("model", "data", None))

    pad_e = ((0, e_pad - e), (0, 0), (0, 0))
    wg = jnp.pad(p.w_gate, pad_e).astype(xf.dtype)
    wu = jnp.pad(p.w_up, pad_e).astype(xf.dtype)
    wd = jnp.pad(p.w_down, pad_e).astype(xf.dtype)
    hgate = jnp.einsum("ecd,edf->ecf", buf, wg,
                       preferred_element_type=jnp.float32)
    hup = jnp.einsum("ecd,edf->ecf", buf, wu,
                     preferred_element_type=jnp.float32)
    hout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hgate) * hup,
                      wd.astype(jnp.float32),
                      preferred_element_type=jnp.float32).astype(xf.dtype)
    hout = constrain(hout, ("model", "data", None))

    yflat = hout.reshape(e_pad * cap, d)
    yflat = jnp.concatenate([yflat, jnp.zeros((1, d), xf.dtype)], axis=0)
    gathered = yflat[slot] * (sw * keep)[:, None].astype(xf.dtype)
    out = jnp.zeros((t, d), xf.dtype).at[st].add(gathered)
    return out, aux


def _moe_local_chunk(xf: Array, p_router: Array, wg: Array, wu: Array,
                     wd: Array, top_k: int, cap: int, e_pad: int,
                     my_experts: Array) -> tuple[Array, Array]:
    """Per-device MoE for one LOCAL token chunk (runs inside shard_map).

    xf (Tloc, d) local tokens; wg/wu/wd (E_loc, d, ffe)/( E_loc, ffe, d)
    local expert weights; my_experts: global ids of local experts (E_loc,).
    Each device routes its own tokens, slices the dispatch buffer rows that
    belong to ITS experts, computes them, and scatters partial outputs back;
    the cross-device combine is ONE psum over "model" done by the caller.
    """
    t, d = xf.shape
    e = p_router.shape[-1]
    e_loc = wg.shape[0]
    logits = (xf @ p_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((t * top_k,), jnp.float32)) / (t * top_k)
    aux = e * jnp.sum(me * ce)

    flat_e = gate_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(t), top_k)
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * top_k) - starts[se]
    # keep only choices routed to experts THIS device owns, within capacity
    e0 = my_experts[0]
    local = (se >= e0) & (se < e0 + e_loc) & (pos < cap)
    slot = jnp.where(local, (se - e0) * cap + pos, e_loc * cap)

    buf = jnp.zeros((e_loc * cap + 1, d), xf.dtype).at[slot].set(
        jnp.where(local[:, None], xf[st], 0.0))
    buf = buf[:-1].reshape(e_loc, cap, d)
    hgate = jnp.einsum("ecd,edf->ecf", buf, wg,
                       preferred_element_type=jnp.float32)
    hup = jnp.einsum("ecd,edf->ecf", buf, wu,
                     preferred_element_type=jnp.float32)
    hout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hgate) * hup,
                      wd.astype(jnp.float32),
                      preferred_element_type=jnp.float32).astype(xf.dtype)

    yflat = jnp.concatenate(
        [hout.reshape(e_loc * cap, d), jnp.zeros((1, d), xf.dtype)], axis=0)
    gathered = yflat[slot] * (sw * local)[:, None].astype(xf.dtype)
    partial = jnp.zeros((t, d), xf.dtype).at[st].add(gathered)
    return partial, aux


def moe_block(x: Array, p: MoEParams, top_k: int, capacity_factor: float,
              tokens_per_chunk: int = 65536, expert_pad: int = 16
              ) -> tuple[Array, Array]:
    """Top-k MoE with capacity; GShard-style expert parallelism.

    Under an active mesh this runs as a shard_map: tokens stay sharded on
    "data"(+"pod"), experts are sharded on "model" (zero-padded to divide),
    every device computes ONLY its own experts' capacity rows, and the
    combine is a single psum over "model" of the (Tloc, d) partial outputs —
    the dispatch buffers never cross devices (the earlier pjit-auto scatter
    lowered to per-chunk multi-GB all-reduces; see EXPERIMENTS.md §Perf).
    Without a mesh it falls back to the single-device dispatch.
    """
    from repro.dist import api as dist_api

    b, s, d = x.shape
    e = p.router.shape[-1]
    e_pad = ((e + expert_pad - 1) // expert_pad) * expert_pad

    ctx = dist_api._current()
    if ctx is None:
        t = b * s
        cap = min(int(max(4, (t * top_k / e) * capacity_factor)), t)
        out, aux = _moe_dispatch_chunk(x.reshape(t, d), p, top_k, cap, e_pad)
        return out.reshape(b, s, d), aux

    mesh, tr = ctx
    from jax.sharding import PartitionSpec as P

    data_ax = tr.get("data")
    model_ax = tr.get("model")
    dp = 1
    for a in (data_ax if isinstance(data_ax, tuple) else (data_ax,)):
        if a:
            dp *= mesh.shape[a]
    mp = mesh.shape[model_ax] if model_ax else 1
    e_loc = e_pad // mp

    pad_e = ((0, e_pad - e), (0, 0), (0, 0))
    wg = constrain(jnp.pad(p.w_gate, pad_e), ("model", "data", None))
    wu = constrain(jnp.pad(p.w_up, pad_e), ("model", "data", None))
    wd = constrain(jnp.pad(p.w_down, pad_e), ("model", None, "data"))

    t_glob = b * s
    t_loc = t_glob // dp
    # local chunking bound (memory): local tokens per dispatch round
    n_chunk = max(1, t_loc // tokens_per_chunk)
    while t_loc % n_chunk:
        n_chunk += 1
    tc = t_loc // n_chunk
    cap = min(int(max(4, (tc * top_k / e) * capacity_factor)), tc)

    wspec_in = P(model_ax, dist_api.resolve_spec(("data",), (d,))[0], None)
    wspec_out = P(model_ax, None,
                  dist_api.resolve_spec(("data",), (d,))[0])
    xspec = P(dist_api.resolve_spec(("data",), (t_glob,))[0], None)

    def local_fn(xf_l, router_l, wg_l, wu_l, wd_l):
        # gather FSDP-sharded expert weights once per layer (not per chunk)
        if data_ax:
            wg_f = jax.lax.all_gather(wg_l, data_ax, axis=1, tiled=True)
            wu_f = jax.lax.all_gather(wu_l, data_ax, axis=1, tiled=True)
            wd_f = jax.lax.all_gather(wd_l, data_ax, axis=2, tiled=True)
        else:
            wg_f, wu_f, wd_f = wg_l, wu_l, wd_l
        midx = jax.lax.axis_index(model_ax) if model_ax else 0
        my_experts = midx * e_loc + jnp.arange(e_loc)

        def one(xc):
            part, aux = _moe_local_chunk(
                xc, router_l, wg_f.astype(xc.dtype), wu_f.astype(xc.dtype),
                wd_f.astype(xc.dtype), top_k, cap, e_pad, my_experts)
            return part, aux

        if n_chunk == 1:
            partial, aux = one(xf_l)
        else:
            parts, auxs = jax.lax.map(one, xf_l.reshape(n_chunk, tc, -1))
            partial, aux = parts.reshape(t_loc, -1), auxs.mean()
        out = jax.lax.psum(partial, model_ax) if model_ax else partial
        all_axes = tuple(a for a in ((model_ax,) if model_ax else ()) +
                         ((data_ax,) if isinstance(data_ax, str) else
                          tuple(data_ax or ())))
        aux = jax.lax.pmean(aux, all_axes) if all_axes else aux
        return out, aux

    out, aux = dist_api.shard_map(
        local_fn, mesh,
        in_specs=(xspec, P(None, None), wspec_in, wspec_in, wspec_out),
        out_specs=(xspec, P()),
    )(x.reshape(t_glob, d), p.router, wg, wu, wd)
    return out.reshape(b, s, d), aux
